"""Benchmark timing helpers."""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall time per call in seconds (blocks on device results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.2f},{derived}"


_QUANT_SCALES = None


def quant_scales():
    """Int8 scale table for the ``_int8`` twin rows: the persisted
    calibration artifact's table when one exists for this backend, else a
    quick traffic-sample fit.  Memoized — every suite in a run times the
    same table, so f32/int8 row pairs differ only in the datapath."""
    global _QUANT_SCALES
    if _QUANT_SCALES is None:
        from repro.runtime import autotune

        calib = autotune.load_calibration()
        if calib is not None and calib.quant_scales is not None:
            _QUANT_SCALES = calib.quant_scales
        else:
            from repro.launch.calibrate import calibrate_quant_scales

            _QUANT_SCALES = calibrate_quant_scales(steps=6,
                                                   flow_models=("cnn",))
    return _QUANT_SCALES
