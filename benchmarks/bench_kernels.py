"""Per-kernel benchmark: correctness (vs oracle) + XLA-path timing + the
kernel's roofline terms on the TPU target (analytic: the container is CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16


def run() -> list[str]:
    rows = []
    from repro.kernels.arype_matmul import arype_matmul, ref_matmul

    for m, k, n in [(1024, 1024, 1024), (4096, 512, 2048)]:
        x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
        err = float(jnp.abs(arype_matmul(x, w) - ref_matmul(x, w)).max())
        t = time_fn(jax.jit(lambda a, b: a @ b), x, w)
        flops = 2 * m * k * n
        byts = (m * k + k * n + m * n) * 2  # bf16 target
        ci = flops / byts
        rows.append(row(
            f"arype_matmul_{m}x{k}x{n}", t * 1e6,
            f"max_err={err:.1e};tpu_compute_us={flops/PEAK_FLOPS_BF16*1e6:.2f};"
            f"tpu_mem_us={byts/HBM_BW*1e6:.2f};arith_intensity={ci:.0f}"))

    from repro.kernels.vpe_smallmm import ref_vpe_matmul, vpe_matmul

    x = jax.random.normal(jax.random.PRNGKey(0), (20000, 3), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 32), jnp.float32)
    err = float(jnp.abs(vpe_matmul(x, w) - ref_vpe_matmul(x, w)).max())
    t = time_fn(jax.jit(lambda a, b: (a[:, :, None] * b[None]).sum(1)), x, w)
    rows.append(row("vpe_smallmm_20000x3x32", t * 1e6,
                    f"max_err={err:.1e};note=paper_cnn_layer1_f1000"))

    from repro.kernels.flash_attention import flash_attention, ref_attention

    b, h, s, d = 1, 4, 512, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, s, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, h, s, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, h, s, d), jnp.float32)
    out = flash_attention(q, k, v, mask="causal")
    ref = ref_attention(q.reshape(b * h, s, d), k.reshape(b * h, s, d),
                        v.reshape(b * h, s, d), mask="causal")
    err = float(jnp.abs(out.reshape(b * h, s, d) - ref).max())
    flops = 4 * b * h * s * s * d / 2  # causal
    rows.append(row("flash_attention_512", 0.0,
                    f"max_err={err:.1e};tpu_compute_us={flops/PEAK_FLOPS_BF16*1e6:.3f}"))

    from repro.kernels.flow_features import flow_feature_update, ref_flow_feature_update
    from repro.kernels.flow_features.ops import META_WIDTH, default_program

    rng = np.random.default_rng(0)
    slots = jnp.asarray(rng.integers(0, 8190, 4096), jnp.int32)
    meta = jnp.asarray(rng.integers(0, 1000, (4096, META_WIDTH)), jnp.int32)
    init = jnp.zeros((8192, 16), jnp.int32)
    prog = default_program()
    outk = flow_feature_update(prog, slots, meta, init)
    refk = ref_flow_feature_update(prog, slots, meta, init)
    eq = bool(jnp.all(outk == refk))
    rows.append(row("flow_features_4096pkts", 0.0, f"exact_match={eq}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
