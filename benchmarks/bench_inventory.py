"""Paper Table 4 analog — implementation inventory.  The FPGA table reports
LUT/BRAM/DSP per module; the TPU-framework analog reports, per assigned
architecture: parameter count, active parameters, per-train-step MODEL_FLOPs,
and the checkpoint footprint — the resources the pod actually provisions.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import row
from repro.configs import SHAPES, get_config, list_archs
from repro.launch.cells import active_param_count, model_flops_for
from repro.models import LM
from repro.models.spec import abstract_params


def run() -> list[str]:
    rows = []
    for arch in list_archs():
        cfg = get_config(arch)
        specs = LM(cfg).specs()
        pa = abstract_params(specs)
        n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(pa))
        n_act = active_param_count(cfg, pa)
        byts = sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree.leaves(pa))
        mf = model_flops_for(cfg, SHAPES["train_4k"], pa)
        rows.append(row(
            f"inventory_{arch}", 0.0,
            f"params={n/1e9:.3f}B;active={n_act/1e9:.3f}B;ckpt_gb={byts/2**30:.1f};"
            f"train4k_model_tflop={mf/1e12:.1f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
