"""Serving-frontend benchmark: N concurrent closed-loop clients driving one
:class:`OctopusService` (queue -> coalesce -> pad-to-bucket -> masked
dispatch), reporting sustained pkt/s and the p50/p99 end-to-end latency the
clients actually observe.

Each client is a seeded :class:`TrafficGenerator` with its own traffic mix —
mice-heavy ports next to elephant-heavy ones, different microbatch sizes —
so the coalescer sees the ragged, uneven arrivals the frontend exists for.
``trace_count`` rides along in the derived column: flat across the run is
the no-retrace-after-warmup proof under real concurrency.

    PYTHONPATH=src python benchmarks/bench_service.py [--smoke]

Rows land in ``benchmarks/run.py --json`` artifacts (CI bench-smoke), so the
service's pkt/s / p99 trajectory is trackable across commits.
"""
from __future__ import annotations

import argparse
import asyncio
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import row  # noqa: E402


def _client_mixes(num_clients: int, batch: int, table_size: int):
    """Heterogeneous per-client configs: alternating mice/elephant-heavy
    mixes and staggered microbatch sizes (the ragged-arrival axis)."""
    from repro.data.traffic import TrafficConfig

    sizes = (batch // 2, batch, batch + batch // 4, batch // 4)
    mixes = (0.05, 0.5, 0.125, 0.3)  # elephant_fraction per client, cycled
    return [TrafficConfig(
        batch_size=max(1, sizes[c % len(sizes)]),
        active_flows=16, elephant_fraction=mixes[c % len(mixes)],
        table_size=table_size, seed=100 + c, client_id=c)
        for c in range(num_clients)]


def _bench_one(num_clients: int, requests: int, batch: int, buckets,
               table_size: int, num_shards: int = 0, quantize: bool = False,
               offload: bool = True):
    import contextlib

    import jax

    from repro.data.traffic import TrafficGenerator
    from repro.models import paper_models
    from repro.runtime import runtime_overrides
    from repro.serving import (
        OctopusPipeline,
        OctopusService,
        PipelineConfig,
        ServiceConfig,
        ShardedOctopusPipeline,
        serve_stream,
    )

    from benchmarks.common import quant_scales

    cfg = PipelineConfig(batch_size=buckets[-1], max_ready=8,
                         flow_model="cnn", table_size=table_size,
                         tracker="segmented")
    pkt_params = paper_models.init_paper_model("mlp", jax.random.PRNGKey(0))
    flow_params = paper_models.init_paper_model("cnn", jax.random.PRNGKey(1))
    # Pipelines capture the ambient runtime at construction, so the int8
    # twin rows only need the override around the constructor.
    ctx = (runtime_overrides(quantize=True, quant_scales=quant_scales())
           if quantize else contextlib.nullcontext())
    with ctx:
        if num_shards:
            pipe = ShardedOctopusPipeline(pkt_params, flow_params, cfg,
                                          num_shards=num_shards)
        else:
            pipe = OctopusPipeline(pkt_params, flow_params, cfg)
    gens = [TrafficGenerator(c)
            for c in _client_mixes(num_clients, batch, table_size)]

    async def drive():
        async with OctopusService(pipe, ServiceConfig(buckets=buckets,
                                                      offload=offload)) as svc:
            warm_traces = svc.trace_count
            await asyncio.gather(*(
                serve_stream(svc, g, requests=requests) for g in gens))
            return svc, warm_traces

    svc, warm_traces = asyncio.run(drive())
    return svc, warm_traces


def run(requests: int = 24, smoke: bool = False):
    """Yield CSV rows (name,us_per_call,derived): one multi-client service
    row per lane layout.  ``us_per_call`` is the client-observed p50 e2e."""
    # offload=True (the default: dispatch on the executor thread, the loop
    # stays responsive) vs the inline `_ovl0` twin — same shape, so the pair
    # isolates what moving the blocking step off the loop is worth.
    if smoke:
        grid = [(4, min(requests, 12), 16, (32, 64), 256, 0, False, True),
                (4, min(requests, 12), 16, (32, 64), 256, 0, False, False),
                (4, min(requests, 12), 16, (32, 64), 256, 0, True, True)]
    else:
        grid = [(4, requests, 16, (32, 64, 128), 1024, 0, False, True),
                (4, requests, 16, (32, 64, 128), 1024, 0, False, False),
                (4, requests, 16, (32, 64, 128), 1024, 0, True, True),
                (8, requests, 24, (64, 128, 256), 1024, 0, False, True),
                (4, requests, 16, (32, 64, 128), 1024, 2, False, True)]
    for (num_clients, reqs, batch, buckets, table_size, num_shards,
         quantize, offload) in grid:
        svc, warm_traces = _bench_one(num_clients, reqs, batch, buckets,
                                      table_size, num_shards,
                                      quantize=quantize, offload=offload)
        s = svc.stats
        lanes = f"_s{num_shards}" if num_shards else ""
        lanes += "_int8" if quantize else ""
        lanes += "" if offload else "_ovl0"
        yield row(
            f"service_cnn_c{num_clients}_b{batch}{lanes}", s.e2e.p50,
            f"pkt_per_s={s.pkt_per_s:.0f};p99_e2e_us={s.e2e.p99:.0f};"
            f"p99_wait_us={s.wait.p99:.0f};host_us={s.host_us:.0f};"
            f"device_us={s.device_us:.0f};clients={num_clients};"
            f"requests={s.served_requests};dispatches={s.dispatches};"
            f"coalesced={s.coalesced};padded={s.padded};"
            f"depth_hwm={s.depth_hwm};retraces={svc.trace_count - warm_traces}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="serving frontend benchmark")
    ap.add_argument("--smoke", action="store_true",
                    help="single small config for per-PR CI")
    ap.add_argument("--requests", type=int, default=24,
                    help="closed-loop requests per client")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    for r in run(requests=args.requests, smoke=args.smoke):
        print(r)
    return 0


if __name__ == "__main__":
    sys.exit(main())
