"""Streaming pipeline benchmark: sustained pkt/s and flow/s over the fused
step (paper headline rows: 31 Mpkt/s extraction, 90 kflow/s use-case 2,
35.7 kflow/s use-case 3), comparing the order-exact scan tracker against the
vectorized segmented tracker, and per-step dispatch against chunked
``scan_len`` dispatch (lax.scan over the step).

    PYTHONPATH=src python benchmarks/bench_pipeline.py [--smoke]

Rows land in ``benchmarks/run.py --json`` artifacts (CI bench-smoke), so the
pkt/s / flow/s trajectory — and the segmented-vs-scan speedup — is trackable
across commits.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import row  # noqa: E402


def _bench_one(flow_model: str, steps: int, batch: int, max_ready: int,
               table_size: int, active_flows: int, tracker: str,
               scan_len: int, seed: int = 0):
    import jax

    from repro.data.traffic import TrafficConfig, TrafficGenerator
    from repro.models import paper_models
    from repro.serving import OctopusPipeline, PipelineConfig

    kw = {} if flow_model == "cnn" else {"top_n": 8}
    cfg = PipelineConfig(batch_size=batch, max_ready=max_ready,
                         flow_model=flow_model, table_size=table_size,
                         tracker=tracker, scan_len=scan_len, **kw)
    pkt_params = paper_models.init_paper_model("mlp", jax.random.PRNGKey(0))
    flow_params = paper_models.init_paper_model(flow_model, jax.random.PRNGKey(1))
    pipe = OctopusPipeline(pkt_params, flow_params, cfg)
    gen = TrafficGenerator(TrafficConfig(
        batch_size=batch, active_flows=active_flows, elephant_fraction=0.3,
        table_size=table_size, seed=seed))
    pipe.warmup()
    stats = pipe.run(gen, steps=steps)
    return pipe, stats


def run(steps: int = 48, smoke: bool = False):
    """Yield CSV rows (name,us_per_call,derived) across (tracker, scan_len).

    Grid: (flow_model, batch, max_ready, table_size, active_flows, tracker,
    scan_len) — the population is sized so elephants cross the ready
    threshold well within ``steps`` and the flow engine actually runs.  The
    smoke grid intentionally holds one shape fixed and varies only tracker /
    scan_len, so the three rows are directly comparable (the acceptance axis:
    segmented + scan_len>1 vs the PR 3 scan baseline)."""
    if smoke:
        grid = [("cnn", 32, 8, 256, 12, "scan", 1),
                ("cnn", 32, 8, 256, 12, "segmented", 1),
                ("cnn", 32, 8, 256, 12, "segmented", 16)]
        steps = min(steps, 32)
    else:
        grid = [("cnn", 32, 8, 1024, 16, "scan", 1),
                ("cnn", 32, 8, 1024, 16, "segmented", 1),
                ("cnn", 32, 8, 1024, 16, "segmented", 8),
                ("cnn", 128, 16, 1024, 64, "segmented", 8),
                ("transformer", 64, 8, 1024, 32, "scan", 1),
                ("transformer", 64, 8, 1024, 32, "segmented", 8)]
    for flow_model, batch, max_ready, table_size, active_flows, tracker, scan_len in grid:
        # keep steps a multiple of scan_len (at least one full chunk):
        # partial chunks would compile the per-step path too and muddy the
        # dispatch-count comparison
        n_steps = max(scan_len, steps - steps % scan_len)
        pipe, s = _bench_one(flow_model, n_steps, batch, max_ready, table_size,
                             active_flows, tracker, scan_len)
        yield row(
            f"pipeline_{flow_model}_b{batch}_{tracker}_x{scan_len}", s.step_us,
            f"pkt_per_s={s.pkt_per_s:.0f};flow_per_s={s.flow_per_s:.1f};"
            f"steps={s.steps};dispatches={s.dispatches};flows={s.flows};"
            f"evicted={s.evicted};trace_count={pipe.trace_count}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="streaming pipeline benchmark")
    ap.add_argument("--smoke", action="store_true",
                    help="single small config for per-PR CI")
    ap.add_argument("--steps", type=int, default=48)
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    for r in run(steps=args.steps, smoke=args.smoke):
        print(r)
    return 0


if __name__ == "__main__":
    sys.exit(main())
