"""Streaming pipeline benchmark: sustained pkt/s and flow/s over the fused
step (paper headline rows: 31 Mpkt/s extraction, 90 kflow/s use-case 2,
35.7 kflow/s use-case 3), comparing the order-exact scan tracker against the
vectorized segmented tracker, per-step dispatch against chunked ``scan_len``
dispatch, the eager loop against the overlapped deferred-sync runtime (the
``_ovl0``/``_ovl1`` twin rows, with the host/device time split in the
derived column), and the single-lane pipeline against hash-partitioned
multi-lane sharding (``num_shards`` > 0 rows).

The sharded rows are *weak scaling*, the paper's own lane-scaling axis
(§2.2: each extractor lane serves its own port): per-lane offered load is
held at ``batch/num_shards`` packets per step with a fixed per-lane capacity,
so the aggregate ingest grows with the lane count — pkt/s should rise
monotonically with ``num_shards`` as the lanes amortize the fixed
per-dispatch cost.  ``padded`` reports the skew cost the keep-masks absorb.

    PYTHONPATH=src python benchmarks/bench_pipeline.py [--smoke]

Rows land in ``benchmarks/run.py --json`` artifacts (CI bench-smoke), so the
pkt/s / flow/s trajectory — and the shard-scaling curve — is trackable
across commits.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import row  # noqa: E402


def _bench_one(flow_model: str, steps: int, batch: int, max_ready: int,
               table_size: int, active_flows: int, tracker: str,
               scan_len: int, num_shards: int = 0, lane_batch=None,
               seed: int = 0, quantize: bool = False, cold_size: int = 0,
               cold_policy: str = "age", top_k=None, pay_bytes=None,
               overlap: bool = False, use_prefetch: bool = False):
    import contextlib

    import jax

    from repro.data.traffic import TrafficConfig, TrafficGenerator, prefetch
    from repro.models import paper_models
    from repro.runtime import runtime_overrides
    from repro.serving import (
        OctopusPipeline,
        PipelineConfig,
        ShardedOctopusPipeline,
    )

    from benchmarks.common import quant_scales

    kw = {} if flow_model == "cnn" else {"top_n": 8}
    if top_k is not None:
        kw["top_k"] = top_k
    if pay_bytes is not None:
        kw["pay_bytes"] = pay_bytes
    cfg = PipelineConfig(batch_size=batch, max_ready=max_ready,
                         flow_model=flow_model, table_size=table_size,
                         tracker=tracker, scan_len=scan_len, overlap=overlap,
                         cold_size=cold_size, cold_policy=cold_policy, **kw)
    pkt_params = paper_models.init_paper_model("mlp", jax.random.PRNGKey(0))
    flow_params = paper_models.init_paper_model(flow_model, jax.random.PRNGKey(1))
    # Pipelines capture the ambient runtime at construction, so the int8
    # twin rows only need the override around the constructor.
    ctx = (runtime_overrides(quantize=True, quant_scales=quant_scales())
           if quantize else contextlib.nullcontext())
    with ctx:
        if num_shards:
            pipe = ShardedOctopusPipeline(pkt_params, flow_params, cfg,
                                          num_shards=num_shards,
                                          lane_batch=lane_batch)
        else:
            pipe = OctopusPipeline(pkt_params, flow_params, cfg)
    gen = TrafficGenerator(TrafficConfig(
        batch_size=batch, active_flows=active_flows, elephant_fraction=0.3,
        table_size=table_size, seed=seed, pay_bytes=cfg.pay_bytes,
        # populations beyond the hot table (the two-level rows) need shared
        # slots — that collision pressure is exactly what the cold store eats
        collision_free=active_flows <= table_size))
    pipe.warmup()
    src = prefetch(gen.batches(steps), depth=2) if use_prefetch else gen
    stats = pipe.run(src, steps=steps)
    return pipe, stats


def _shard_grid(smoke: bool):
    """(per_lane_load, num_shards, lane_batch, table_size) weak-scaling rows:
    aggregate batch = per_lane_load x num_shards, per-lane capacity fixed at
    1.5x the per-lane load (skew headroom; overflow spills into extra merge
    rounds).  The full grid's 8-lane row runs 8 x 1024-slot banks — the
    paper's 8k-flow table, one lane per bank."""
    per_lane, cap = 128, 192
    shards = (1, 2, 4) if smoke else (1, 2, 4, 8)
    return [(per_lane, s, cap if s > 1 else None, 1024) for s in shards]


def _scenario_rows(steps: int):
    """One row per scenario (fixed shapes in smoke and full runs, so the
    topk row can sit in the bench-trend TRACKED set)."""
    from repro.core import decisions
    from repro.data.traffic import TrafficConfig, TrafficGenerator
    from repro.scenarios import (
        AdversarialScenario,
        DDoSScenario,
        HeavyHitterScenario,
        adversarial_config,
    )
    from repro.serving import OctopusPipeline, PipelineConfig
    import jax
    from repro.models import paper_models

    # heavy-hitter: two-level table, population ~2x the hot bank, top-k over
    # hot + cold residents every step
    sc = HeavyHitterScenario(k=8, batch_size=128, max_ready=16,
                             table_size=1024, cold_size=4096, top_n=8,
                             top_k=1, pay_bytes=4)
    gen = TrafficGenerator(TrafficConfig(
        batch_size=128, active_flows=2048, table_size=1024,
        collision_free=False, elephant_fraction=0.3, pay_bytes=4, seed=0))
    sc.pipe.warmup()
    sc.run(gen, steps)
    s = sc.pipe.stats
    yield row(
        "scenario_topk_b128_cold4096", s.step_us,
        f"pkt_per_s={s.pkt_per_s:.0f};flow_per_s={s.flow_per_s:.1f};"
        f"steps={s.steps};spilled={s.spilled};promoted={s.promoted};"
        f"k=8;trace_count={sc.pipe.trace_count}")

    # DDoS: anomaly head + host-side hysteresis controller feedback
    sc = DDoSScenario(deny_on=0.6, deny_off=0.4, batch_size=64,
                      table_size=1024)
    gen = TrafficGenerator(TrafficConfig(
        batch_size=64, active_flows=16, table_size=1024,
        elephant_fraction=1.0, elephant_pkts=(30, 60), seed=0))
    sc.pipe.warmup()
    sc.run(gen, steps)
    s = sc.pipe.stats
    yield row(
        "scenario_ddos_b64_cnn", s.step_us,
        f"pkt_per_s={s.pkt_per_s:.0f};flow_per_s={s.flow_per_s:.1f};"
        f"steps={s.steps};emissions={len(sc.emissions)};"
        f"denied={len(sc.denied)};churn={sc.churn};"
        f"trace_count={sc.pipe.trace_count}")

    # collision attack against the tracker path (feature-only heads, so the
    # row isolates the eviction churn instead of engine inference)
    cfg = PipelineConfig(batch_size=64, max_ready=8, table_size=256,
                         top_n=8, top_k=1, pay_bytes=4,
                         pkt_head=decisions.PassHead(),
                         flow_head=decisions.TopKHead())
    pipe = OctopusPipeline(
        paper_models.init_paper_model("mlp", jax.random.PRNGKey(0)),
        paper_models.init_paper_model("cnn", jax.random.PRNGKey(1)), cfg)
    sc = AdversarialScenario(pipe, adversarial_config(
        "collision_attack", batch_size=64, table_size=256, adv_slots=4,
        active_flows=32, pay_bytes=4, seed=0))
    pipe.warmup()
    s = sc.run(steps)
    yield row(
        "scenario_adv_collision_b64", s.step_us,
        f"pkt_per_s={s.pkt_per_s:.0f};flow_per_s={s.flow_per_s:.1f};"
        f"steps={s.steps};evicted={s.evicted};new_flows={s.new_flows};"
        f"trace_count={pipe.trace_count}")


def run(steps: int = 48, smoke: bool = False):
    """Yield CSV rows (name,us_per_call,derived) across (tracker, scan_len,
    num_shards).

    Grid: (flow_model, batch, max_ready, table_size, active_flows, tracker,
    scan_len) for the single-lane rows — one shape held fixed so tracker /
    scan_len rows stay directly comparable — plus the `_shard_grid` sharded
    family (segmented tracker), whose rows share a per-lane load so the
    num_shards axis is the only variable."""
    if smoke:
        grid = [("cnn", 32, 8, 256, 12, "scan", 1, False),
                ("cnn", 32, 8, 256, 12, "segmented", 1, False),
                ("cnn", 32, 8, 256, 12, "segmented", 16, False),
                ("cnn", 32, 8, 256, 12, "segmented", 16, True)]
        steps = min(steps, 32)
    else:
        grid = [("cnn", 32, 8, 1024, 16, "scan", 1, False),
                ("cnn", 32, 8, 1024, 16, "segmented", 1, False),
                ("cnn", 32, 8, 1024, 16, "segmented", 8, False),
                ("cnn", 32, 8, 1024, 16, "segmented", 8, True),
                ("cnn", 128, 16, 1024, 64, "segmented", 8, False),
                ("cnn", 128, 16, 1024, 64, "segmented", 8, True),
                ("transformer", 64, 8, 1024, 32, "scan", 1, False),
                ("transformer", 64, 8, 1024, 32, "segmented", 8, False)]
    for (flow_model, batch, max_ready, table_size, active_flows, tracker,
         scan_len, quantize) in grid:
        # keep steps a multiple of scan_len (at least one full chunk):
        # partial chunks would compile the per-step path too and muddy the
        # dispatch-count comparison
        n_steps = max(scan_len, steps - steps % scan_len)
        pipe, s = _bench_one(flow_model, n_steps, batch, max_ready, table_size,
                             active_flows, tracker, scan_len, quantize=quantize)
        suffix = "_int8" if quantize else ""
        yield row(
            f"pipeline_{flow_model}_b{batch}_{tracker}_x{scan_len}{suffix}",
            s.step_us,
            f"pkt_per_s={s.pkt_per_s:.0f};flow_per_s={s.flow_per_s:.1f};"
            f"steps={s.steps};dispatches={s.dispatches};flows={s.flows};"
            f"evicted={s.evicted};trace_count={pipe.trace_count}")

    # ---- overlapped-dispatch twins: identical shape, ovl0 = eager loop,
    # ovl1 = deferred-sync run() + the depth-2 traffic prefetcher, so chunk
    # k+1's generation and staging hide under chunk k's device execution.
    # host_us/device_us in the derived column show where the time went (the
    # device share is the EXPOSED wait — it shrinks under overlap).
    ovl_steps = max(8, min(steps, 48) - min(steps, 48) % 8)
    for overlap in (False, True):
        pipe, s = _bench_one("cnn", ovl_steps, 128, 16, 1024, 64,
                             "segmented", 8, overlap=overlap,
                             use_prefetch=overlap)
        yield row(
            f"pipeline_cnn_b128_segmented_x8_ovl{int(overlap)}", s.step_us,
            f"pkt_per_s={s.pkt_per_s:.0f};host_us={s.host_us:.0f};"
            f"device_us={s.device_us:.0f};steps={s.steps};"
            f"dispatches={s.dispatches};flows={s.flows};"
            f"trace_count={pipe.trace_count}")

    # ---- hierarchical flow table (hot + cold): effective capacity 10^5-10^6
    # flows with a live population ~4x the hot table, so every step runs the
    # full spill/promote machinery.  top_k/pay_bytes shrink to keep the cold
    # bank's payload plane small (the cnn flow model never reads it).
    cold_grid = ([(1024, 131072, 4096)] if smoke else
                 [(1024, 0, 4096), (1024, 131072, 4096),
                  (1024, 1048576, 4096)])
    cold_steps = min(steps, 16) if smoke else min(steps, 24)
    for hot, cold, population in cold_grid:
        pipe, s = _bench_one("cnn", cold_steps, 128, 16, hot, population,
                             "segmented", 1, cold_size=cold,
                             top_k=1, pay_bytes=4)
        yield row(
            f"pipeline_cnn_b128_cold{cold}", s.step_us,
            f"pkt_per_s={s.pkt_per_s:.0f};flow_per_s={s.flow_per_s:.1f};"
            f"steps={s.steps};capacity={hot + cold};flows={s.flows};"
            f"evicted={s.evicted};spilled={s.spilled};promoted={s.promoted};"
            f"trace_count={pipe.trace_count}")

    # ---- scenario rows: the pluggable-head use cases (repro.scenarios).
    # heavy-hitter runs feature-only heads (no engine dispatch at all), DDoS
    # runs the anomaly head + hysteresis feedback, and the collision row
    # measures what a hash-collision attack costs the tracker path.
    yield from _scenario_rows(min(steps, 12) if smoke else min(steps, 24))

    shard_steps = min(steps, 24) if smoke else min(steps, 32)
    for per_lane, num_shards, lane_batch, table_size in _shard_grid(smoke):
        batch = per_lane * num_shards
        pipe, s = _bench_one("cnn", shard_steps, batch, 16, table_size,
                             32 * num_shards, "segmented", 1,
                             num_shards=num_shards, lane_batch=lane_batch)
        yield row(
            f"pipeline_cnn_lane{per_lane}_segmented_s{num_shards}", s.step_us,
            f"pkt_per_s={s.pkt_per_s:.0f};flow_per_s={s.flow_per_s:.1f};"
            f"steps={s.steps};dispatches={s.dispatches};padded={s.padded};"
            f"backend={pipe.backend};flows={s.flows};"
            f"trace_count={pipe.trace_count}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="streaming pipeline benchmark")
    ap.add_argument("--smoke", action="store_true",
                    help="single small config for per-PR CI")
    ap.add_argument("--steps", type=int, default=48)
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    for r in run(steps=args.steps, smoke=args.smoke):
        print(r)
    return 0


if __name__ == "__main__":
    sys.exit(main())
