"""Paper Table 5 analog — use-case 1: packet-based MLP intrusion detection.

Paper: 207 ns end-to-end on the FPGA (222 MHz VPE, feature extract + compute).
Here: jit'd per-packet-batch inference latency on the host CPU (the latency
path), plus the FPGA cycle-model estimate for the same kernel instruction
schedule (fig. 7: 4x prd + vadd + 2x prds), and the routed-path comparison
(Octopus VPE routing vs forcing everything onto the systolic/MXU path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core.collaborative import OctopusCycleModel
from repro.models import paper_models
from repro.runtime import RuntimeConfig


def run() -> list[str]:
    rows = []
    params = paper_models.init_paper_model("mlp", jax.random.PRNGKey(0))
    for batch in (1, 8, 64):
        x = jax.random.normal(jax.random.PRNGKey(1), (batch, 6), jnp.float32)
        for policy in ("collaborative", "arype_only"):
            cfg = RuntimeConfig(policy=policy)
            fn = jax.jit(lambda p, xx, cfg=cfg: paper_models.mlp_apply(p, xx, config=cfg))
            t = time_fn(fn, params, x)
            rows.append(row(
                f"usecase1_mlp_b{batch}_{policy}", t * 1e6,
                f"per_pkt_us={t/batch*1e6:.3f};paper_fpga_ns=207"))
    # FPGA cycle model for the MLP instruction schedule on the VPE
    m = OctopusCycleModel()
    layers = [("l0", 1, 6, 12), ("l1", 1, 12, 6), ("l2", 1, 6, 3), ("l3", 1, 3, 2)]
    rep = m.stack_report(layers, collaborative=True)
    ns = rep["time_s"] * 1e9
    rows.append(row("usecase1_mlp_cycle_model", ns / 1e3,
                    f"model_ns={ns:.0f};paper_ns=207;paper_delta={ns/207:.2f}x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
