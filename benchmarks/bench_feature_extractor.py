"""Feature extractor throughput (paper §4.1: 31 Mpkt/s at 125 MHz, ~124 Gbps
at 500 B packets).

Two execution modes benchmarked on packets from the synthetic trace:
  * scan (order-exact oracle — the FPGA's serial line-rate semantics)
  * segmented (TPU-parallel: sort + segment reductions across all flows)
The segmented path is the hardware adaptation that buys back parallelism on
batch-oriented hardware.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core.feature_extractor import ExtractorConfig, FeatureExtractor
from repro.data.packets import PacketTraceConfig, synth_packet_trace


def run() -> list[str]:
    rows = []
    cfg = PacketTraceConfig(num_flows=400, pkts_per_flow=20, seed=0, table_size=8192)
    packets, *_ = synth_packet_trace(cfg)
    n = int(packets.ts.shape[0])
    ex = FeatureExtractor(ExtractorConfig(table_size=8192, top_n=20))

    scan_fn = jax.jit(lambda st, p: ex.extract_scan(st, p)[0].features)
    st0 = ex.init_state()
    t_scan = time_fn(scan_fn, st0, packets, warmup=1, iters=3)
    rows.append(row("feature_extractor_scan", t_scan * 1e6,
                    f"mpkt_s={n/t_scan/1e6:.3f};paper_mpkt_s=31"))

    seg_fn = jax.jit(lambda p: ex.extract_segmented(p)[0])
    t_seg = time_fn(seg_fn, packets, warmup=1, iters=5)
    gbps = n * 500 * 8 / t_seg / 1e9
    rows.append(row("feature_extractor_segmented", t_seg * 1e6,
                    f"mpkt_s={n/t_seg/1e6:.3f};gbps_at_500B={gbps:.1f};paper_gbps=124"))

    from repro.kernels.flow_features.ops import default_program, flow_feature_update
    from repro.core.flow_tracker import hash_slot, build_meta

    slots = hash_slot(packets.tuple_hash, 8192)
    meta = jax.vmap(lambda i: build_meta(
        jax.tree.map(lambda x: x[i], packets), jnp.int32(0)))(jnp.arange(n))
    init = jnp.zeros((8192, 16), jnp.int32)
    prog = default_program()
    kern_fn = jax.jit(lambda s, m, st: flow_feature_update(prog, s, m, st, block=256))
    t_kern = time_fn(kern_fn, slots, meta, init, warmup=1, iters=2)
    rows.append(row("feature_extractor_pallas_interpret", t_kern * 1e6,
                    f"mpkt_s={n/t_kern/1e6:.3f};note=interpret-mode-correctness-only"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
