"""Benchmark harness — one suite per paper table.

    PYTHONPATH=src python benchmarks/run.py                    # full CSV
    PYTHONPATH=src python benchmarks/run.py --smoke --json bench.json

Prints ``name,us_per_call,derived`` CSV rows (unchanged contract), and with
``--json`` also writes a structured artifact: per-suite rows + wall time, the
platform fingerprint and the active calibration fingerprint — the record CI
uploads on every PR so the perf trajectory is trackable across commits.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import os
import sys
import time

# Invoked as `python benchmarks/run.py`, sys.path[0] is benchmarks/ itself;
# the suite imports need the repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

SCHEMA_VERSION = 1


def _parse_row(raw: str) -> dict:
    name, us, derived = raw.split(",", 2)
    try:
        us_val = float(us)
    except ValueError:
        us_val = float("nan")
    return {"name": name, "us_per_call": us_val, "derived": derived}


def _suites(smoke: bool) -> list:
    from benchmarks import (
        bench_collaborative,
        bench_feature_extractor,
        bench_inventory,
        bench_kernels,
        bench_pipeline,
        bench_service,
        bench_usecase1_mlp,
        bench_usecase3_transformer,
    )

    if smoke:
        # The fast paper-table subset: small shapes, no Pallas-interpret or
        # full-inventory sweeps, sized for a per-PR CI job.
        return [
            ("usecase1_mlp(T5)", bench_usecase1_mlp.run),
            ("collaborative(T6)", lambda: bench_collaborative.run(flows=200)),
            ("usecase3_transformer", lambda: bench_usecase3_transformer.run(flows=100)),
            ("pipeline(streaming)", lambda: bench_pipeline.run(smoke=True)),
            ("service(frontend)", lambda: bench_service.run(smoke=True)),
        ]
    return [
        ("inventory(T4)", bench_inventory.run),
        ("usecase1_mlp(T5)", bench_usecase1_mlp.run),
        ("collaborative(T6)", bench_collaborative.run),
        ("usecase3_transformer", bench_usecase3_transformer.run),
        ("feature_extractor", bench_feature_extractor.run),
        ("kernels", bench_kernels.run),
        ("pipeline(streaming)", bench_pipeline.run),
        ("service(frontend)", bench_service.run),
    ]


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description="run the paper-table benchmark suites")
    ap.add_argument("--smoke", action="store_true",
                    help="fast subset for per-PR CI (seconds, not minutes)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write a structured result artifact to PATH")
    ap.add_argument("--calibrated", action="store_true",
                    help="run under RuntimeConfig.calibrated() (falls back to "
                         "analytic defaults when no artifact exists)")
    args = ap.parse_args(argv)

    from repro.runtime import RuntimeConfig, current_runtime, octopus_runtime, platform

    ctx = (octopus_runtime(RuntimeConfig.calibrated()) if args.calibrated
           else contextlib.nullcontext())
    suites = _suites(args.smoke)
    results, failures = [], []
    print("name,us_per_call,derived")
    with ctx:
        active = current_runtime()
        for label, fn in suites:
            t0 = time.perf_counter()
            rows, error = [], None
            try:
                for r in fn():
                    print(r)
                    rows.append(_parse_row(r))
            except Exception as e:  # keep the harness going; record the failure
                error = repr(e)
                failures.append((label, error))
                print(f"{label},nan,ERROR={e!r}")
            if error is None and not rows:
                # A suite that silently emits nothing would hollow out the
                # trajectory gate — treat it like a raise.
                error = "no rows emitted"
                failures.append((label, error))
                print(f"{label},nan,ERROR='no rows emitted'")
            wall = time.perf_counter() - t0
            results.append({"suite": label, "wall_s": wall, "rows": rows,
                            "error": error})
            sys.stderr.write(f"[bench] {label} done in {wall:.1f}s\n")

    if args.json:
        artifact = {
            "schema_version": SCHEMA_VERSION,
            "smoke": args.smoke,
            "platform": platform.fingerprint(),
            "calibration": active.calibration,
            "runtime": {"policy": active.policy, "tau": active.tau,
                        "vpe_max_elems": active.vpe_max_elems,
                        "use_pallas": active.use_pallas,
                        "interpret": active.interpret,
                        "quantize": active.quantize,
                        "quant_impl": active.quant_impl,
                        "quant_scales": (active.quant_scales.fingerprint
                                         if active.quant_scales is not None
                                         else None)},
            "created_unix": time.time(),
            "suites": results,
        }
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=1)
        sys.stderr.write(f"[bench] wrote {args.json}\n")

    if failures:
        sys.stderr.write(f"[bench] FAILURES: {failures}\n")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
