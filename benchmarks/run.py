# One function per paper table. Print ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (
        bench_collaborative,
        bench_feature_extractor,
        bench_inventory,
        bench_kernels,
        bench_usecase1_mlp,
        bench_usecase3_transformer,
    )

    suites = [
        ("inventory(T4)", bench_inventory.run),
        ("usecase1_mlp(T5)", bench_usecase1_mlp.run),
        ("collaborative(T6)", bench_collaborative.run),
        ("usecase3_transformer", bench_usecase3_transformer.run),
        ("feature_extractor", bench_feature_extractor.run),
        ("kernels", bench_kernels.run),
    ]
    print("name,us_per_call,derived")
    failures = []
    for label, fn in suites:
        t0 = time.perf_counter()
        try:
            for r in fn():
                print(r)
        except Exception as e:  # keep the harness going; record the failure
            failures.append((label, repr(e)))
            print(f"{label},nan,ERROR={e!r}")
        sys.stderr.write(f"[bench] {label} done in {time.perf_counter()-t0:.1f}s\n")
    if failures:
        sys.stderr.write(f"[bench] FAILURES: {failures}\n")
        sys.exit(1)


if __name__ == "__main__":
    main()
