"""Use-case 3 — flow-based payload transformer (paper: 35.7 kflow/s at 96.3%
AryPE efficiency with collaborative block-aggregation offload)."""
from __future__ import annotations

import jax

from benchmarks.common import row, time_fn
from repro.core.collaborative import OctopusCycleModel, usecase3_plan
from repro.models import paper_models


def run(flows: int = 1000) -> list[str]:
    rows = []
    m = OctopusCycleModel()
    rep = m.stack_report(usecase3_plan(flows), collaborative=True)
    rows.append(row(
        "usecase3_cycle_model", rep["time_s"] * 1e6,
        f"arype_eff={rep['arype_eff']:.3f};paper_eff=0.963;"
        f"kflow_s={flows/rep['time_s']/1e3:.1f};paper_kflow_s=35.7"))

    params = paper_models.init_paper_model("transformer", jax.random.PRNGKey(0))
    x = jax.random.uniform(jax.random.PRNGKey(1),
                           (flows, paper_models.TF_PKTS, paper_models.TF_BYTES))
    fn = jax.jit(lambda p, xx: paper_models.transformer_apply(p, xx))
    t = time_fn(fn, params, x)
    rows.append(row("usecase3_jax", t * 1e6, f"kflow_s={flows/t/1e3:.1f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
