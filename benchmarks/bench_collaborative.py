"""Paper Table 6 analog — heterogeneous collaborative computing ablation on
the use-case 2 CNN (f tracked flows).

Three views:
  (1) FPGA cycle model (first principles, paper's hardware parameters):
      AryPE efficiency with/without collaborating + throughput speedup
      (paper: 48.2% -> 81.1%, 53 -> 90 kflow/s, 1.69x).
  (2) Measured JAX/XLA: routed execution (small layers -> VPE path, fused
      aggregation) vs 'straightforwardly inserted accelerator' (everything on
      the dot path, K-block partials materialized through HBM).
  (3) Pallas engine kernels in interpret mode (correctness proof only; wall
      times are not meaningful in interpret mode).
"""
from __future__ import annotations

import jax

from benchmarks.common import row, time_fn
from repro.core.collaborative import OctopusCycleModel, usecase2_plan
from repro.models import paper_models
from repro.runtime import RuntimeConfig


def run(flows: int = 1000) -> list[str]:
    rows = []
    m = OctopusCycleModel()
    plan = usecase2_plan(flows)  # one placement, shared by model + execution
    off = m.stack_report(plan, collaborative=False)
    on = m.stack_report(plan, collaborative=True)
    speedup = off["time_s"] / on["time_s"]
    rows.append(row(
        "collab_cycle_model_wo", off["time_s"] * 1e6,
        f"arype_eff={off['arype_eff']:.3f};paper_eff=0.482;kflow_s={flows/off['time_s']/1e3:.1f}"))
    rows.append(row(
        "collab_cycle_model_w", on["time_s"] * 1e6,
        f"arype_eff={on['arype_eff']:.3f};paper_eff=0.811;vpe_eff={on['vpe_eff']:.3f};"
        f"kflow_s={flows/on['time_s']/1e3:.1f}"))
    rows.append(row("collab_cycle_model_speedup", 0.0,
                    f"speedup={speedup:.2f}x;paper=1.69x"))

    params = paper_models.init_paper_model("cnn", jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (flows, paper_models.CNN_SEQ))
    variants = {
        # all on the dot path, fused aggregation
        "fused": RuntimeConfig(policy="arype_only"),
        # 'straightforwardly inserted': block partials round-trip through memory
        "unfused": RuntimeConfig(policy="arype_only", fused_aggregation=False),
        # Octopus placement
        "routed_fused": RuntimeConfig(policy="collaborative"),
    }
    times = {}
    for name, cfg in variants.items():
        fn = jax.jit(lambda p, xx, cfg=cfg: paper_models.cnn_apply(p, xx, config=cfg))
        times[name] = time_fn(fn, params, x)
        rows.append(row(f"collab_jax_{name}", times[name] * 1e6,
                        f"kflow_s={flows/times[name]/1e3:.1f}"))
    # The fusion ablation is the hardware-transferable part of Table 6 (the
    # CPU host prefers dots over the VPU-style mul+reduce, so the routing
    # ablation only shows its effect on the TPU target / cycle model).
    rows.append(row(
        "collab_jax_fusion_speedup", 0.0,
        f"unfused_over_fused={times['unfused']/times['fused']:.2f}x;paper=1.69x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
