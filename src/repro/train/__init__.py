from repro.train.steps import make_train_step
from repro.train.loop import Trainer, TrainLoopConfig
