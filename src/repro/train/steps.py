"""Jit-compiled train/eval steps: value_and_grad + clip + optimizer update,
with optional microbatched gradient accumulation and int8 gradient compression.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.transformer import loss_fn
from repro.optim import Optimizer, clip_by_global_norm


def make_train_step(
    cfg: ArchConfig,
    optimizer: Optimizer,
    *,
    grad_clip: float = 1.0,
    accum_steps: int = 1,
    compress_grads: bool = False,
) -> Callable:
    """Returns train_step(params, opt_state, step, batch) -> (params, opt_state,
    metrics).  With accum_steps > 1, the batch's leading dim is split into
    microbatches scanned sequentially (activation memory / pipeline overlap
    trade-off)."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, batch
        )
        return grads, metrics

    def train_step(params, opt_state, step, batch):
        if accum_steps == 1:
            grads, metrics = grads_of(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % accum_steps == 0, (b, accum_steps)
                return x.reshape(accum_steps, b // accum_steps, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                g_acc, m_acc = carry
                g, m = grads_of(params, mb)
                g_acc = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32), g_acc, g)
                m_acc = jax.tree.map(lambda a, b_: a + b_, m_acc, m)
                return (g_acc, m_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = {"ce": 0.0, "aux": 0.0, "loss": 0.0}
            m0 = jax.tree.map(jnp.float32, m0)
            (grads, metrics), _ = lax.scan(acc_body, (g0, m0), micro)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            metrics = jax.tree.map(lambda m: m / accum_steps, metrics)

        if compress_grads:
            from repro.distributed.compression import compress_tree, decompress_tree

            grads = decompress_tree(compress_tree(grads))

        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        new_params, new_opt = optimizer.update(grads, opt_state, params, step)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(cfg: ArchConfig) -> Callable:
    def eval_step(params, batch):
        loss, metrics = loss_fn(params, cfg, batch)
        return metrics

    return eval_step
