"""Fault-tolerant training loop: checkpoint/restart, bit-exact resume,
straggler watchdog, elastic remesh-on-restore.

Failure model exercised in tests:
  * hard crash mid-run (simulated via fail_at_step) -> restart resumes from
    the latest atomic checkpoint with an identical loss trajectory;
  * elastic restart: restore under a different device count/mesh (shardings
    recomputed; checkpoint format is sharding-agnostic);
  * straggler detection: per-step wall time is tracked against a rolling
    median; steps slower than ``straggler_factor``x median are counted and
    surfaced in metrics (at pod scale this signal feeds the scheduler that
    re-shards data away from slow hosts — the single-host container validates
    the detection mechanism).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ArchConfig
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.models.transformer import LM
from repro.optim import cosine_schedule, make_optimizer
from repro.train.steps import make_train_step


@dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 25
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    lr: float = 3e-4
    warmup_steps: int = 10
    grad_clip: float = 1.0
    accum_steps: int = 1
    log_every: int = 10
    straggler_factor: float = 3.0
    fail_at_step: Optional[int] = None  # fault-injection for tests
    async_checkpoints: bool = True


class Trainer:
    def __init__(
        self,
        cfg: ArchConfig,
        loop: TrainLoopConfig,
        data: TokenPipelineConfig,
        *,
        shardings: Optional[Any] = None,
        mesh=None,
    ):
        self.cfg = cfg
        self.loop = loop
        self.model = LM(cfg)
        self.pipeline = TokenPipeline(data)
        lr = cosine_schedule(loop.lr, loop.warmup_steps, loop.total_steps)
        self.optimizer = make_optimizer(cfg.optimizer, lr)
        self.ckpt = CheckpointManager(
            loop.checkpoint_dir, keep=loop.keep_checkpoints,
            async_writes=loop.async_checkpoints,
        )
        self.mesh = mesh
        self.shardings = shardings
        step_fn = make_train_step(cfg, self.optimizer, grad_clip=loop.grad_clip,
                                  accum_steps=loop.accum_steps)
        self._jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
        self.step_times: list[float] = []
        self.straggler_steps = 0

    # ------------------------------------------------------------------ state
    def init_state(self, seed: int = 0):
        params = self.model.init(jax.random.PRNGKey(seed))
        opt_state = self.optimizer.init(params)
        return params, opt_state, 0

    def restore_or_init(self, seed: int = 0):
        if self.ckpt.latest_step() is not None:
            params, opt_state, _ = self.init_state(seed)
            state = {"params": params, "opt": opt_state}
            restored, extra, step = self.ckpt.restore(state)
            return restored["params"], restored["opt"], int(extra["next_step"])
        return self.init_state(seed)

    # ------------------------------------------------------------------- run
    def run(self, *, seed: int = 0) -> dict:
        params, opt_state, start_step = self.restore_or_init(seed)
        history = []
        t_med = None
        for step in range(start_step, self.loop.total_steps):
            if self.loop.fail_at_step is not None and step == self.loop.fail_at_step:
                self.ckpt.wait()
                raise RuntimeError(f"injected failure at step {step}")
            batch = self.pipeline.batch(step)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            t0 = time.perf_counter()
            params, opt_state, metrics = self._jit_step(
                params, opt_state, jnp.asarray(step, jnp.int32), batch
            )
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            self.step_times.append(dt)
            if len(self.step_times) >= 5:
                t_med = float(np.median(self.step_times[-50:]))
                if dt > self.loop.straggler_factor * t_med:
                    self.straggler_steps += 1
            history.append(loss)
            if (step + 1) % self.loop.checkpoint_every == 0 or step + 1 == self.loop.total_steps:
                self.ckpt.save(
                    {"params": params, "opt": opt_state}, step + 1,
                    extra={"next_step": step + 1,
                           "data_state": self.pipeline.state(step + 1)},
                )
            if (step + 1) % self.loop.log_every == 0:
                print(f"step {step+1:5d} loss {loss:.4f} "
                      f"({dt*1e3:.1f} ms, stragglers {self.straggler_steps})")
        self.ckpt.wait()
        return {
            "final_loss": history[-1] if history else float("nan"),
            "history": history,
            "straggler_steps": self.straggler_steps,
            "median_step_time_s": t_med or (np.median(self.step_times) if self.step_times else None),
        }
