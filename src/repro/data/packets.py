"""Synthetic packet-trace generator for the in-network use-cases.

Generates interleaved flows with class-dependent statistics (packet sizes,
inter-arrival times, directions, flags, payload bytes), so the three use-case
models have learnable structure.  Deterministic in (seed,) — every host can
regenerate any trace slice, which is also the loss-recovery story for the
packet pipeline at scale.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.flow_tracker import PacketBatch

import jax.numpy as jnp


@dataclass(frozen=True)
class PacketTraceConfig:
    num_flows: int = 256
    pkts_per_flow: int = 20
    num_classes: int = 8
    pay_bytes: int = 16
    seed: int = 0
    malicious_fraction: float = 0.25
    collision_free: bool = True  # tuple hashes chosen to avoid table collisions
    table_size: int = 8192


def synth_packet_trace(cfg: PacketTraceConfig) -> tuple[PacketBatch, np.ndarray, np.ndarray]:
    """Returns (packets interleaved in arrival order, flow_class (num_flows,),
    flow_tuple_hash (num_flows,)).

    Class statistics: class c flows draw packet sizes ~ N(200+80c, 40) and
    inter-arrival ~ Exp(50*(c+1)) us; 'malicious' flows (class 0 w.p.
    malicious_fraction) additionally use small, fast packets — this makes
    use-case 1's binary task and use-cases 2/3's class task learnable.
    """
    rng = np.random.default_rng(cfg.seed)
    F, N = cfg.num_flows, cfg.pkts_per_flow
    classes = rng.integers(0, cfg.num_classes, F)
    malicious = rng.random(F) < cfg.malicious_fraction

    if cfg.collision_free:
        # pick tuple hashes whose table slots are distinct
        from repro.core.flow_tracker import hash_slot

        hashes = []
        used = set()
        cand = rng.integers(1, 2**31 - 1, F * 8)
        for h in cand:
            s = int(hash_slot(jnp.asarray([h], jnp.int32), cfg.table_size)[0])
            if s not in used:
                used.add(s)
                hashes.append(h)
            if len(hashes) == F:
                break
        tuple_hash = np.asarray(hashes, np.int32)
    else:
        tuple_hash = rng.integers(1, 2**31 - 1, F).astype(np.int32)

    sizes = np.zeros((F, N), np.int32)
    intvs = np.zeros((F, N), np.int32)
    for f in range(F):
        c = classes[f]
        mu_s, mu_t = 200 + 80 * c, 50 * (c + 1)
        if malicious[f]:
            mu_s, mu_t = 64, 5
        sizes[f] = np.clip(rng.normal(mu_s, 40, N), 40, 1500).astype(np.int32)
        intvs[f] = np.clip(rng.exponential(mu_t, N), 1, 10**6).astype(np.int32)

    starts = rng.integers(0, 10**6, F)
    ts = starts[:, None] + np.cumsum(intvs, axis=1)
    dirs = (rng.random((F, N)) < 0.5).astype(np.int32)
    flags = rng.integers(0, 64, (F, N)).astype(np.int32)
    protos = np.repeat(rng.integers(0, 3, F)[:, None], N, axis=1).astype(np.int32)
    payload = rng.integers(0, 256, (F, N, cfg.pay_bytes)).astype(np.int32)
    # class signature in the payload so use-case 3 is learnable
    payload[..., 0] = (classes[:, None] * 13 + 7) % 256
    payload[..., 1] = np.where(malicious[:, None], 251, payload[..., 1])

    flat_ts = ts.reshape(-1)
    order = np.argsort(flat_ts, kind="stable")  # interleave flows by arrival

    def take(a):
        return jnp.asarray(a.reshape(F * N, *a.shape[2:])[order])

    packets = PacketBatch(
        ts=take(ts).astype(jnp.int32),
        size=take(sizes),
        dir=take(dirs),
        flags=take(flags),
        proto=take(protos),
        tuple_hash=take(np.repeat(tuple_hash[:, None], N, axis=1)),
        payload=take(payload),
    )
    labels = np.where(malicious, 0, 1)  # binary: malicious=0
    return packets, classes.astype(np.int32), tuple_hash, labels.astype(np.int32)
