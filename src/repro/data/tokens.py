"""Deterministic LM token pipeline with checkpointable state.

Synthetic but *learnable* streams: a per-document Markov chain over the vocab
(low-entropy transitions) so a small LM's loss decreases measurably within a
few hundred steps on CPU.

Determinism contract (fault tolerance / straggler recovery):
  batch(step, host_shard) is a pure function of (seed, step, shard) — any
  worker can recompute any other worker's batch, restarts resume bit-exact
  from the step recorded in the checkpoint, and elastic restarts with a
  different shard count re-partition the same stream.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass(frozen=True)
class TokenPipelineConfig:
    vocab_size: int = 256
    seq_len: int = 128
    global_batch: int = 32
    seed: int = 0
    num_shards: int = 1
    shard: int = 0
    branching: int = 4  # markov branching factor (lower = more learnable)


class TokenPipeline:
    def __init__(self, cfg: TokenPipelineConfig):
        assert cfg.global_batch % cfg.num_shards == 0
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # fixed Markov table: each token has `branching` likely successors
        self.table = rng.integers(0, cfg.vocab_size,
                                  (cfg.vocab_size, cfg.branching)).astype(np.int32)

    @property
    def local_batch(self) -> int:
        return self.cfg.global_batch // self.cfg.num_shards

    def batch(self, step: int) -> dict:
        """Pure function of (seed, step, shard)."""
        c = self.cfg
        rng = np.random.default_rng((c.seed, step, c.shard))
        b = self.local_batch
        toks = np.zeros((b, c.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, c.vocab_size, b)
        branch = rng.integers(0, c.branching, (b, c.seq_len))
        noise = rng.random((b, c.seq_len)) < 0.05
        rand_tok = rng.integers(0, c.vocab_size, (b, c.seq_len))
        for t in range(c.seq_len):
            nxt = self.table[toks[:, t], branch[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand_tok[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def state(self, step: int) -> dict:
        return {"step": step, "seed": self.cfg.seed, "num_shards": self.cfg.num_shards}

    def iterate(self, start_step: int) -> Iterator[tuple[int, dict]]:
        step = start_step
        while True:
            yield step, self.batch(step)
            step += 1
