"""Streaming synthetic traffic for the serving pipeline.

Unlike :mod:`repro.data.packets` (one finite trace, every flow delivers
exactly ``pkts_per_flow`` packets), this module models a *live* link: a fixed
population of concurrent flows with a heavy-tailed split —

  * **mice** — short flows (a few packets) that usually die below the
    tracker's top-n threshold and are recycled by collision/eviction,
  * **elephants** — long flows that cross the threshold (possibly several
    times) and drive the ready-flow emission path,

plus optional **bursts** (several back-to-back packets of one flow, the
line-rate pattern the FPGA tracker must absorb).  Completed flows are
replaced by fresh ones, so the stream never drains.

Everything is deterministic in ``seed`` — any host can regenerate any batch
sequence, which is also the loss-recovery story at scale.  Batches come out
as fixed-size :class:`PacketBatch` microbatches (static shapes, jit-friendly).
The clock is int32 microseconds (the tracker's ts width); a run that would
overflow it raises instead of wrapping into negative inter-arrival times.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, NamedTuple, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.flow_tracker import PacketBatch, hash_slot_scalar

_TS_MAX = 2**31 - 1  # PacketBatch.ts is int32 microseconds


# ---------------------------------------------------------------------------
# Hash partitioning (multi-lane serving)
# ---------------------------------------------------------------------------

def shard_of(tuple_hash, num_shards: int):
    """Lane assignment: ``tuple_hash % num_shards`` through uint32, so a
    flow's packets always land in the same shard (no cross-shard flow state)
    and host/device agree on negative int32 hashes.  Works on jnp arrays,
    numpy arrays and python ints alike."""
    if isinstance(tuple_hash, (int, np.integer)):
        return int((int(tuple_hash) & 0xFFFFFFFF) % num_shards)
    if isinstance(tuple_hash, np.ndarray):
        return (tuple_hash.astype(np.uint32) % np.uint32(num_shards)).astype(np.int32)
    return (tuple_hash.astype(jnp.uint32) % jnp.uint32(num_shards)).astype(jnp.int32)


class ShardedBatch(NamedTuple):
    """One dispatch round of a hash-partitioned microbatch (static shapes,
    S = num_shards, C = per-lane capacity).

    Rows with ``keep == False`` are padding (zeroed packets, ``src == P``):
    the tracker lanes drop them via the keep mask and output merges drop them
    via the out-of-range ``src`` scatter."""

    shards: PacketBatch  # (S, C) leaves — per-lane packets, arrival order
    keep: jax.Array  # (S, C) bool — row holds a real packet
    src: jax.Array  # (S, C) int32 — original batch index (P for padding)


def partition_batch(batch: PacketBatch, num_shards: int, *,
                    lane_batch: Optional[int] = None,
                    keep: Optional[np.ndarray] = None) -> list[ShardedBatch]:
    """Hash-partition one microbatch into ``num_shards`` lanes
    (``shard_of(tuple_hash)``), preserving per-lane arrival order.

    Conservation contract (property-tested): every input packet appears in
    exactly one shard of exactly one round with its keep bit set, at the lane
    ``shard_of`` names; padding rows are zeroed with ``src == P``.

    ``lane_batch`` is the per-lane capacity C.  The default (``None``) is the
    full batch size — skew-proof, always a single round.  A smaller C trades
    padding for rounds: when hash skew overfills a lane, the overflow spills
    into further :class:`ShardedBatch` rounds (each lane's FIFO is split into
    C-sized windows), and the caller dispatches the rounds in order — the
    tracker merge is sequential-composable, so the result is bit-exact to the
    single-round path.

    ``keep`` (optional bool mask over the batch) pre-drops rows before
    partitioning: rows with ``keep == False`` land in no lane of no round,
    exactly as if the batch held only the kept rows — the serving frontend's
    bucket-padded batches partition this way, so padding never hashes into
    lane 0.  The conservation contract then covers the kept rows only."""
    n = int(np.asarray(batch.ts).shape[0])
    if num_shards <= 0:
        raise ValueError(f"num_shards must be positive, got {num_shards}")
    cap = n if lane_batch is None else int(lane_batch)
    if not 0 < cap <= n:
        raise ValueError(f"lane_batch must be in [1, {n}], got {cap}")
    arrays = [np.asarray(a) for a in batch]
    shard = shard_of(np.asarray(batch.tuple_hash), num_shards)
    if keep is not None:
        mask = np.asarray(keep, bool)
        if mask.shape != (n,):
            raise ValueError(f"keep must have shape ({n},), got {mask.shape}")
        lanes = [np.flatnonzero((shard == s) & mask) for s in range(num_shards)]
    else:
        lanes = [np.flatnonzero(shard == s) for s in range(num_shards)]
    rounds = max(1, -(-max((len(ix) for ix in lanes), default=0) // cap))

    out = []
    for r in range(rounds):
        # NOT named `keep`: shadowing the parameter would silently break any
        # later read of the caller's mask (ruff PLR1704 guards this repo-wide)
        keep_rows = np.zeros((num_shards, cap), bool)
        src = np.full((num_shards, cap), n, np.int32)
        for s, ix in enumerate(lanes):
            window = ix[r * cap:(r + 1) * cap]
            keep_rows[s, : len(window)] = True
            src[s, : len(window)] = window
        take = np.minimum(src, n - 1)  # padding rows read row n-1, then zeroed

        def gather(a):
            g = a[take]
            return jnp.asarray(np.where(
                keep_rows.reshape(keep_rows.shape + (1,) * (g.ndim - 2)), g, 0))

        out.append(ShardedBatch(
            shards=PacketBatch(*(gather(a) for a in arrays)),
            keep=jnp.asarray(keep_rows), src=jnp.asarray(src)))
    return out


ADVERSARIAL_MODES = ("none", "flash_crowd", "elephant_storm",
                     "collision_attack")


@dataclass(frozen=True)
class TrafficConfig:
    batch_size: int = 32  # packets per emitted microbatch
    active_flows: int = 64  # concurrent flow population
    elephant_fraction: float = 0.125
    mice_pkts: tuple[int, int] = (2, 12)  # uniform packet-count range
    elephant_pkts: tuple[int, int] = (40, 120)
    burst_prob: float = 0.1  # chance a scheduled flow emits a burst
    burst_len: int = 4
    malicious_fraction: float = 0.2
    num_classes: int = 8
    pay_bytes: int = 16
    table_size: int = 1024
    collision_free: bool = True  # no two *live* flows share a table slot
    seed: int = 0
    client_id: int = 0  # stamped on the generator for multi-stream serving
    # --- adversarial modes (deterministic in `seed`, like everything else):
    # "flash_crowd"       every adv_period-th batch is a crowd of batch_size
    #                     fresh one-packet flows (SYN-flood shape: maximal
    #                     flow-establishment churn, nothing ever goes ready)
    # "elephant_storm"    every spawned flow is an elephant and every
    #                     scheduled emission is a maximal burst_len burst
    #                     (line-rate pressure on the ready/drain path)
    # "collision_attack"  every spawned flow hashes into one of the first
    #                     adv_slots tracker slots (worst-case eviction churn
    #                     + the segmented tracker's in-batch collision
    #                     fallback on every batch); with adv_shards > 0 the
    #                     flows additionally all land in shard 0 of an
    #                     adv_shards-lane partition, so same-slot flows share
    #                     a shard and the sharded-exactness contract holds
    #                     while lane 0 absorbs the whole attack
    adversarial: str = "none"
    adv_period: int = 4  # flash_crowd: crowd every adv_period-th batch
    adv_slots: int = 2  # collision_attack: number of targeted hot slots
    adv_shards: int = 0  # collision_attack: pin flows to shard 0 of N lanes

    def __post_init__(self):
        if self.adversarial not in ADVERSARIAL_MODES:
            raise ValueError(f"adversarial must be one of {ADVERSARIAL_MODES}, "
                             f"got {self.adversarial!r}")
        if self.adv_period <= 0:
            raise ValueError(f"adv_period must be positive, got {self.adv_period}")
        if not 0 < self.adv_slots <= self.table_size:
            raise ValueError(f"adv_slots must be in [1, table_size="
                             f"{self.table_size}], got {self.adv_slots}")
        if self.adv_shards < 0:
            raise ValueError(f"adv_shards must be >= 0, got {self.adv_shards}")
        if self.adversarial == "collision_attack" and self.collision_free:
            raise ValueError("collision_attack concentrates live flows onto "
                             "shared slots — set collision_free=False")


class _Flow:
    __slots__ = ("tuple_hash", "slot", "cls", "malicious", "elephant",
                 "remaining", "mu_size", "mu_intv", "proto", "last_dir")

    def __init__(self, tuple_hash: int, slot: int, cls: int, malicious: bool,
                 elephant: bool, remaining: int, mu_size: float,
                 mu_intv: float, proto: int):
        self.tuple_hash = tuple_hash
        self.slot = slot
        self.cls = cls
        self.malicious = malicious
        self.elephant = elephant
        self.remaining = remaining
        self.mu_size = mu_size
        self.mu_intv = mu_intv
        self.proto = proto
        self.last_dir = 0


class TrafficGenerator:
    """Seeded infinite stream of fixed-size packet microbatches.

    Iterating yields :class:`PacketBatch` forever — bound it with
    ``OctopusPipeline.run(traffic, steps=N)`` or ``batches(steps)``."""

    def __init__(self, cfg: TrafficConfig = TrafficConfig()):
        if cfg.batch_size <= 0 or cfg.active_flows <= 0:
            raise ValueError("batch_size and active_flows must be positive")
        if cfg.collision_free and cfg.active_flows > cfg.table_size:
            raise ValueError("collision_free needs active_flows <= table_size")
        if (cfg.adversarial == "flash_crowd" and cfg.collision_free
                and cfg.active_flows + cfg.batch_size > cfg.table_size):
            raise ValueError(
                "flash_crowd spawns batch_size extra live flows per crowd "
                "batch — collision_free needs active_flows + batch_size <= "
                "table_size")
        self.cfg = cfg
        self.client_id = cfg.client_id
        self.rng = np.random.default_rng(cfg.seed)
        self.clock = 0  # global microsecond clock (ts are non-decreasing)
        self.flows_started = 0
        self.flows_completed = 0
        self.batches_emitted = 0
        self._live_slots: set[int] = set()
        self._live_hashes: set[int] = set()
        self._flows = [self._spawn_flow() for _ in range(cfg.active_flows)]

    # ------------------------------------------------------------- population
    def _spawn_flow(self) -> _Flow:
        c = self.cfg
        attack = c.adversarial == "collision_attack"
        tries = 64 * max(c.table_size, 1) * (max(1, c.adv_shards) if attack
                                             else 1)
        for _ in range(tries):
            h = int(self.rng.integers(1, 2**31 - 1))
            slot = hash_slot_scalar(h, c.table_size)
            if attack:
                # concentrate the population: only hashes landing in the
                # first adv_slots hot slots qualify, and (with adv_shards)
                # only those partitioning into shard 0 — so colliding flows
                # always share a shard, preserving sharded exactness
                if slot >= c.adv_slots or (
                        c.adv_shards and shard_of(h, c.adv_shards) != 0):
                    continue
            # live tuple hashes must be unique in EVERY mode (two live flows
            # sharing a hash silently merge in the tracker while the
            # generator's flows_started / class labels count two); slot
            # uniqueness is the stricter extra constraint of collision_free
            if h not in self._live_hashes and (
                    not c.collision_free or slot not in self._live_slots):
                break
        else:  # pragma: no cover - astronomically unlikely under the guard
            raise RuntimeError("could not find a collision-free slot")
        self._live_slots.add(slot)
        self._live_hashes.add(h)

        elephant = (True if c.adversarial == "elephant_storm"
                    else self.rng.random() < c.elephant_fraction)
        lo, hi = c.elephant_pkts if elephant else c.mice_pkts
        cls = int(self.rng.integers(0, c.num_classes))
        malicious = self.rng.random() < c.malicious_fraction
        mu_size, mu_intv = 200 + 80 * cls, 50.0 * (cls + 1)
        if malicious:  # small fast packets, same signature as data.packets
            cls, mu_size, mu_intv = 0, 64, 5.0
        self.flows_started += 1
        return _Flow(h, slot, cls, malicious, elephant,
                     int(self.rng.integers(lo, hi + 1)), mu_size, mu_intv,
                     int(self.rng.integers(0, 3)))

    def _retire(self, idx: int) -> None:
        f = self._flows[idx]
        self._live_slots.discard(f.slot)
        self._live_hashes.discard(f.tuple_hash)
        self.flows_completed += 1
        self._flows[idx] = self._spawn_flow()

    # ------------------------------------------------------------------ batch
    def _tick(self, mu: float) -> int:
        """Advance the global clock by one ~exp(mu) inter-arrival and return
        it, failing loud before int32 wrap (negative inter-arrival times
        would silently corrupt min_intv/flow_dur in the tracker)."""
        self.clock += max(1, int(self.rng.exponential(mu)))
        if self.clock > _TS_MAX:
            raise RuntimeError(
                "traffic clock exceeded int32 microseconds "
                f"({_TS_MAX}); restart the generator (fresh seed) for "
                "longer soaks")
        return self.clock

    def _crowd_batch(self) -> PacketBatch:
        """One flash-crowd microbatch: ``batch_size`` fresh one-packet flows
        (unique live hashes, like every spawn), each retired immediately —
        maximal establishment/recycle churn, no flow ever reaches top-n."""
        c = self.cfg
        n = c.batch_size
        ts = np.zeros(n, np.int32)
        size = np.zeros(n, np.int32)
        dirs = np.zeros(n, np.int32)
        flags = np.zeros(n, np.int32)
        proto = np.zeros(n, np.int32)
        thash = np.zeros(n, np.int32)
        payload = np.zeros((n, c.pay_bytes), np.int32)
        for i in range(n):
            f = self._spawn_flow()
            ts[i] = self._tick(2.0)  # near-line-rate arrival spacing
            size[i] = int(np.clip(self.rng.normal(64, 8), 40, 1500))
            flags[i] = 2  # SYN-like
            proto[i] = f.proto
            thash[i] = f.tuple_hash
            payload[i] = self.rng.integers(0, 256, c.pay_bytes)
            # one packet and gone: release the live slot/hash without
            # touching the steady-state population in self._flows
            self._live_slots.discard(f.slot)
            self._live_hashes.discard(f.tuple_hash)
            self.flows_completed += 1
        return PacketBatch(
            ts=jnp.asarray(ts), size=jnp.asarray(size), dir=jnp.asarray(dirs),
            flags=jnp.asarray(flags), proto=jnp.asarray(proto),
            tuple_hash=jnp.asarray(thash), payload=jnp.asarray(payload))

    def next_batch(self) -> PacketBatch:
        c = self.cfg
        self.batches_emitted += 1
        if (c.adversarial == "flash_crowd"
                and self.batches_emitted % c.adv_period == 0):
            return self._crowd_batch()
        n = c.batch_size
        ts = np.zeros(n, np.int32)
        size = np.zeros(n, np.int32)
        dirs = np.zeros(n, np.int32)
        flags = np.zeros(n, np.int32)
        proto = np.zeros(n, np.int32)
        thash = np.zeros(n, np.int32)
        payload = np.zeros((n, c.pay_bytes), np.int32)

        i = 0
        while i < n:
            idx = int(self.rng.integers(0, len(self._flows)))
            f = self._flows[idx]
            if c.adversarial == "elephant_storm":
                burst = c.burst_len  # every emission is a maximal burst
            else:
                burst = 1
                if self.rng.random() < c.burst_prob:
                    burst = int(self.rng.integers(2, c.burst_len + 1))
            for _ in range(min(burst, f.remaining, n - i)):
                ts[i] = self._tick(f.mu_intv)
                size[i] = int(np.clip(self.rng.normal(f.mu_size, 40), 40, 1500))
                f.last_dir ^= int(self.rng.random() < 0.4)  # occasional turn
                dirs[i] = f.last_dir
                flags[i] = int(self.rng.integers(0, 64))
                proto[i] = f.proto
                thash[i] = f.tuple_hash
                row = self.rng.integers(0, 256, c.pay_bytes)
                row[0] = (f.cls * 13 + 7) % 256  # class signature byte
                if f.malicious:
                    row[1] = 251
                payload[i] = row
                f.remaining -= 1
                i += 1
            if f.remaining == 0:
                self._retire(idx)

        return PacketBatch(
            ts=jnp.asarray(ts), size=jnp.asarray(size), dir=jnp.asarray(dirs),
            flags=jnp.asarray(flags), proto=jnp.asarray(proto),
            tuple_hash=jnp.asarray(thash), payload=jnp.asarray(payload))

    def batches(self, steps: Optional[int] = None) -> Iterator[PacketBatch]:
        """Yield ``steps`` microbatches (forever when ``steps`` is None)."""
        produced = 0
        while steps is None or produced < steps:
            yield self.next_batch()
            produced += 1

    def __iter__(self) -> Iterator[PacketBatch]:
        return self.batches(None)


def merge_streams(*gens: TrafficGenerator, seed: int = 0,
                  steps: Optional[int] = None,
                  tagged: bool = False) -> Iterator:
    """Deterministically interleave N seeded generators into one stream.

    Each yielded microbatch is pulled whole from one generator, chosen by a
    dedicated ``seed``-keyed RNG — so the interleave order is stable across
    runs (same seed + same generator configs => the same stream, batch for
    batch), independent of each generator's own seed.  Conservation
    (property-tested): every batch a generator produces appears exactly once
    in the merged stream, in that generator's own order — the merge reorders
    *across* clients, never within one.

    ``tagged=True`` yields ``(client_id, PacketBatch)`` pairs (the serving
    harness needs the attribution); the default yields bare batches so the
    merged stream can drive ``OctopusPipeline.run`` directly.  ``steps``
    bounds the total batch count (the generators are infinite)."""
    if not gens:
        raise ValueError("merge_streams needs at least one generator")
    rng = np.random.default_rng(seed)
    produced = 0
    while steps is None or produced < steps:
        g = gens[int(rng.integers(0, len(gens)))]
        batch = g.next_batch()
        yield (g.client_id, batch) if tagged else batch
        produced += 1


def prefetch(iterable, depth: int = 2) -> Iterator:
    """Pull ``iterable`` on a background thread, staying up to ``depth``
    items ahead of the consumer (bounded queue — the producer blocks when
    the consumer falls behind, so memory stays O(depth)).

    Order-preserving: the consumer sees exactly the source sequence, so a
    prefetched pipeline run stays bit-identical.  Exception-transparent: a
    producer error is re-raised at the consumer's next pull.  Use it with
    the overlapped pipeline to move batch *generation* off the dispatch
    thread as well::

        pipe.run(prefetch(gen.batches(steps), depth=2), steps=steps)

    The producer runs ahead by up to ``depth`` batches, so only wrap
    bounded iterators you own: wrapping a generator shared with other
    consumers would pull batches this consumer never sees.  The thread is a
    daemon and starts at the first ``next()``, so an unconsumed prefetch
    costs nothing and an abandoned one never blocks interpreter exit."""
    import queue
    import threading

    if depth <= 0:
        raise ValueError(f"depth must be positive, got {depth}")
    q: queue.Queue = queue.Queue(maxsize=depth)
    _end = object()  # sentinel: (end, exception-or-None) terminates the pull

    def produce() -> None:
        try:
            for item in iterable:
                q.put(item)
        except BaseException as e:  # noqa: BLE001 — forwarded to the consumer
            q.put((_end, e))
            return
        q.put((_end, None))

    threading.Thread(target=produce, name="traffic-prefetch",
                     daemon=True).start()
    while True:
        item = q.get()
        if isinstance(item, tuple) and len(item) == 2 and item[0] is _end:
            if item[1] is not None:
                raise item[1]
            return
        yield item
