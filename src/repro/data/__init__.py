from repro.data.packets import PacketTraceConfig, synth_packet_trace
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.data.traffic import TrafficConfig, TrafficGenerator, prefetch
