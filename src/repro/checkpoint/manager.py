"""Checkpoint/restore for fault tolerance and elastic scaling.

Design (orbax-lite, no external deps):
  * a checkpoint is a directory ``step_<N>/`` holding one ``.npy`` file per
    pytree leaf (path-encoded filenames) + ``manifest.json`` (treedef, dtypes,
    shapes, step, extra metadata such as data-pipeline state);
  * writes go to ``step_<N>.tmp`` then ``os.rename`` -> atomic: a crash mid-
    write never corrupts the latest checkpoint (restart-safety);
  * an async writer thread moves device arrays to host and serializes off the
    training path; ``wait()`` joins before the next save (bounded queue = 1);
  * restore is *sharding-agnostic*: leaves are loaded to host and
    ``jax.device_put`` onto whatever shardings the (possibly different-sized)
    restart mesh prescribes — this is the elastic-scaling path;
  * retention keeps the newest ``keep`` checkpoints (quorum note: on a real
    multi-host cluster each host writes its own shard set and the manifest
    carries a host count; restore requires a complete quorum — the single-host
    container exercises the same code path with host count 1).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> tuple[list[tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]
    return items, treedef


def _safe_name(key: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]", "_", key)


_NATIVE = {"float64", "float32", "float16", "int64", "int32", "int16", "int8",
           "uint64", "uint32", "uint16", "uint8", "bool"}


def _encode(arr: np.ndarray) -> np.ndarray:
    """bf16/fp8 etc. are not numpy-native: store raw bytes (dtype in manifest)."""
    if arr.dtype.name in _NATIVE:
        return arr
    return np.frombuffer(arr.tobytes(), np.uint8).reshape(arr.shape + (arr.dtype.itemsize,))


def _decode(arr: np.ndarray, dtype_name: str, shape: tuple) -> np.ndarray:
    if dtype_name in _NATIVE:
        return arr
    dt = jnp.dtype(dtype_name)
    return np.frombuffer(arr.tobytes(), dt).reshape(shape)


def save_pytree(tree: Any, directory: str, *, step: int, extra: Optional[dict] = None) -> str:
    """Synchronous atomic save.  Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    items, _ = _flatten(tree)
    manifest = {"step": step, "leaves": [], "extra": extra or {}, "hosts": 1}
    for key, leaf in items:
        arr = np.asarray(jax.device_get(leaf))
        fname = _safe_name(key) + ".npy"
        np.save(os.path.join(tmp, fname), _encode(arr))
        manifest["leaves"].append(
            {"key": key, "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def load_pytree(
    path: str,
    like: Any,
    *,
    shardings: Optional[Any] = None,
) -> tuple[Any, dict]:
    """Restore into the structure of ``like``; device_put onto ``shardings``
    (tree matching ``like``) if given — the mesh may differ from save time."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {l["key"]: l for l in manifest["leaves"]}
    items, treedef = _flatten(like)
    shard_leaves = None
    if shardings is not None:
        shard_leaves = jax.tree.flatten(shardings)[0]
    out = []
    for i, (key, leaf) in enumerate(items):
        meta = by_key.get(key)
        if meta is None:
            raise KeyError(f"checkpoint at {path} is missing leaf {key}")
        arr = np.load(os.path.join(path, meta["file"]))
        arr = _decode(arr, meta["dtype"], tuple(meta["shape"]))
        want_dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
        if str(arr.dtype) != str(want_dtype):
            arr = arr.astype(want_dtype)
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out), manifest["extra"]


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, async_writes: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_writes = async_writes
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # -- discovery -----------------------------------------------------------
    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def path_for(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    # -- save ----------------------------------------------------------------
    def save(self, tree: Any, step: int, extra: Optional[dict] = None):
        self.wait()
        # snapshot to host NOW so training can mutate donated buffers
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_pytree(host_tree, self.directory, step=step, extra=extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if self.async_writes:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            self._raise_if_failed()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.path_for(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def restore(self, like: Any, *, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> tuple[Any, dict, int]:
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        tree, extra = load_pytree(self.path_for(step), like, shardings=shardings)
        return tree, extra, step
