"""Second-level (cold) flow table: the spill/promote half of the two-level
tracker (ROADMAP: hierarchical flow table — 10^5-10^6 flows, not 8k).

The hot level stays the per-lane :class:`~repro.core.flow_tracker.TrackerState`
bank, bit-identical to the single-level tracker (with ``cold_size == 0`` the
pipeline never touches this module).  This module adds a large
:class:`ColdState` table that collision evictions spill *into* (instead of
silently dropping the stale flow) and re-establishment promotes *from*:

  * **2-choice hashing** — every tuple hash owns two cold candidate slots
    (:func:`cold_slots`, two independent multiplicative mixers); an insert
    prefers a slot already holding the tuple (overwrite, never duplicate),
    then an empty slot (first candidate wins ties), and only then evicts the
    candidate with the smaller policy stamp.
  * **pluggable eviction policy** — ``"age"`` stamps entries with the
    spilled flow's ``last_ts`` (the longest-idle flow loses), ``"lru"`` with
    a monotonic insert tick (the least-recently-spilled flow loses).

Per-microbatch step semantics, applied by the serving pipelines and mirrored
one-for-one by the pure-Python oracle in ``tests/test_cold_store.py``:

  1. :func:`promote_pass` — for every batch-touched hot slot (ascending slot
     order) whose *head* packet's tuple is not live in hot but present in
     cold, the cold entry is loaded back into the hot slot before the merge
     (so the merge counts it as a hit and the flow's count keeps growing);
     a displaced hot occupant spills into cold first.
  2. the tracker merge runs on hot exactly as today, emitting
     :class:`~repro.core.flow_tracker.SpillRecords` for every eviction
     (``with_spills=True``; scan and segmented agree bit-exactly).
  3. :func:`apply_spills` — the records insert into cold sequentially in
     packet order (2-choice + policy).
  4. :func:`scrub_live` — any batch tuple live in hot after the merge is
     cleared from cold, so a tuple is never simultaneously live in hot and
     present in cold (a flow that re-established mid-batch after its own
     eviction leaves no stale twin behind).

The invariant from step 4 is what makes promotion sound: a cold lookup can
never resurrect an outdated copy of a flow the hot table still owns.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import flow_tracker as ft

COLD_POLICIES = ("age", "lru")


class ColdState(NamedTuple):
    """The cold table: one entry per slot, ``count == 0`` means empty.
    Leaves mirror :class:`~repro.core.flow_tracker.TrackerState` plus the
    eviction-policy ``stamp`` and the monotonic insert ``tick``."""

    tuple_id: jax.Array  # (C,) int32
    count: jax.Array  # (C,) int32 — 0 == empty
    last_ts: jax.Array  # (C,) int32
    features: jax.Array  # (C, 16) int32
    series: jax.Array  # (C, top_n) int32
    sizes: jax.Array  # (C, top_n) int32
    payload: jax.Array  # (C, top_k, pay_bytes) int32
    stamp: jax.Array  # (C,) int32 — eviction key (policy-defined)
    tick: jax.Array  # () int32 — total inserts so far (the lru clock)


class TwoLevelState(NamedTuple):
    """The hierarchical tracker state the pipelines carry when
    ``cold_size > 0``: the hot bank plus its cold spill table."""

    hot: ft.TrackerState
    cold: ColdState


def init_cold(cold_size: int, top_n: int, top_k: int,
              pay_bytes: int) -> ColdState:
    return ColdState(
        tuple_id=jnp.zeros((cold_size,), jnp.int32),
        count=jnp.zeros((cold_size,), jnp.int32),
        last_ts=jnp.zeros((cold_size,), jnp.int32),
        features=jnp.zeros((cold_size, 16), jnp.int32),
        series=jnp.zeros((cold_size, top_n), jnp.int32),
        sizes=jnp.zeros((cold_size, top_n), jnp.int32),
        payload=jnp.zeros((cold_size, top_k, pay_bytes), jnp.int32),
        stamp=jnp.zeros((cold_size,), jnp.int32),
        tick=jnp.int32(0),
    )


def init_two_level(table_size: int, cold_size: int, top_n: int, top_k: int,
                   pay_bytes: int) -> TwoLevelState:
    return TwoLevelState(
        hot=ft.init_state(table_size, top_n, top_k, pay_bytes),
        cold=init_cold(cold_size, top_n, top_k, pay_bytes))


def cold_slots(tuple_hash: jax.Array, cold_size: int) -> tuple[jax.Array,
                                                               jax.Array]:
    """The tuple's two cold candidate slots (2-choice hashing).  Two
    independent multiplicative mixers (murmur3 finalizer constants), both
    distinct from the hot table's :func:`~repro.core.flow_tracker.hash_slot`
    mixer so hot collisions don't correlate with cold collisions."""
    h = tuple_hash.astype(jnp.uint32)
    a = h * jnp.uint32(0x85EBCA6B)
    a = a ^ (a >> 13)
    b = h * jnp.uint32(0xC2B2AE35)
    b = b ^ (b >> 16)
    return ((a % jnp.uint32(cold_size)).astype(jnp.int32),
            (b % jnp.uint32(cold_size)).astype(jnp.int32))


def cold_slots_scalar(tuple_hash: int, cold_size: int) -> tuple[int, int]:
    """:func:`cold_slots` for one host-side int — the oracle's mirror.  Must
    stay bit-identical to the array version (tested)."""
    a = ((tuple_hash & 0xFFFFFFFF) * 0x85EBCA6B) & 0xFFFFFFFF
    a ^= a >> 13
    b = ((tuple_hash & 0xFFFFFFFF) * 0xC2B2AE35) & 0xFFFFFFFF
    b ^= b >> 16
    return int(a % cold_size), int(b % cold_size)


def _check_policy(policy: str) -> None:
    if policy not in COLD_POLICIES:
        raise ValueError(f"policy must be one of {COLD_POLICIES}, "
                         f"got {policy!r}")


def _choose_slot(cold: ColdState, h: jax.Array) -> jax.Array:
    """Insert destination for tuple ``h``: its own entry if present (never
    duplicate), else the first empty candidate, else the candidate with the
    smaller stamp (tie prefers candidate 1)."""
    a, b = cold_slots(h, cold.tuple_id.shape[0])
    occ_a = cold.count[a] > 0
    occ_b = cold.count[b] > 0
    match_a = occ_a & (cold.tuple_id[a] == h)
    match_b = occ_b & (cold.tuple_id[b] == h)
    victim = jnp.where(cold.stamp[a] <= cold.stamp[b], a, b)
    return jnp.where(match_a, a,
                     jnp.where(match_b, b,
                               jnp.where(~occ_a, a,
                                         jnp.where(~occ_b, b, victim))))


def _insert_one(cold: ColdState, tid, cnt, ts, feats, ser, siz, pay,
                do: jax.Array, policy: str) -> ColdState:
    """Insert one flow record (scalar leaves) when ``do``; a False ``do``
    scatters to the out-of-range sentinel and is a complete no-op."""
    C = cold.tuple_id.shape[0]
    tgt = jnp.where(do, _choose_slot(cold, tid), C)
    stamp = ts if policy == "age" else cold.tick
    return cold._replace(
        tuple_id=cold.tuple_id.at[tgt].set(tid, mode="drop"),
        count=cold.count.at[tgt].set(cnt, mode="drop"),
        last_ts=cold.last_ts.at[tgt].set(ts, mode="drop"),
        features=cold.features.at[tgt].set(feats, mode="drop"),
        series=cold.series.at[tgt].set(ser, mode="drop"),
        sizes=cold.sizes.at[tgt].set(siz, mode="drop"),
        payload=cold.payload.at[tgt].set(pay, mode="drop"),
        stamp=cold.stamp.at[tgt].set(stamp, mode="drop"),
        tick=cold.tick + do.astype(jnp.int32),
    )


def promote_pass(hot: ft.TrackerState, cold: ColdState,
                 packets: ft.PacketBatch,
                 keep: Optional[jax.Array] = None, *,
                 policy: str) -> tuple[ft.TrackerState, ColdState, jax.Array]:
    """Step 1 of the two-level step: walk the batch's segment heads in
    ascending hot-slot order; where the head tuple is not live in hot but
    present in cold, load the cold entry into the hot slot (spilling a
    displaced occupant into cold first) and free the cold source.  Returns
    ``(hot, cold, promoted_count)``.

    Runs *before* the merge, so the merge sees the promoted flow as a hit
    and its packet count keeps growing where the single-level tracker would
    have restarted from zero.  Only the segment head consults cold: a second
    tuple colliding onto the same slot mid-batch establishes fresh exactly
    as today (its stale cold twin, if any, is scrubbed after the merge).

    Implementation note — the sequential walk only carries the *small* (C,)
    bookkeeping leaves (tuple_id / count / last_ts / stamp / tick), where
    every 2-choice decision lives; the wide leaves (features / series /
    sizes / payload) are moved afterwards with vectorized scatters.  (A loop
    that both gathers and scatters the wide cold leaves per iteration makes
    XLA copy the whole cold bank each step — ~seconds at 10^5+ slots.)
    The split is exact, not an approximation, because within one pass:
      * segment heads own *distinct* hot slots, so hot reads/writes never
        interleave across iterations;
      * a promoted source slot always still holds its pre-pass record (a
        displaced occupant's tuple hashes to an *earlier* head's hot slot,
        so it can never be a later head's promotion source);
      * when two displaced occupants land on the same cold slot the later
        insert wins — resolved below with a last-writer mask.
    The oracle differential in tests/test_cold_store.py pins all of this."""
    _check_policy(policy)
    F = hot.tuple_id.shape[0]
    C = cold.tuple_id.shape[0]
    P = packets.ts.shape[0]
    slots = ft.hash_slot(packets.tuple_hash, F)
    if keep is not None:
        slots = jnp.where(keep, slots, F)
    order = jnp.argsort(slots, stable=True)
    s_slot = slots[order]
    s_hash = packets.tuple_hash[order]
    first = jnp.concatenate([jnp.ones((1,), bool), s_slot[1:] != s_slot[:-1]])

    def body(carry, i):
        c_tid, c_cnt, c_ts, c_stamp, tick = carry
        f = s_slot[i]
        h = s_hash[i]
        fs = jnp.where(f < F, f, 0)
        head = first[i] & (f < F)
        # hot is read-only here: heads own distinct slots, so no iteration
        # observes another's hot write — hot updates all land in phase 2
        hit = (hot.count[fs] > 0) & (hot.tuple_id[fs] == h)
        a, b = cold_slots(h, C)
        in_a = (c_cnt[a] > 0) & (c_tid[a] == h)
        in_b = (c_cnt[b] > 0) & (c_tid[b] == h)
        promo = head & ~hit & (in_a | in_b)
        src = jnp.where(in_a, a, b)
        disp = promo & (hot.count[fs] > 0)
        occupant = (hot.tuple_id[fs], hot.count[fs], hot.last_ts[fs])

        # free the source, then 2-choice-insert the displaced occupant (its
        # probe legitimately sees — and may reuse — the just-freed slot).
        # All gathers probe the PRE-clear state and adjust for the freed
        # slot analytically (ox == csrc means empty), so each buffer sees
        # one gather phase then one scatter phase per iteration — the shape
        # XLA keeps in place; interleaving gathers between the clear and
        # insert scatters makes it copy the (C,) leaves every iteration.
        csrc = jnp.where(promo, src, C)
        oa, ob = cold_slots(occupant[0], C)
        occ_a = (c_cnt[oa] > 0) & (oa != csrc)
        occ_b = (c_cnt[ob] > 0) & (ob != csrc)
        match_a = occ_a & (c_tid[oa] == occupant[0])
        match_b = occ_b & (c_tid[ob] == occupant[0])
        victim = jnp.where(c_stamp[oa] <= c_stamp[ob], oa, ob)
        choose = jnp.where(match_a, oa,
                           jnp.where(match_b, ob,
                                     jnp.where(~occ_a, oa,
                                               jnp.where(~occ_b, ob, victim))))
        dst = jnp.where(disp, choose, C)
        stamp = occupant[2] if policy == "age" else tick
        c_tid = c_tid.at[csrc].set(0, mode="drop").at[dst].set(
            occupant[0], mode="drop")
        c_cnt = c_cnt.at[csrc].set(0, mode="drop").at[dst].set(
            occupant[1], mode="drop")
        c_ts = c_ts.at[dst].set(occupant[2], mode="drop")
        c_stamp = c_stamp.at[csrc].set(0, mode="drop").at[dst].set(
            stamp, mode="drop")
        tick = tick + disp.astype(jnp.int32)
        return ((c_tid, c_cnt, c_ts, c_stamp, tick), (promo, src, fs, dst))

    carry0 = (cold.tuple_id, cold.count, cold.last_ts, cold.stamp, cold.tick)
    carry, (promo, srcs, fss, dsts) = lax.scan(
        body, carry0, jnp.arange(P, dtype=jnp.int32))
    c_tid, c_cnt, c_ts, c_stamp, tick = carry

    # phase 2: promoted entries hot[fs] <- pre-pass cold[src].  Gathering
    # from the pre-pass cold is exact — a promotion source still holds its
    # pre-pass record (see the implementation note above).
    tgts = jnp.where(promo, fss, F)
    srcs_safe = jnp.where(promo, srcs, 0)

    def load(hot_leaf, cold_leaf):
        return hot_leaf.at[tgts].set(cold_leaf[srcs_safe], mode="drop")

    # displaced occupants cold[dst] <- pre-pass hot[fs]; duplicate dst rows
    # resolve to the LAST writer, matching the sequential small-leaf walk
    dup_later = jnp.triu(dsts[None, :] == dsts[:, None], k=1).any(axis=1)
    dsts_w = jnp.where(dup_later, C, dsts)
    fss_safe = jnp.where(dsts_w < C, fss, 0)

    def store(cold_leaf, hot_leaf):
        return cold_leaf.at[dsts_w].set(hot_leaf[fss_safe], mode="drop")

    new_hot = hot._replace(
        tuple_id=load(hot.tuple_id, cold.tuple_id),
        count=load(hot.count, cold.count),
        last_ts=load(hot.last_ts, cold.last_ts),
        features=load(hot.features, cold.features),
        series=load(hot.series, cold.series),
        sizes=load(hot.sizes, cold.sizes),
        payload=load(hot.payload, cold.payload))
    new_cold = cold._replace(
        tuple_id=c_tid, count=c_cnt, last_ts=c_ts, stamp=c_stamp, tick=tick,
        features=store(cold.features, hot.features),
        series=store(cold.series, hot.series),
        sizes=store(cold.sizes, hot.sizes),
        payload=store(cold.payload, hot.payload))
    return new_hot, new_cold, promo.sum().astype(jnp.int32)


def apply_spills(cold: ColdState, spills: ft.SpillRecords, *,
                 policy: str) -> tuple[ColdState, jax.Array]:
    """Step 3: fold one merge's eviction records into cold, sequentially in
    packet order (later spills may evict earlier ones — exactly the scalar
    semantics the oracle mirrors).  Returns ``(cold, inserted_count)``."""
    _check_policy(policy)
    P = spills.mask.shape[0]

    def body(i, cold):
        return _insert_one(cold, spills.tuple_id[i], spills.count[i],
                           spills.last_ts[i], spills.features[i],
                           spills.series[i], spills.sizes[i],
                           spills.payload[i], spills.mask[i], policy)

    cold = lax.fori_loop(0, P, body, cold)
    return cold, spills.mask.sum().astype(jnp.int32)


def scrub_live(cold: ColdState, hot: ft.TrackerState,
               packets: ft.PacketBatch,
               keep: Optional[jax.Array] = None) -> ColdState:
    """Step 4: clear any cold entry whose tuple is live in hot after the
    merge.  Only batch tuples can have newly established, so a (P,)-wide
    vectorized check covers every possible violation of the no-twin
    invariant; clears are idempotent, so no sequencing is needed."""
    F = hot.tuple_id.shape[0]
    C = cold.tuple_id.shape[0]
    h = packets.tuple_hash
    k = jnp.ones(h.shape, bool) if keep is None else keep
    fs = ft.hash_slot(h, F)
    live = k & (hot.count[fs] > 0) & (hot.tuple_id[fs] == h)
    a, b = cold_slots(h, C)
    hit_a = live & (cold.count[a] > 0) & (cold.tuple_id[a] == h)
    hit_b = live & (cold.count[b] > 0) & (cold.tuple_id[b] == h)
    ca = jnp.where(hit_a, a, C)
    cb = jnp.where(hit_b, b, C)

    def clear(leaf):
        return leaf.at[ca].set(0, mode="drop").at[cb].set(0, mode="drop")

    return cold._replace(tuple_id=clear(cold.tuple_id),
                         count=clear(cold.count),
                         stamp=clear(cold.stamp))


def cold_occupancy(cold: ColdState) -> jax.Array:
    """() int32 — live cold entries (monitoring / tests)."""
    return (cold.count > 0).sum().astype(jnp.int32)
