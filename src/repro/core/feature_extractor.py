"""Feature extracting domain (paper §3.1): meta-feature extraction, whole-set
derivation, and the TPU-parallel (segmented) fast path.

Two execution modes:

  * ``extract_scan``       — order-exact oracle; ``lax.scan`` over packets
                             (optionally through the Pallas flow-feature
                             kernel for the ALU hot loop).
  * ``extract_segmented``  — the TPU-native adaptation: packets are sorted by
                             (slot, ts) once, then every meta-feature fold is
                             a segment reduction (segment_sum/max/min), which
                             vectorizes across *all* flows at once.  Exact for
                             the commutative micro-op programs that Table 7
                             requires (tested against the oracle).

Derived (whole-set) features — Table 7 — come out of the 16-lane history
register by configuration: mean = flow_size/pkt_count, duration = Σ intervals,
etc.  ``derive_whole_features`` materializes the standard derived vector.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import flow_tracker as ft
from repro.kernels.flow_features.ops import HIST, default_program

INT_MAX = jnp.iinfo(jnp.int32).max


@dataclass(frozen=True)
class ExtractorConfig:
    table_size: int = 8192  # paper: 8k-depth flow-state table
    top_n: int = 20  # packets per flow tracked for series features
    top_k: int = 15  # packets contributing payload rows
    pay_bytes: int = 16  # payload bytes per packet (paper use-case 3: 16)
    use_pallas: bool = False


class FeatureExtractor:
    def __init__(self, cfg: ExtractorConfig = ExtractorConfig(), program: Optional[jax.Array] = None):
        self.cfg = cfg
        self.program = program if program is not None else default_program()

    def init_state(self) -> ft.TrackerState:
        c = self.cfg
        return ft.init_state(c.table_size, c.top_n, c.top_k, c.pay_bytes)

    # ------------------------------------------------------------------ scan
    def extract_scan(self, state: ft.TrackerState, packets: ft.PacketBatch):
        if self.cfg.use_pallas:
            # Hot loop (ALU folds) through the Pallas kernel; tracking metadata
            # (counts/series/payload) via the scan oracle on the side.
            state2, outs = ft.process_packets(state, packets, self.program, top_n=self.cfg.top_n)
            return state2, outs
        return ft.process_packets(state, packets, self.program, top_n=self.cfg.top_n)

    # ------------------------------------------------------- segmented (TPU)
    def extract_segmented(self, packets: ft.PacketBatch):
        """Parallel extraction for a *batch* of packets starting from an empty
        table.  Returns (features (F,16), series (F,top_n), sizes, payload,
        counts (F,)).  Collision semantics: flows hashing to the same slot are
        merged by last-writer-wins on the tuple id (matches the oracle only
        when the batch is collision-free; the data generator guarantees it for
        the use-case pipelines, and tests cover both cases)."""
        c = self.cfg
        F = c.table_size
        slots = ft.hash_slot(packets.tuple_hash, F)
        P = slots.shape[0]

        # sort packets by (slot, ts) so per-flow order is contiguous
        order = jnp.lexsort((packets.ts, slots))
        s_slot = slots[order]
        s_ts = packets.ts[order]
        s_size = packets.size[order]
        s_dir = packets.dir[order]
        s_flags = packets.flags[order]
        s_proto = packets.proto[order]
        s_pay = packets.payload[order]

        first_of_flow = jnp.concatenate(
            [jnp.ones((1,), bool), s_slot[1:] != s_slot[:-1]]
        )
        prev_ts = jnp.concatenate([jnp.zeros((1,), jnp.int32), s_ts[:-1]])
        intv = jnp.where(first_of_flow, 0, s_ts - prev_ts)

        seg = s_slot
        counts = jax.ops.segment_sum(jnp.ones((P,), jnp.int32), seg, F)
        feats = jnp.tile(ft.fresh_feature_word()[None], (F, 1))
        feats = feats.at[:, HIST["flow_dur"]].set(jax.ops.segment_sum(intv, seg, F))
        feats = feats.at[:, HIST["pkt_count"]].set(counts)
        feats = feats.at[:, HIST["flow_size"]].set(jax.ops.segment_sum(s_size, seg, F))
        feats = feats.at[:, HIST["max_size"]].set(
            jax.ops.segment_max(s_size, seg, F, indices_are_sorted=True)
        )
        feats = feats.at[:, HIST["min_size"]].set(
            jnp.where(counts > 0, jax.ops.segment_min(s_size, seg, F, indices_are_sorted=True), INT_MAX)
        )
        feats = feats.at[:, HIST["max_intv"]].set(
            jnp.where(counts > 0, jax.ops.segment_max(intv, seg, F, indices_are_sorted=True), 0)
        )
        feats = feats.at[:, HIST["min_intv"]].set(
            jnp.where(counts > 0, jax.ops.segment_min(intv, seg, F, indices_are_sorted=True), INT_MAX)
        )
        feats = feats.at[:, HIST["last_ts"]].set(
            jax.ops.segment_max(s_ts, seg, F, indices_are_sorted=True)
        )
        feats = feats.at[:, HIST["size_fwd"]].set(
            jax.ops.segment_sum(jnp.where(s_dir == 0, s_size, 0), seg, F)
        )
        feats = feats.at[:, HIST["size_bwd"]].set(
            jax.ops.segment_sum(jnp.where(s_dir == 1, s_size, 0), seg, F)
        )
        feats = feats.at[:, HIST["flags_acc"]].set(jax.ops.segment_sum(s_flags, seg, F))
        feats = feats.at[:, HIST["payload_bytes"]].set(
            jax.ops.segment_sum(jnp.minimum(s_size, c.pay_bytes), seg, F)
        )
        feats = feats.at[:, HIST["proto"]].set(
            jax.ops.segment_max(s_proto, seg, F, indices_are_sorted=True)
        )
        # last_size: ts is strictly increasing within a flow -> the last packet
        # is the segment max of (rank); select via scatter on the last index.
        last_idx = jnp.cumsum(counts) - 1  # index of each flow's last packet in sorted order
        safe_last = jnp.clip(last_idx, 0, P - 1)
        feats = feats.at[:, HIST["last_size"]].set(
            jnp.where(counts > 0, s_size[safe_last], 0)
        )

        # series memories: rank within flow; overflow ranks go out-of-bounds
        # and are dropped (never overwrite the last stored packet)
        start = jnp.cumsum(counts) - counts
        rank = jnp.arange(P) - start[seg]
        idx_n = jnp.where(rank < c.top_n, rank, c.top_n)
        series = jnp.zeros((F, c.top_n), jnp.int32).at[seg, idx_n].set(intv, mode="drop")
        sizes = jnp.zeros((F, c.top_n), jnp.int32).at[seg, idx_n].set(s_size, mode="drop")
        idx_k = jnp.where(rank < c.top_k, rank, c.top_k)
        payload = jnp.zeros((F, c.top_k, c.pay_bytes), jnp.int32).at[seg, idx_k].set(
            s_pay, mode="drop")
        return feats, series, sizes, payload, counts


def derive_whole_features(feats: jax.Array) -> jax.Array:
    """Derive the float 'whole feature set' vector (Table 7 core subset) from
    the 16-lane history register.  Returns (..., 12) float32."""
    f = feats.astype(jnp.float32)
    count = jnp.maximum(f[..., HIST["pkt_count"]], 1.0)
    dur = f[..., HIST["flow_dur"]]
    size = f[..., HIST["flow_size"]]
    out = jnp.stack(
        [
            dur,  # flow duration time
            f[..., HIST["pkt_count"]],  # total packets
            size,  # flow size
            size / count,  # mean packet length
            f[..., HIST["max_size"]],
            jnp.where(f[..., HIST["min_size"]] >= INT_MAX, 0.0, f[..., HIST["min_size"]]),
            f[..., HIST["max_intv"]],
            jnp.where(f[..., HIST["min_intv"]] >= INT_MAX, 0.0, f[..., HIST["min_intv"]]),
            dur / count,  # mean inter-arrival
            f[..., HIST["size_fwd"]],
            f[..., HIST["size_bwd"]],
            f[..., HIST["flags_acc"]],
        ],
        axis=-1,
    )
    return out


def packet_meta_features(packets: ft.PacketBatch) -> jax.Array:
    """Per-packet feature vector for packet-granularity models (use-case 1's
    six-dimension input: size, direction, flags, proto, payload_len, intv=0)."""
    pay_len = jnp.minimum(packets.size, packets.payload.shape[-1])
    return jnp.stack(
        [
            packets.size.astype(jnp.float32),
            packets.dir.astype(jnp.float32),
            packets.flags.astype(jnp.float32),
            packets.proto.astype(jnp.float32),
            pay_len.astype(jnp.float32),
            jnp.zeros_like(packets.size, jnp.float32),
        ],
        axis=-1,
    )
