"""Feature extracting domain (paper §3.1): meta-feature extraction, whole-set
derivation, and the TPU-parallel (segmented) tracker update.

Two execution modes over the same :class:`~repro.core.flow_tracker.TrackerState`:

  * ``extract_scan``       — order-exact oracle; ``lax.scan`` over packets,
                             mirroring the FPGA's serial line-rate fold.  With
                             ``use_pallas`` the 16-lane ALU fold additionally
                             replays through the ``flow_features`` Pallas
                             kernel (exact, any micro-op program) and the
                             kernel result replaces the feature table — so
                             the kernel is exercised on the real
                             establish/evict stream (equality with the scan
                             oracle is asserted in tests).
  * ``segmented_update``   — the TPU-native fast path used by the streaming
                             pipeline: packets are sorted by slot once
                             (stable, so per-flow batch order is preserved),
                             then the whole microbatch merges into the live
                             ``TrackerState`` in one vectorized pass — counts,
                             series/payload memories and tuple ids by rank
                             arithmetic + scatter, feature lanes by segment
                             reductions (or by the Pallas ALU fold under
                             ``use_pallas``, which supports arbitrary
                             programs).  Slots whose batch segment mixes more
                             than one tuple hash take the scan oracle's values
                             instead (a ``lax.cond`` fallback), so the result
                             is *bit-exact* to the oracle in every case — the
                             fallback merely costs the scan when a collision
                             actually occurs.

``extract_segmented`` (empty-table extraction, the original API) is the thin
wrapper ``segmented_update(init_state(), packets)``.

Derived (whole-set) features — Table 7 — come out of the 16-lane history
register by configuration: mean = flow_size/pkt_count, duration = Σ intervals,
etc.  ``derive_whole_features`` materializes the standard derived vector.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import flow_tracker as ft
from repro.kernels.flow_features.ops import (
    HIST,
    default_program,
    default_program_np,
    fold_features,
)

INT_MAX = jnp.iinfo(jnp.int32).max


@dataclass(frozen=True)
class ExtractorConfig:
    table_size: int = 8192  # paper: 8k-depth flow-state table
    top_n: int = 20  # packets per flow tracked for series features
    top_k: int = 15  # packets contributing payload rows
    pay_bytes: int = 16  # payload bytes per packet (paper use-case 3: 16)
    use_pallas: bool = False
    interpret: Optional[bool] = None  # None: derive from the ambient runtime


class SegmentedOut(NamedTuple):
    """Aggregate tracker events of one segmented microbatch merge."""

    new_flows: jax.Array  # () int32 — flows established this batch
    evicted: jax.Array  # () int32 — stale flows recycled by collision
    fallback_slots: jax.Array  # () int32 — slots that took the scan fallback


def check_default_program(program: jax.Array) -> None:
    """The jnp segment-reduction lanes hard-code the default program's
    semantics; refuse a different concrete program loudly instead of silently
    diverging.  (A traced program cannot be inspected — callers jitting over
    the program must route through ``use_pallas``, which folds any program.)"""
    try:
        arr = np.asarray(program)
    except Exception:
        return
    if not np.array_equal(arr, default_program_np()):
        raise ValueError(
            "segmented_update without use_pallas supports only the default "
            "micro-op program (its feature lanes are segment reductions, not "
            "an ALU replay); set use_pallas=True or use the scan tracker")


FALLBACK_MODES = ("auto", "always", "never")


def _mixed_segment_heads(s_slot: jax.Array, s_hash: jax.Array,
                         table_size: int) -> jax.Array:
    """(P,) bool over slot-sorted packets — True where a tuple-hash flip
    occurs inside one slot segment (sentinel rows >= table_size excluded).
    The ONE in-batch collision predicate: :func:`segmented_update`'s scan
    fallback and :func:`batch_collisions` must agree, so both call this."""
    return jnp.concatenate([
        jnp.zeros((1,), bool),
        (s_slot[1:] == s_slot[:-1]) & (s_hash[1:] != s_hash[:-1])
        & (s_slot[1:] < table_size)])


def batch_collisions(packets: ft.PacketBatch, table_size: int,
                     keep: Optional[jax.Array] = None) -> jax.Array:
    """() bool — does this (optionally masked) microbatch contain an in-batch
    slot collision (two distinct tuple hashes mapping to one slot)?  This is
    exactly the predicate :func:`segmented_update`'s scan fallback guards on
    (both share :func:`_mixed_segment_heads`), exposed so batched callers
    (the sharded pipeline's vmapped lanes) can hoist the branch *outside*
    their vmap — a vmapped ``lax.cond`` lowers to a select that pays for
    both branches, i.e. the whole scan oracle on every batch."""
    slots = ft.hash_slot(packets.tuple_hash, table_size)
    if keep is not None:
        slots = jnp.where(keep, slots, table_size)
    order = jnp.argsort(slots, stable=True)
    return _mixed_segment_heads(slots[order], packets.tuple_hash[order],
                                table_size).any()


def segmented_update(
    state: ft.TrackerState,
    packets: ft.PacketBatch,
    program: Optional[jax.Array] = None,
    *,
    top_n: int,
    use_pallas: bool = False,
    interpret: Optional[bool] = None,
    keep: Optional[jax.Array] = None,
    fallback: str = "auto",
    with_spills: bool = False,
):
    """Merge a whole microbatch into the live tracker state in one vectorized
    pass — the TPU-parallel replacement for the per-packet scan.

    Exactness contract (tested differentially against
    :func:`flow_tracker.process_packets` and the pure-Python oracle): the
    returned state and event counts are bit-identical to scanning the batch
    packet by packet.  Slots whose batch segment contains more than one
    distinct tuple hash (an in-batch collision — establish/evict flips mid-
    segment) cannot be expressed as a single segment reduction; those slots
    take the scan oracle's values via a ``lax.cond`` fallback that only
    executes when a collision is actually present in the batch.

    ``keep`` (optional, (P,) bool) drops packets without changing shapes:
    masked-out packets sort to the out-of-range sentinel slot, so every
    segment reduction and scatter ignores them — the exactness contract then
    holds against scanning only the kept packets.  This is how the sharded
    lanes consume hash-partitioned (padded) microbatches.

    ``fallback`` controls the collision branch: ``"auto"`` (default) guards
    it with a ``lax.cond``; ``"always"``/``"never"`` select a branch
    statically, for callers that hoist the :func:`batch_collisions`
    predicate outside a vmap.  ``"never"`` is only exact when the batch
    really has no in-batch collision — callers own that guard.

    ``with_spills`` (static) additionally returns the merge's
    :class:`~repro.core.flow_tracker.SpillRecords`, bit-identical to the
    scan tracker's (differentially tested): a non-colliding slot's eviction
    happens exactly at its segment-head packet, so the pre-batch occupant
    scatters back to that packet's original batch position; colliding slots
    take the scan fallback's per-packet records.  Returns
    ``(state, SegmentedOut)`` by default,
    ``(state, SegmentedOut, SpillRecords)`` under ``with_spills``.
    """
    if fallback not in FALLBACK_MODES:
        raise ValueError(f"fallback must be one of {FALLBACK_MODES}, "
                         f"got {fallback!r}")
    if program is None:
        program = default_program()
    if not use_pallas:
        check_default_program(program)
    if interpret is None:  # platform-derived, like every other entry point
        from repro.runtime import resolve_config

        interpret = resolve_config(None).interpret
    F = state.tuple_id.shape[0]
    top_k = state.payload.shape[1]
    pay_bytes = state.payload.shape[2]
    P = packets.ts.shape[0]
    masked = keep is not None  # unmasked callers keep the kernel fast path
    if keep is None:
        keep = jnp.ones((P,), bool)

    slots = ft.hash_slot(packets.tuple_hash, F)
    # masked-out packets take the sentinel slot F: they sort to the end and
    # every segment reduction / scatter (num_segments == F, mode="drop")
    # ignores them
    slots_eff = jnp.where(keep, slots, F)
    # stable sort by slot: per-flow packets stay in batch (arrival) order
    order = jnp.argsort(slots_eff, stable=True)
    s = jax.tree_util.tree_map(lambda a: a[order], packets)
    s_slot = slots_eff[order]
    s_keep = keep[order]

    first = jnp.concatenate([jnp.ones((1,), bool), s_slot[1:] != s_slot[:-1]])
    ones = jnp.ones((P,), jnp.int32)
    counts_b = jax.ops.segment_sum(ones, s_slot, F, indices_are_sorted=True)
    touched = counts_b > 0

    # in-batch collision: a segment holding >1 distinct tuple hash (the
    # shared predicate — batch_collisions must see exactly these flips)
    mixed = _mixed_segment_heads(s_slot, s.tuple_hash, F)
    collide = jnp.zeros((F,), jnp.int32).at[s_slot].max(
        mixed.astype(jnp.int32), mode="drop") > 0

    # single-hash segments: any reduction of equal values recovers the hash
    h_f = jax.ops.segment_max(s.tuple_hash, s_slot, F, indices_are_sorted=True)
    occupied = state.count > 0
    hit = touched & occupied & (state.tuple_id == h_f)
    establish = touched & ~hit  # first packet of the segment establishes
    evicted_f = touched & occupied & ~hit

    count0 = jnp.where(hit, state.count, 0)
    feats_base = jnp.where(establish[:, None], ft.fresh_feature_word()[None, :],
                           state.features)
    series_base = jnp.where(establish[:, None], 0, state.series)
    sizes_base = jnp.where(establish[:, None], 0, state.sizes)
    pay_base = jnp.where(establish[:, None, None], 0, state.payload)

    # inter-arrival per packet: within the segment from the previous packet,
    # at the segment head from the live flow's last_ts (0 at establish)
    prev_ts = jnp.concatenate([jnp.zeros((1,), jnp.int32), s.ts[:-1]])
    head_intv = jnp.where(hit[s_slot], s.ts - state.last_ts[s_slot], 0)
    intv = jnp.where(first, head_intv, s.ts - prev_ts)

    start = jnp.cumsum(counts_b) - counts_b
    rank = jnp.arange(P, dtype=jnp.int32) - start[s_slot]
    g_rank = count0[s_slot] + rank  # per-flow packet index incl. history
    last_idx = jnp.clip(jnp.cumsum(counts_b) - 1, 0, max(P - 1, 0))

    if use_pallas:
        # ALU fold through the Pallas kernel: exact for any program (per-slot
        # order is the batch order; establish resets are pre-applied in
        # feats_base; colliding slots are overwritten by the fallback)
        meta = jax.vmap(ft.build_meta)(s, intv)
        feats = fold_features(program, s_slot, meta, feats_base,
                              keep=s_keep if masked else None,
                              interpret=interpret)
    else:
        segsum = lambda x: jax.ops.segment_sum(x, s_slot, F,
                                               indices_are_sorted=True)
        segmax = lambda x: jax.ops.segment_max(x, s_slot, F,
                                               indices_are_sorted=True)
        segmin = lambda x: jax.ops.segment_min(x, s_slot, F,
                                               indices_are_sorted=True)

        feats = feats_base

        def upd(f, lane, val):
            return f.at[:, lane].set(jnp.where(touched, val, f[:, lane]))

        base = lambda lane: feats_base[:, lane]
        feats = upd(feats, HIST["flow_dur"], base(HIST["flow_dur"]) + segsum(intv))
        feats = upd(feats, HIST["pkt_count"], count0 + counts_b)
        feats = upd(feats, HIST["flow_size"], base(HIST["flow_size"]) + segsum(s.size))
        feats = upd(feats, HIST["max_size"],
                    jnp.maximum(base(HIST["max_size"]), segmax(s.size)))
        feats = upd(feats, HIST["min_size"],
                    jnp.minimum(base(HIST["min_size"]), segmin(s.size)))
        feats = upd(feats, HIST["max_intv"],
                    jnp.maximum(base(HIST["max_intv"]), segmax(intv)))
        feats = upd(feats, HIST["min_intv"],
                    jnp.minimum(base(HIST["min_intv"]), segmin(intv)))
        feats = upd(feats, HIST["last_ts"], s.ts[last_idx])
        feats = upd(feats, HIST["size_fwd"],
                    base(HIST["size_fwd"]) + segsum(jnp.where(s.dir == 0, s.size, 0)))
        feats = upd(feats, HIST["size_bwd"],
                    base(HIST["size_bwd"]) + segsum(jnp.where(s.dir == 1, s.size, 0)))
        feats = upd(feats, HIST["flags_acc"], base(HIST["flags_acc"]) + segsum(s.flags))
        feats = upd(feats, HIST["last_size"], s.size[last_idx])
        feats = upd(feats, HIST["payload_bytes"],
                    base(HIST["payload_bytes"]) + segsum(jnp.minimum(s.size, pay_bytes)))
        feats = upd(feats, HIST["proto"], s.proto[last_idx])

    # series/payload memories by per-flow rank; overflow ranks are dropped
    # (never overwrite the oldest stored packets — oracle semantics)
    idx_n = jnp.where(g_rank < top_n, g_rank, top_n)
    series = series_base.at[s_slot, idx_n].set(intv, mode="drop")
    sizes = sizes_base.at[s_slot, idx_n].set(s.size, mode="drop")
    idx_k = jnp.where(g_rank < top_k, g_rank, top_k)
    payload = pay_base.at[s_slot, idx_k].set(s.payload, mode="drop")

    seg_state = ft.TrackerState(
        tuple_id=jnp.where(touched, h_f, state.tuple_id),
        count=jnp.where(touched, count0 + counts_b, state.count),
        last_ts=jnp.where(touched, s.ts[last_idx], state.last_ts),
        features=feats,
        series=series,
        sizes=sizes,
        payload=payload,
    )
    new_nc = jnp.sum(establish & ~collide).astype(jnp.int32)
    ev_nc = jnp.sum(evicted_f & ~collide).astype(jnp.int32)
    pkt_collides = collide[slots]  # original batch order

    if with_spills:
        # a non-colliding slot's eviction happens exactly at its segment-head
        # packet (scan semantics: the first batch packet touching the slot
        # displaces the stale occupant), so the pre-batch occupant snapshot
        # scatters back to that packet's original batch position; colliding
        # slots are overwritten per-packet by the scan fallback below
        safe_sl = jnp.where(s_slot < F, s_slot, 0)
        ev_head = first & (s_slot < F) & evicted_f[safe_sl]
        pos = jnp.where(ev_head, order, P)

        def scat_like(table):
            return jnp.zeros((P,) + table.shape[1:], table.dtype).at[pos].set(
                table[safe_sl], mode="drop")

        seg_spills = ft.SpillRecords(
            mask=jnp.zeros((P,), bool).at[pos].set(ev_head, mode="drop"),
            slot=jnp.full((P,), F, jnp.int32).at[pos].set(s_slot, mode="drop"),
            tuple_id=scat_like(state.tuple_id),
            count=scat_like(state.count),
            last_ts=scat_like(state.last_ts),
            features=scat_like(state.features),
            series=scat_like(state.series),
            sizes=scat_like(state.sizes),
            payload=scat_like(state.payload),
        )
    else:
        seg_spills = None

    def with_fallback(_):
        if with_spills:
            scan_state, outs, scan_spills = ft.process_packets(
                state, packets, program, top_n=top_n, keep=keep,
                with_spills=True)
        else:
            scan_state, outs = ft.process_packets(state, packets, program,
                                                  top_n=top_n, keep=keep)
            scan_spills = None

        def pick(seg_leaf, scan_leaf):
            m = collide.reshape((F,) + (1,) * (seg_leaf.ndim - 1))
            return jnp.where(m, scan_leaf, seg_leaf)

        merged = jax.tree_util.tree_map(pick, seg_state, scan_state)
        new = new_nc + jnp.sum(outs.new_flow & pkt_collides).astype(jnp.int32)
        ev = ev_nc + jnp.sum(outs.evicted & pkt_collides).astype(jnp.int32)
        if not with_spills:
            return merged, new, ev, None

        def pick_pkt(seg_leaf, scan_leaf):
            m = pkt_collides.reshape((P,) + (1,) * (seg_leaf.ndim - 1))
            return jnp.where(m, scan_leaf, seg_leaf)

        return merged, new, ev, jax.tree_util.tree_map(pick_pkt, seg_spills,
                                                       scan_spills)

    def without_fallback(_):
        return seg_state, new_nc, ev_nc, seg_spills

    if fallback == "always":
        state1, new_flows, evicted, spills = with_fallback(None)
    elif fallback == "never":
        state1, new_flows, evicted, spills = without_fallback(None)
    else:
        state1, new_flows, evicted, spills = lax.cond(
            collide.any(), with_fallback, without_fallback, operand=None)
    out = SegmentedOut(new_flows=new_flows, evicted=evicted,
                       fallback_slots=jnp.sum(collide).astype(jnp.int32))
    if with_spills:
        return state1, out, spills
    return state1, out


class FeatureExtractor:
    def __init__(self, cfg: ExtractorConfig = ExtractorConfig(), program: Optional[jax.Array] = None):
        self.cfg = cfg
        self.program = program if program is not None else default_program()

    def init_state(self) -> ft.TrackerState:
        c = self.cfg
        return ft.init_state(c.table_size, c.top_n, c.top_k, c.pay_bytes)

    def _interpret(self) -> bool:
        if self.cfg.interpret is not None:
            return self.cfg.interpret
        from repro.runtime import resolve_config

        return resolve_config(None).interpret

    # ------------------------------------------------------------------ scan
    def extract_scan(self, state: ft.TrackerState, packets: ft.PacketBatch):
        """Order-exact oracle (``lax.scan``).  Under ``use_pallas`` the
        feature table is additionally recomputed by replaying the ALU fold
        through the Pallas ``flow_features`` kernel and the kernel's result
        replaces the scanned feature lanes — identical by construction
        (asserted in tests, not at runtime), so the kernel is exercised on
        the real establish/evict stream.  Tracking metadata (counts,
        series, payload, tuple ids) always comes from the scan: it is the
        inherently sequential part the FPGA pipelines in hardware."""
        state2, outs = ft.process_packets(state, packets, self.program,
                                          top_n=self.cfg.top_n)
        if not self.cfg.use_pallas:
            return state2, outs
        P = packets.ts.shape[0]
        F = self.cfg.table_size
        pos = jnp.arange(P, dtype=jnp.int32)
        # a flow's feature word only reflects packets since its LAST establish
        # (each establish resets the word) — replay exactly those
        last_est = jnp.full((F,), -1, jnp.int32).at[outs.slot].max(
            jnp.where(outs.new_flow, pos, -1))
        keep = pos >= last_est[outs.slot]
        feats_base = jnp.where((last_est >= 0)[:, None],
                               ft.fresh_feature_word()[None, :],
                               state.features)
        meta = jax.vmap(ft.build_meta)(packets, outs.arv_intv)
        feats = fold_features(self.program, outs.slot, meta, feats_base,
                              keep=keep, interpret=self._interpret())
        return state2._replace(features=feats), outs

    # ------------------------------------------------------- segmented (TPU)
    def segmented_update(self, state: ft.TrackerState, packets: ft.PacketBatch):
        """Vectorized microbatch merge into live state (see module-level
        :func:`segmented_update`); honours ``cfg.use_pallas``."""
        return segmented_update(state, packets, self.program,
                                top_n=self.cfg.top_n,
                                use_pallas=self.cfg.use_pallas,
                                interpret=self._interpret())

    def extract_segmented(self, packets: ft.PacketBatch):
        """Parallel extraction for a *batch* of packets starting from an empty
        table.  Returns (features (F,16), series (F,top_n), sizes, payload,
        counts (F,)).  Exact against the scan oracle, including in-batch slot
        collisions (those take the scan fallback inside
        :func:`segmented_update`)."""
        state, _ = self.segmented_update(self.init_state(), packets)
        return (state.features, state.series, state.sizes, state.payload,
                state.count)


def derive_whole_features(feats: jax.Array) -> jax.Array:
    """Derive the float 'whole feature set' vector (Table 7 core subset) from
    the 16-lane history register.  Returns (..., 12) float32."""
    f = feats.astype(jnp.float32)
    count = jnp.maximum(f[..., HIST["pkt_count"]], 1.0)
    dur = f[..., HIST["flow_dur"]]
    size = f[..., HIST["flow_size"]]
    out = jnp.stack(
        [
            dur,  # flow duration time
            f[..., HIST["pkt_count"]],  # total packets
            size,  # flow size
            size / count,  # mean packet length
            f[..., HIST["max_size"]],
            jnp.where(f[..., HIST["min_size"]] >= INT_MAX, 0.0, f[..., HIST["min_size"]]),
            f[..., HIST["max_intv"]],
            jnp.where(f[..., HIST["min_intv"]] >= INT_MAX, 0.0, f[..., HIST["min_intv"]]),
            dur / count,  # mean inter-arrival
            f[..., HIST["size_fwd"]],
            f[..., HIST["size_bwd"]],
            f[..., HIST["flags_acc"]],
        ],
        axis=-1,
    )
    return out


def packet_meta_features(packets: ft.PacketBatch) -> jax.Array:
    """Per-packet feature vector for packet-granularity models (use-case 1's
    six-dimension input: size, direction, flags, proto, payload_len, intv=0)."""
    pay_len = jnp.minimum(packets.size, packets.payload.shape[-1])
    return jnp.stack(
        [
            packets.size.astype(jnp.float32),
            packets.dir.astype(jnp.float32),
            packets.flags.astype(jnp.float32),
            packets.proto.astype(jnp.float32),
            pay_len.astype(jnp.float32),
            jnp.zeros_like(packets.size, jnp.float32),
        ],
        axis=-1,
    )
