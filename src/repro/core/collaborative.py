"""Heterogeneous collaborative computing (paper §3.2.3).

Two artifacts live here:

1. :func:`collaborative_forward` — execute a stack of matmul layers with the
   router's placement (small layers -> VPE path, large -> AryPE path, block
   aggregation fused), plus the explicit *unfused* mode for the paper's
   "wo/ collaborating" ablation (Table 6).

2. :class:`OctopusCycleModel` — a cycle-accurate-ish analytical model of the
   paper's FPGA implementation (16x16 AryPE, 8-lane x 2-sublane SIMDU, 8-unit
   VU, 222 MHz, dual 16-byte memory channels).  We use it to *validate the
   paper's own claims* (Table 6's 53 -> 90 kflow/s, 1.69x; use-case 3's
   35.7 kflow/s) from first principles before going beyond them on TPU.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.util import ceil_div
from repro.core import router


# ---------------------------------------------------------------------------
# Execution
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MatmulLayer:
    w_name: str
    activation: Optional[str] = None


def collaborative_forward(
    x: jax.Array,
    weights: Sequence[jax.Array],
    activations: Sequence[Optional[str]],
    *,
    policy: str = "collaborative",
    use_pallas: bool = False,
    fused_aggregation: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """Run x through a stack of routed matmuls.  ``fused_aggregation=False``
    reproduces the 'wo/ collaborating' ablation: AryPE-path matmuls write
    K-block partials to memory and aggregate in a separate pass."""
    h = x
    for w, act in zip(weights, activations):
        if not fused_aggregation:
            m, k = int(np.prod(h.shape[:-1])), h.shape[-1]
            r = router.route_matmul(m, k, w.shape[-1], policy=policy)
            if r.path == "arype":
                if use_pallas:
                    from repro.kernels.arype_matmul import arype_matmul_unfused

                    h = arype_matmul_unfused(
                        h.reshape(-1, k), w, activation=act or "none", interpret=interpret
                    ).reshape(*h.shape[:-1], w.shape[-1])
                else:
                    h = _unfused_jnp(h, w, act)
                continue
        h = router.matmul(h, w, policy=policy, activation=act,
                          use_pallas=use_pallas, interpret=interpret)
    return h


def _unfused_jnp(x: jax.Array, w: jax.Array, act: Optional[str], bk: int = 32) -> jax.Array:
    """bk=32 matches the paper's §3.2.3 blocking example (a 32x32 array splits
    K=96 into blocks); a 128x128 MXU absorbs these K's in one pass — itself a
    hardware-adaptation finding recorded in EXPERIMENTS.md §Validation.
    Partials are materialized through optimization barriers so XLA cannot
    re-fuse the aggregation (the 'wo/ collaborating' semantics)."""
    k = x.shape[-1]
    nk = ceil_div(k, bk)
    partials = []
    for i in range(nk):
        xs = x[..., i * bk : (i + 1) * bk]
        ws = w[i * bk : (i + 1) * bk]
        p = jax.lax.dot_general(xs, ws, (((x.ndim - 1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        partials.append(jax.lax.optimization_barrier(p))
    out = partials[0]
    for p in partials[1:]:
        out = jax.lax.optimization_barrier(out + p)  # serialized VU-on-AryPE stall
    if act == "relu":
        out = jnp.maximum(out, 0.0)
    elif act == "silu":
        out = out * jax.nn.sigmoid(out)
    elif act == "gelu":
        out = jax.nn.gelu(out)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Analytical FPGA cycle model (validates the paper's own numbers)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OctopusHW:
    """Paper §4.1 implementation parameters."""

    array_k: int = 16  # AryPE systolic array is 16x16
    clock_hz: float = 222e6  # computing-domain clock
    simd_lanes: int = 8  # SIMDU lanes
    sublanes: int = 2  # sub-lanes per lane
    mults_per_sublane: int = 4  # 4-wide vector product per sub-lane
    vu_units: int = 8  # VU parallel adder/mult units
    mem_channels: int = 2  # dual memory channels
    bytes_per_cycle: int = 16  # 128-bit channel width


@dataclass
class LayerCost:
    name: str
    mk_n: tuple[int, int, int]
    engine: str
    compute_cycles: float
    stall_cycles: float
    mem_cycles: float
    useful_macs: float

    @property
    def total_cycles(self) -> float:
        return max(self.compute_cycles + self.stall_cycles, self.mem_cycles)


class OctopusCycleModel:
    """Cycle model for a stack of (M,K)x(K,N) layers on the Octopus FPGA.

    AryPE: an (M,K)x(K,N) matmul is blocked into ceil(K/k)*ceil(N/k) passes of
    (M,k)x(k,k); each pass streams M rows plus 2k fill/drain cycles.  Without
    collaboration, each extra K-block costs an aggregation stall of M rows per
    N-block (the array is idle while partial blocks are added).  Data movement
    uses the dual 16-byte channels (int8 operands).

    VPE/SIMDU: 8 lanes x 2 sublanes x 4 mults = 64 MACs/cycle.
    VU: 8 adds/cycle (aggregation offload in collaborative mode).
    """

    def __init__(self, hw: OctopusHW = OctopusHW()):
        self.hw = hw

    def matmul_cost(self, m: int, k: int, n: int, engine: str, collaborative: bool) -> LayerCost:
        hw = self.hw
        macs = float(m) * k * n
        if engine == "vpe":
            mults = hw.simd_lanes * hw.sublanes * hw.mults_per_sublane
            compute = macs / mults
            mem = (m * k + k * n + m * n) / (hw.mem_channels * hw.bytes_per_cycle)
            return LayerCost("vpe", (m, k, n), "vpe", compute, 0.0, mem, macs)
        kb = ceil_div(k, hw.array_k)
        nb = ceil_div(n, hw.array_k)
        compute = kb * nb * (m + 2 * hw.array_k)
        stall = 0.0 if collaborative else (kb - 1) * nb * m  # aggregation stalls the array
        # operands stream per pass: activations (m x k-block) per N-block + weights
        bytes_moved = nb * (m * min(k, hw.array_k) * kb) + k * n + m * n * 4  # int8 in, fp32 partials out
        mem = bytes_moved / (hw.mem_channels * hw.bytes_per_cycle)
        return LayerCost("arype", (m, k, n), "arype", compute, stall, mem, macs)

    def stack_report(
        self, layers: Sequence[tuple[str, int, int, int]], *, collaborative: bool
    ) -> dict:
        """layers: (name, M, K, N).  Placement: the router decides (same policy
        as the JAX execution path) when collaborative; everything on AryPE when
        not (the 'straightforwardly inserted accelerator')."""
        hw = self.hw
        arype, vpe = [], []
        for name, m, k, n in layers:
            r = router.route_matmul(m, k, n, policy="collaborative")
            engine = r.path if collaborative else "arype"
            cost = self.matmul_cost(m, k, n, engine, collaborative)
            (vpe if engine == "vpe" else arype).append((name, cost))
        ary_cycles = sum(c.total_cycles for _, c in arype)
        vpe_cycles = sum(c.total_cycles for _, c in vpe)
        # Engines run concurrently in collaborative mode; serially otherwise.
        total = max(ary_cycles, vpe_cycles) if collaborative else ary_cycles + vpe_cycles
        ary_peak = hw.array_k**2
        vpe_peak = hw.simd_lanes * hw.sublanes * hw.mults_per_sublane
        ary_macs = sum(c.useful_macs for _, c in arype)
        vpe_macs = sum(c.useful_macs for _, c in vpe)
        return {
            "collaborative": collaborative,
            "arype_eff": ary_macs / (ary_cycles * ary_peak) if ary_cycles else 0.0,
            "vpe_eff": vpe_macs / (vpe_cycles * vpe_peak) if vpe_cycles else 0.0,
            "total_cycles": total,
            "time_s": total / hw.clock_hz,
            "arype_cycles": ary_cycles,
            "vpe_cycles": vpe_cycles,
        }


def usecase2_layers(f: int) -> list[tuple[str, int, int, int]]:
    """Paper use-case 2 CNN matmul shapes for f tracked flows (§4.2)."""
    return [
        ("conv1", 20 * f, 3, 32),
        ("conv2", 10 * f, 96, 32),
        ("conv3", 5 * f, 96, 32),
        ("fc", f, 96, 128),
        ("linear", f, 128, 162),
    ]


def usecase3_layers(f: int) -> list[tuple[str, int, int, int]]:
    """Paper use-case 3 transformer matmul shapes for f tracked flows."""
    out = []
    for name, m, k, n in [
        ("wq", 15, 16, 64),
        ("wk", 15, 16, 64),
        ("wv", 15, 16, 64),
        ("qk", 15, 64, 15),
        ("av", 15, 15, 64),
        ("mlp1", 15, 64, 128),
        ("mlp2", 15, 128, 64),
    ]:
        out.append((name, m * f, k, n))
    return out
