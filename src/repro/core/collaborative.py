"""Heterogeneous collaborative computing (paper §3.2.3).

Two artifacts live here:

1. :func:`collaborative_forward` — execute a stack of matmul layers with the
   router's placement (small layers -> VPE path, large -> AryPE path, block
   aggregation fused).  Placement comes from a :class:`RoutePlan` (built once
   per stack, or passed in), so the execution path and the cycle model share
   one source of truth.  ``RuntimeConfig.fused_aggregation=False`` reproduces
   the paper's "wo/ collaborating" ablation (Table 6): AryPE-path matmuls
   write K-block partials to memory and aggregate in a separate pass.

2. :class:`OctopusCycleModel` — a cycle-accurate-ish analytical model of the
   paper's FPGA implementation (16x16 AryPE, 8-lane x 2-sublane SIMDU, 8-unit
   VU, 222 MHz, dual 16-byte memory channels).  We use it to *validate the
   paper's own claims* (Table 6's 53 -> 90 kflow/s, 1.69x; use-case 3's
   35.7 kflow/s) from first principles before going beyond them on TPU.
   Its :meth:`stack_report` consumes the same :class:`RoutePlan` the JAX
   path executes, so analytical placement can never silently diverge.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.util import ceil_div
from repro.core import router
from repro.runtime import RoutePlan, RuntimeConfig, resolve_config


# ---------------------------------------------------------------------------
# Execution
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MatmulLayer:
    w_name: str
    activation: Optional[str] = None


def plan_stack(
    x: Union[jax.Array, jax.ShapeDtypeStruct],
    weights: Sequence[jax.Array],
    *,
    config: Optional[RuntimeConfig] = None,
    names: Optional[Sequence[str]] = None,
) -> RoutePlan:
    """Route a stack of matmul layers once: the (batch*M) stream length is
    invariant through the stack, K/N follow the weight shapes."""
    m_eff = int(np.prod(x.shape[:-1], dtype=np.int64))
    layers = []
    for i, w in enumerate(weights):
        name = names[i] if names is not None else f"layer{i}"
        layers.append((name, m_eff, int(w.shape[0]), int(w.shape[1])))
    return RoutePlan.from_layers(layers, config=config)


def collaborative_forward(
    x: jax.Array,
    weights: Sequence[jax.Array],
    activations: Sequence[Optional[str]],
    *,
    config: Optional[RuntimeConfig] = None,
    plan: Optional[RoutePlan] = None,
) -> jax.Array:
    """Run x through a stack of routed matmuls, executing ``plan`` (built here
    when not supplied).  A supplied plan's own config governs execution unless
    ``config=`` overrides it."""
    if config is None and plan is not None:
        config = plan.config
    cfg = resolve_config(config)
    if plan is None:
        plan = plan_stack(x, weights, config=cfg)
    else:
        if len(plan.steps) != len(weights):
            raise ValueError(
                f"plan has {len(plan.steps)} steps but the stack has "
                f"{len(weights)} layers — rebuild the plan for this stack")
        m_eff = int(np.prod(x.shape[:-1], dtype=np.int64))
        for step, w in zip(plan.steps, weights):
            if (step.m, step.k, step.n) != (m_eff, int(w.shape[0]), int(w.shape[1])):
                raise ValueError(
                    f"plan step {step.name!r} was routed for shape "
                    f"({step.m},{step.k},{step.n}) but the stack executes "
                    f"({m_eff},{int(w.shape[0])},{int(w.shape[1])}) — a stale "
                    "plan would silently diverge from the router; rebuild it")
    h = x
    for step, w, act in zip(plan.steps, weights, activations):
        if not cfg.fused_aggregation and step.engine == "arype":
            k = h.shape[-1]
            if cfg.use_pallas:
                from repro.kernels.arype_matmul import arype_matmul_unfused

                h = arype_matmul_unfused(
                    h.reshape(-1, k), w, activation=act or "none", interpret=cfg.interpret
                ).reshape(*h.shape[:-1], w.shape[-1])
            else:
                h = _unfused_jnp(h, w, act)
            continue
        h = router.matmul(h, w, activation=act, route=step.route, config=cfg)
    return h


def _unfused_jnp(x: jax.Array, w: jax.Array, act: Optional[str], bk: int = 32) -> jax.Array:
    """bk=32 matches the paper's §3.2.3 blocking example (a 32x32 array splits
    K=96 into blocks); a 128x128 MXU absorbs these K's in one pass — itself a
    hardware-adaptation finding recorded in EXPERIMENTS.md §Validation.
    Partials are materialized through optimization barriers so XLA cannot
    re-fuse the aggregation (the 'wo/ collaborating' semantics)."""
    k = x.shape[-1]
    nk = ceil_div(k, bk)
    partials = []
    for i in range(nk):
        xs = x[..., i * bk : (i + 1) * bk]
        ws = w[i * bk : (i + 1) * bk]
        p = jax.lax.dot_general(xs, ws, (((x.ndim - 1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        partials.append(jax.lax.optimization_barrier(p))
    out = partials[0]
    for p in partials[1:]:
        out = jax.lax.optimization_barrier(out + p)  # serialized VU-on-AryPE stall
    if act == "relu":
        out = jnp.maximum(out, 0.0)
    elif act == "silu":
        out = out * jax.nn.sigmoid(out)
    elif act == "gelu":
        out = jax.nn.gelu(out)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Analytical FPGA cycle model (validates the paper's own numbers)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OctopusHW:
    """Paper §4.1 implementation parameters."""

    array_k: int = 16  # AryPE systolic array is 16x16
    clock_hz: float = 222e6  # computing-domain clock
    simd_lanes: int = 8  # SIMDU lanes
    sublanes: int = 2  # sub-lanes per lane
    mults_per_sublane: int = 4  # 4-wide vector product per sub-lane
    vu_units: int = 8  # VU parallel adder/mult units
    mem_channels: int = 2  # dual memory channels
    bytes_per_cycle: int = 16  # 128-bit channel width


@dataclass
class LayerCost:
    name: str
    mk_n: tuple[int, int, int]
    engine: str
    compute_cycles: float
    stall_cycles: float
    mem_cycles: float
    useful_macs: float

    @property
    def total_cycles(self) -> float:
        return max(self.compute_cycles + self.stall_cycles, self.mem_cycles)


class OctopusCycleModel:
    """Cycle model for a stack of (M,K)x(K,N) layers on the Octopus FPGA.

    AryPE: an (M,K)x(K,N) matmul is blocked into ceil(K/k)*ceil(N/k) passes of
    (M,k)x(k,k); each pass streams M rows plus 2k fill/drain cycles.  Without
    collaboration, each extra K-block costs an aggregation stall of M rows per
    N-block (the array is idle while partial blocks are added).  Data movement
    uses the dual 16-byte channels (int8 operands).

    VPE/SIMDU: 8 lanes x 2 sublanes x 4 mults = 64 MACs/cycle.
    VU: 8 adds/cycle (aggregation offload in collaborative mode).
    """

    def __init__(self, hw: OctopusHW = OctopusHW()):
        self.hw = hw

    def matmul_cost(self, m: int, k: int, n: int, engine: str, collaborative: bool) -> LayerCost:
        hw = self.hw
        macs = float(m) * k * n
        if engine == "vpe":
            mults = hw.simd_lanes * hw.sublanes * hw.mults_per_sublane
            compute = macs / mults
            mem = (m * k + k * n + m * n) / (hw.mem_channels * hw.bytes_per_cycle)
            return LayerCost("vpe", (m, k, n), "vpe", compute, 0.0, mem, macs)
        kb = ceil_div(k, hw.array_k)
        nb = ceil_div(n, hw.array_k)
        compute = kb * nb * (m + 2 * hw.array_k)
        stall = 0.0 if collaborative else (kb - 1) * nb * m  # aggregation stalls the array
        # operands stream per pass: activations (m x k-block) per N-block + weights
        bytes_moved = nb * (m * min(k, hw.array_k) * kb) + k * n + m * n * 4  # int8 in, fp32 partials out
        mem = bytes_moved / (hw.mem_channels * hw.bytes_per_cycle)
        return LayerCost("arype", (m, k, n), "arype", compute, stall, mem, macs)

    def stack_report(
        self,
        plan: Union[RoutePlan, Sequence[tuple[str, int, int, int]]],
        *,
        collaborative: bool,
        config: Optional[RuntimeConfig] = None,
    ) -> dict:
        """Cost a placement plan.  ``plan`` is a :class:`RoutePlan` (the same
        object the JAX path executes); a bare ``(name, M, K, N)`` layer list
        is routed into one first — under ``config`` if given, else under the
        router-decides policy as the legacy form always did (a forced ambient
        policy would silently defeat the ``collaborative`` flag).  ``config``
        applies only to that bare-list form: a :class:`RoutePlan` already
        carries the config its routes were decided under.  Placement:
        the plan's recorded routes when collaborative; everything on AryPE
        when not (the 'straightforwardly inserted accelerator').  The report's
        ``calibration`` key records the measured-crossover fingerprint the
        plan's thresholds came from (None: analytic defaults)."""
        if not isinstance(plan, RoutePlan):
            from repro.runtime import current_runtime

            cfg = (config if config is not None
                   else current_runtime().replace(policy="collaborative"))
            plan = RoutePlan.from_layers(plan, config=cfg)
        hw = self.hw
        arype, vpe = [], []
        placements = {}
        for step in plan.steps:
            engine = step.engine if collaborative else "arype"
            placements[step.name] = engine
            cost = self.matmul_cost(step.m, step.k, step.n, engine, collaborative)
            (vpe if engine == "vpe" else arype).append((step.name, cost))
        ary_cycles = sum(c.total_cycles for _, c in arype)
        vpe_cycles = sum(c.total_cycles for _, c in vpe)
        # Engines run concurrently in collaborative mode; serially otherwise.
        total = max(ary_cycles, vpe_cycles) if collaborative else ary_cycles + vpe_cycles
        ary_peak = hw.array_k**2
        vpe_peak = hw.simd_lanes * hw.sublanes * hw.mults_per_sublane
        ary_macs = sum(c.useful_macs for _, c in arype)
        vpe_macs = sum(c.useful_macs for _, c in vpe)
        return {
            "collaborative": collaborative,
            "calibration": plan.config.calibration,
            "placements": placements,
            "arype_eff": ary_macs / (ary_cycles * ary_peak) if ary_cycles else 0.0,
            "vpe_eff": vpe_macs / (vpe_cycles * vpe_peak) if vpe_cycles else 0.0,
            "total_cycles": total,
            "time_s": total / hw.clock_hz,
            "arype_cycles": ary_cycles,
            "vpe_cycles": vpe_cycles,
        }


def usecase2_layers(f: int) -> list[tuple[str, int, int, int]]:
    """Paper use-case 2 CNN matmul shapes for f tracked flows (§4.2)."""
    return [
        ("conv1", 20 * f, 3, 32),
        ("conv2", 10 * f, 96, 32),
        ("conv3", 5 * f, 96, 32),
        ("fc", f, 96, 128),
        ("linear", f, 128, 162),
    ]


def usecase3_layers(f: int) -> list[tuple[str, int, int, int]]:
    """Paper use-case 3 transformer matmul shapes for f tracked flows."""
    out = []
    for name, m, k, n in [
        ("wq", 15, 16, 64),
        ("wk", 15, 16, 64),
        ("wv", 15, 16, 64),
        ("qk", 15, 64, 15),
        ("av", 15, 15, 64),
        ("mlp1", 15, 64, 128),
        ("mlp2", 15, 128, 64),
    ]:
        out.append((name, m * f, k, n))
    return out


def usecase2_plan(f: int, *, config: Optional[RuntimeConfig] = None) -> RoutePlan:
    return RoutePlan.from_layers(usecase2_layers(f), config=config)


def usecase3_plan(f: int, *, config: Optional[RuntimeConfig] = None) -> RoutePlan:
    return RoutePlan.from_layers(usecase3_layers(f), config=config)
