"""Flow tracker (paper §3.1): hash-indexed flow-state establishment, update,
and freeing, with ready-flow emission at the top-n packet threshold.

State per slot (paper: "MAC address, packet number of current flow, the
timestamp of last packet"):
  * ``tuple_id``   the flow's 5-tuple hash (collision detection / eviction)
  * ``count``      packets seen so far
  * ``last_ts``    timestamp of the latest packet
  * ``features``   the 16-lane history register (ALU cluster output)
  * ``series``     per-flow vector memory (top-n arrival intervals / sizes)
  * ``payload``    per-flow payload matrix (top-k packets x top-b bytes)

Collisions follow the paper's freeing rule: a new tuple hashing onto an
occupied slot evicts the stale flow (outdated-flow recycling).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.flow_features.flow_features import apply_alu_program
from repro.kernels.flow_features.ops import HIST, META, META_WIDTH

INT_MAX = jnp.iinfo(jnp.int32).max
# history lanes that hold running minima start at INT_MAX
_MIN_LANES = (HIST["min_size"], HIST["min_intv"])


class TrackerState(NamedTuple):
    tuple_id: jax.Array  # (F,) int32
    count: jax.Array  # (F,) int32
    last_ts: jax.Array  # (F,) int32
    features: jax.Array  # (F, 16) int32
    series: jax.Array  # (F, top_n) int32  (arrival-interval vector memory)
    sizes: jax.Array  # (F, top_n) int32  (packet-size vector memory)
    payload: jax.Array  # (F, top_k, pay_bytes) int32


class PacketBatch(NamedTuple):
    """Struct-of-arrays packet records (the parser's output, §3.1 step 1)."""

    ts: jax.Array  # (P,) int32 microseconds
    size: jax.Array  # (P,) int32
    dir: jax.Array  # (P,) int32 0/1
    flags: jax.Array  # (P,) int32
    proto: jax.Array  # (P,) int32
    tuple_hash: jax.Array  # (P,) int32 hash of the 5-tuple
    payload: jax.Array  # (P, pay_bytes) int32 (truncated payload)


def fresh_feature_word() -> jax.Array:
    w = jnp.zeros((16,), jnp.int32)
    for lane in _MIN_LANES:
        w = w.at[lane].set(INT_MAX)
    return w


def init_state(table_size: int, top_n: int, top_k: int, pay_bytes: int) -> TrackerState:
    return TrackerState(
        tuple_id=jnp.zeros((table_size,), jnp.int32),
        count=jnp.zeros((table_size,), jnp.int32),
        last_ts=jnp.zeros((table_size,), jnp.int32),
        features=jnp.tile(fresh_feature_word()[None], (table_size, 1)),
        series=jnp.zeros((table_size, top_n), jnp.int32),
        sizes=jnp.zeros((table_size, top_n), jnp.int32),
        payload=jnp.zeros((table_size, top_k, pay_bytes), jnp.int32),
    )


def hash_slot(tuple_hash: jax.Array, table_size: int) -> jax.Array:
    """Multiplicative hash onto the flow table (FPGA uses CRC; same semantics)."""
    h = tuple_hash.astype(jnp.uint32) * jnp.uint32(0x9E3779B1)
    h = h ^ (h >> 16)
    return (h % jnp.uint32(table_size)).astype(jnp.int32)


def hash_slot_scalar(tuple_hash: int, table_size: int) -> int:
    """:func:`hash_slot` for one host-side int (no device dispatch) — used by
    hot host loops like the traffic generator's collision avoidance.  Must
    stay bit-identical to the array version (tested)."""
    h = ((tuple_hash & 0xFFFFFFFF) * 0x9E3779B1) & 0xFFFFFFFF
    h ^= h >> 16
    return int(h % table_size)


def build_meta(pkt, arv_intv: jax.Array) -> jax.Array:
    """Assemble the meta register (paper Table 2) for one packet."""
    m = jnp.zeros((META_WIDTH,), jnp.int32)
    m = m.at[META["pkt_size"]].set(pkt.size)
    m = m.at[META["arv_intv"]].set(arv_intv)
    m = m.at[META["dir"]].set(pkt.dir)
    m = m.at[META["flags"]].set(pkt.flags)
    m = m.at[META["ts"]].set(pkt.ts)
    m = m.at[META["payload_len"]].set(jnp.minimum(pkt.size, pkt.payload.shape[-1]))
    m = m.at[META["one"]].set(1)
    m = m.at[META["size_fwd"]].set(jnp.where(pkt.dir == 0, pkt.size, 0))
    m = m.at[META["size_bwd"]].set(jnp.where(pkt.dir == 1, pkt.size, 0))
    m = m.at[META["neg_pkt_size"]].set(-pkt.size)
    m = m.at[META["neg_arv_intv"]].set(-arv_intv)
    m = m.at[META["proto"]].set(pkt.proto)
    return m


class StepOut(NamedTuple):
    slot: jax.Array
    ready: jax.Array  # flow hit top_n with this packet
    new_flow: jax.Array
    evicted: jax.Array
    arv_intv: jax.Array  # inter-arrival time seen by the tracker (0 at establish)


class SpillRecords(NamedTuple):
    """One row per batch packet: the flow state an eviction overwrote, read
    out *before* the establishing write (the cold store's insert feed).  Rows
    with ``mask == False`` are padding (slot == table_size, data zeros); the
    scan and segmented trackers emit bit-identical records (tested)."""

    mask: jax.Array  # (P,) bool — this packet evicted a live flow
    slot: jax.Array  # (P,) int32; table_size for padding rows
    tuple_id: jax.Array  # (P,) int32
    count: jax.Array  # (P,) int32
    last_ts: jax.Array  # (P,) int32
    features: jax.Array  # (P, 16) int32
    series: jax.Array  # (P, top_n) int32
    sizes: jax.Array  # (P, top_n) int32
    payload: jax.Array  # (P, top_k, pay_bytes) int32


def process_packets(
    state: TrackerState,
    packets: PacketBatch,
    program: jax.Array,
    *,
    top_n: int,
    keep: Optional[jax.Array] = None,
    with_spills: bool = False,
):
    """Order-exact oracle: lax.scan over packets (the FPGA processes packets
    serially at line rate).  See feature_extractor.extract_segmented for the
    TPU-parallel path.

    ``keep`` (optional, (P,) bool) drops packets without changing shapes: a
    masked-out packet is a complete no-op on the table (its scatter lands on
    the out-of-range sentinel slot ``table_size`` and is dropped) and its
    :class:`StepOut` row is neutral (slot == table_size, all flags False).
    This is how the sharded lanes process hash-partitioned microbatches whose
    static per-lane shape is padded.

    With ``with_spills=True`` (a static trace-time flag — the default trace
    is unchanged) the return gains a third element: :class:`SpillRecords`
    capturing every evicted flow's pre-overwrite state, in packet order."""
    table_size = state.tuple_id.shape[0]
    top_k = state.payload.shape[1]
    if keep is None:
        keep = jnp.ones(packets.ts.shape, bool)

    def step(st: TrackerState, xs):
        pkt, k = xs
        slot = hash_slot(pkt.tuple_hash, table_size)
        occupied = st.count[slot] > 0
        hit = occupied & (st.tuple_id[slot] == pkt.tuple_hash)
        evict = occupied & ~hit
        is_new = ~hit

        count0 = jnp.where(is_new, 0, st.count[slot])
        feats0 = jnp.where(is_new, fresh_feature_word(), st.features[slot])
        series0 = jnp.where(is_new, jnp.zeros_like(st.series[slot]), st.series[slot])
        sizes0 = jnp.where(is_new, jnp.zeros_like(st.sizes[slot]), st.sizes[slot])
        pay0 = jnp.where(is_new, jnp.zeros_like(st.payload[slot]), st.payload[slot])

        arv_intv = jnp.where(count0 > 0, pkt.ts - st.last_ts[slot], 0)
        meta = build_meta(pkt, arv_intv)
        new_feats = apply_alu_program(program, meta, feats0)

        idx = jnp.minimum(count0, top_n - 1)
        series1 = series0.at[idx].set(jnp.where(count0 < top_n, arv_intv, series0[idx]))
        sizes1 = sizes0.at[idx].set(jnp.where(count0 < top_n, pkt.size, sizes0[idx]))
        kidx = jnp.minimum(count0, top_k - 1)
        pay1 = pay0.at[kidx].set(jnp.where(count0 < top_k, pkt.payload, pay0[kidx]))

        count1 = count0 + 1
        # masked-out packets write to the out-of-range sentinel slot: dropped
        upd = jnp.where(k, slot, table_size)
        st1 = TrackerState(
            tuple_id=st.tuple_id.at[upd].set(pkt.tuple_hash, mode="drop"),
            count=st.count.at[upd].set(count1, mode="drop"),
            last_ts=st.last_ts.at[upd].set(pkt.ts, mode="drop"),
            features=st.features.at[upd].set(new_feats, mode="drop"),
            series=st.series.at[upd].set(series1, mode="drop"),
            sizes=st.sizes.at[upd].set(sizes1, mode="drop"),
            payload=st.payload.at[upd].set(pay1, mode="drop"),
        )
        out = StepOut(slot=upd, ready=k & (count1 == top_n), new_flow=k & is_new,
                      evicted=k & evict, arv_intv=jnp.where(k, arv_intv, 0))
        if not with_spills:
            return st1, out
        # snapshot the evicted occupant BEFORE the establishing write above
        # overwrote it (we read from `st`, the pre-packet state)
        sp = k & evict

        def grab(leaf):
            return jnp.where(sp, leaf[slot], 0)  # scalar mask broadcasts

        spill = SpillRecords(
            mask=sp, slot=jnp.where(sp, slot, table_size),
            tuple_id=grab(st.tuple_id), count=grab(st.count),
            last_ts=grab(st.last_ts), features=grab(st.features),
            series=grab(st.series), sizes=grab(st.sizes),
            payload=grab(st.payload))
        return st1, (out, spill)

    if not with_spills:
        return lax.scan(step, state, (packets, keep))
    state1, (out, spills) = lax.scan(step, state, (packets, keep))
    return state1, out, spills


def release_flows(state: TrackerState, slots: jax.Array) -> TrackerState:
    """FIN handling: computing finished for these slots; recycle storage
    (paper: 'read out the top address in in-flight FIFO and set packet
    numbers in this address to zero').

    Recycles ALL seven leaves (a slot that keeps stale tuple_id / series /
    sizes / payload poisons the next flow established there) and scatters
    with ``mode="drop"`` so the ``table_size`` padding sentinel is a no-op
    instead of clamping onto — and wiping — the last table slot."""
    return state._replace(
        tuple_id=state.tuple_id.at[slots].set(0, mode="drop"),
        count=state.count.at[slots].set(0, mode="drop"),
        last_ts=state.last_ts.at[slots].set(0, mode="drop"),
        features=state.features.at[slots].set(fresh_feature_word(),
                                              mode="drop"),
        series=state.series.at[slots].set(0, mode="drop"),
        sizes=state.sizes.at[slots].set(0, mode="drop"),
        payload=state.payload.at[slots].set(0, mode="drop"),
    )


class DrainResult(NamedTuple):
    """Up to ``max_ready`` emitted ready flows, fixed shapes (R = max_ready).
    Rows with ``mask == False`` are padding (slot == table_size, zeros)."""

    slots: jax.Array  # (R,) int32; table_size for padding rows
    mask: jax.Array  # (R,) bool — row holds a real emitted flow
    tuple_id: jax.Array  # (R,) int32
    count: jax.Array  # (R,) int32 (>= top_n wherever mask)
    features: jax.Array  # (R, 16) int32
    series: jax.Array  # (R, top_n) int32
    sizes: jax.Array  # (R, top_n) int32
    payload: jax.Array  # (R, top_k, pay_bytes) int32


def ready_mask(state: TrackerState, *, top_n: int) -> jax.Array:
    """(F,) bool — flows that have delivered their top-n packets and await
    emission (the in-flight FIFO contents, §3.1)."""
    return state.count >= top_n


def drain_ready(state: TrackerState, *, top_n: int,
                max_ready: int) -> tuple[TrackerState, DrainResult]:
    """Consume ready-flow emission: read out up to ``max_ready`` flows whose
    ``count >= top_n`` (lowest slots first, deterministically) and recycle
    their table entries (paper: pop the in-flight FIFO, zero the packet
    number).  Output shapes are static, so the step jit/scan-compiles; flows
    beyond ``max_ready`` stay ready and drain on a later call."""
    table_size = state.tuple_id.shape[0]
    if not 0 < max_ready <= table_size:
        raise ValueError(f"max_ready must be in [1, {table_size}], got {max_ready}")
    ready = ready_mask(state, top_n=top_n)
    # smallest `max_ready` ready slot indices, padded with table_size
    keys = jnp.where(ready, jnp.arange(table_size, dtype=jnp.int32),
                     jnp.int32(table_size))
    slots = -jax.lax.top_k(-keys, max_ready)[0]
    mask = slots < table_size
    safe = jnp.where(mask, slots, 0)

    def emit(rows: jax.Array, fill) -> jax.Array:
        m = mask.reshape((max_ready,) + (1,) * (rows.ndim - 1))
        return jnp.where(m, rows[safe], fill)

    out = DrainResult(
        slots=jnp.where(mask, slots, table_size),
        mask=mask,
        tuple_id=emit(state.tuple_id, 0),
        count=emit(state.count, 0),
        features=emit(state.features, 0),
        series=emit(state.series, 0),
        sizes=emit(state.sizes, 0),
        payload=emit(state.payload, 0),
    )
    # recycle: padding rows index table_size -> out of bounds -> dropped
    upd = out.slots
    state2 = state._replace(
        tuple_id=state.tuple_id.at[upd].set(0, mode="drop"),
        count=state.count.at[upd].set(0, mode="drop"),
        last_ts=state.last_ts.at[upd].set(0, mode="drop"),
        features=state.features.at[upd].set(fresh_feature_word(), mode="drop"),
        series=state.series.at[upd].set(0, mode="drop"),
        sizes=state.sizes.at[upd].set(0, mode="drop"),
        payload=state.payload.at[upd].set(0, mode="drop"),
    )
    return state2, out
