"""The Octopus placement router (paper §2.3, §3.2.3).

Every matmul in the framework goes through :func:`matmul`.  At trace time the
router inspects the *static* operand shapes, evaluates the paper's systolic
utilization model, and dispatches to one of the two engine paths:

  * **AryPE path** — MXU-aligned blocked matmul (throughput engine).  On a real
    TPU with ``RuntimeConfig.use_pallas`` this is the fused-accumulation Pallas
    kernel; otherwise an XLA ``dot_general`` (which targets the MXU natively).
  * **VPE path** — broadcast-multiply + lane-reduce (latency engine / small
    shapes).  Shapes whose MXU utilization would fall below the config's
    ``tau`` are re-expressed as VPU work, exactly as Octopus offloads the
    CNN's first layer to the SIMDU sub-lanes.

All tuning lives in :class:`repro.runtime.RuntimeConfig` — ambient via
``with octopus_runtime(cfg):`` or passed explicitly as ``config=``.  (The
old per-call ``policy=`` / ``use_pallas=`` / ``interpret=`` /
``accum_dtype=`` kwargs were removed on the PR 1 deprecation schedule.)
The utilization model itself lives in :mod:`repro.runtime.routing`; this
module re-exports it so existing imports (``router.route_matmul``,
``router.mxu_utilization``, ...) keep working.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime import (
    Route,
    RuntimeConfig,
    mxu_utilization,
    resolve_config,
    systolic_utilization,
)
from repro.runtime import quant as _quant
from repro.runtime import routing as _routing

__all__ = [
    "Route",
    "matmul",
    "mxu_utilization",
    "route_matmul",
    "systolic_utilization",
]

# Deprecated aliases for the old module globals — the live values are fields
# of RuntimeConfig; these are kept only so old imports keep resolving.
MXU = RuntimeConfig.mxu_tile
FILL_DEPTH = RuntimeConfig.fill_depth
TAU = RuntimeConfig.tau
VPE_MAX_ELEMS = RuntimeConfig.vpe_max_elems


def route_matmul(m: int, k: int, n: int, *, config: Optional[RuntimeConfig] = None,
                 name: Optional[str] = None) -> Route:
    """Placement decision for an (m,k)x(k,n) matmul under ``config`` (the
    ambient runtime when None)."""
    return _routing.route_matmul(m, k, n, config=resolve_config(config), name=name)


def _vpe_mm(x: jax.Array, w: jax.Array, accum_dtype=jnp.float32) -> jax.Array:
    """(..., M, K) x (K, N) as broadcast-multiply + reduce (VPU path)."""
    prod = x[..., :, :, None].astype(accum_dtype) * w[..., None, :, :].astype(accum_dtype)
    return prod.sum(axis=-2)


def _arype_mm(x: jax.Array, w: jax.Array, accum_dtype=jnp.float32) -> jax.Array:
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())), preferred_element_type=accum_dtype
    )


def _apply_activation(out: jax.Array, activation: Optional[str]) -> jax.Array:
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation == "silu":
        out = out * jax.nn.sigmoid(out)
    elif activation == "gelu":
        out = jax.nn.gelu(out)
    return out


def _resolve_quant_impl(cfg: RuntimeConfig, k: int) -> str:
    """Pick the int8 execution encoding for a contraction depth ``k``.

    "auto" emulates on CPU hosts, where XLA lowers int8 dots through a slow
    generic path, and goes native elsewhere.  Emulation is only bit-exact to
    int32 accumulation up to :data:`repro.runtime.quant.EMULATE_MAX_K`; deeper
    contractions force the native encoding regardless."""
    if k > _quant.EMULATE_MAX_K:
        return "native"
    if cfg.quant_impl != "auto":
        return cfg.quant_impl
    from repro.runtime import platform

    return "emulate" if platform.backend() == "cpu" else "native"


def _quantized_mm(x: jax.Array, w: jax.Array, scale_x, scale_w,
                  path: str, cfg: RuntimeConfig) -> jax.Array:
    """Int8 engine matmul: quantize operands to the symmetric grid (per-tensor
    activation scale, per-tensor or per-output-channel weight scales),
    contract with int32 accumulation (or its exact f32 emulation), dequantize
    to f32.  The activation is applied by the caller, after dequant."""
    k = x.shape[-1]
    dq = jnp.asarray(_quant.dequant_row(scale_x, scale_w, w.shape[-1]))
    if _resolve_quant_impl(cfg, k) == "emulate":
        xq = _quant.quantize_f32int(x, scale_x)
        wq = _quant.quantize_f32int(w, scale_w)
        acc = _vpe_mm(xq, wq) if path == "vpe" else _arype_mm(xq, wq)
    else:
        xq = _quant.quantize_i8(x, scale_x)
        wq = _quant.quantize_i8(w, scale_w)
        acc = (_vpe_mm(xq, wq, jnp.int32) if path == "vpe"
               else _arype_mm(xq, wq, jnp.int32))
    return acc.astype(jnp.float32) * dq


def matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    activation: Optional[str] = None,
    out_dtype=None,
    config: Optional[RuntimeConfig] = None,
    route: Optional[Route] = None,
    name: Optional[str] = None,
) -> jax.Array:
    """Routed matmul: x (..., M, K) @ w (K, N) -> (..., M, N).

    Placement and execution are governed by ``config`` (default: the ambient
    :func:`repro.runtime.current_runtime`).  Pass ``route=`` to execute a
    pre-decided :class:`Route` (e.g. a :class:`RoutePlan` step) instead of
    re-deriving it.

    With ``config.use_pallas`` the call lowers through the Pallas engine
    kernels (TPU target; validated with ``interpret=True`` on CPU).
    Otherwise the two paths are expressed in jnp so XLA emits MXU dots vs
    VPU mul+reduce respectively.

    With ``config.quantize`` the matmul runs in int8 operands / int32
    accumulation, dequantized to f32 before the activation — but only when
    the layer ``name`` has a calibrated entry in ``config.quant_scales``;
    unnamed or uncalibrated matmuls execute the f32 path unchanged.  When a
    :func:`repro.runtime.quant.record_scales` block is active and the call
    is eager, the operands' max-abs statistics are recorded (that is the
    calibration tap).
    """
    cfg = resolve_config(config)
    *batch, m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    m_eff = int(np.prod(batch, dtype=np.int64)) * m if batch else m
    r = route if route is not None else _routing.route_matmul(m_eff, k, n, config=cfg, name=name)
    out_dtype = out_dtype or x.dtype
    acc = jnp.dtype(cfg.accum_dtype)
    _quant.maybe_record(name, x, w)

    qscales = (cfg.quant_scales.lookup(name, _routing.current_scope())
               if cfg.quantize and cfg.quant_scales is not None else None)

    if cfg.use_pallas:
        x2 = x.reshape(-1, k)
        if qscales is not None:
            sx, sw = qscales
            if r.path == "vpe":
                from repro.kernels.vpe_smallmm import vpe_matmul_q

                out = vpe_matmul_q(x2, w, scale_x=sx, scale_w=sw,
                                   activation=activation or "none",
                                   out_dtype=out_dtype, interpret=cfg.interpret)
            else:
                from repro.kernels.arype_matmul import arype_matmul_q

                out = arype_matmul_q(x2, w, scale_x=sx, scale_w=sw,
                                     activation=activation or "none",
                                     out_dtype=out_dtype, interpret=cfg.interpret)
        elif r.path == "vpe":
            from repro.kernels.vpe_smallmm import vpe_matmul

            out = vpe_matmul(x2, w, activation=activation or "none",
                             out_dtype=out_dtype, interpret=cfg.interpret)
        else:
            from repro.kernels.arype_matmul import arype_matmul

            out = arype_matmul(x2, w, activation=activation or "none",
                               out_dtype=out_dtype, interpret=cfg.interpret)
        return out.reshape(*batch, m, n)

    if qscales is not None:
        out = _quantized_mm(x, w, qscales[0], qscales[1], r.path, cfg)
    else:
        out = _vpe_mm(x, w, acc) if r.path == "vpe" else _arype_mm(x, w, acc)
    return _apply_activation(out, activation).astype(out_dtype)
