"""The Octopus placement router (paper §2.3, §3.2.3).

Every matmul in the framework goes through :func:`matmul`.  At trace time the
router inspects the *static* operand shapes, evaluates the paper's systolic
utilization model, and dispatches to one of the two engine paths:

  * **AryPE path** — MXU-aligned blocked matmul (throughput engine).  On a real
    TPU with ``RuntimeConfig.use_pallas`` this is the fused-accumulation Pallas
    kernel; otherwise an XLA ``dot_general`` (which targets the MXU natively).
  * **VPE path** — broadcast-multiply + lane-reduce (latency engine / small
    shapes).  Shapes whose MXU utilization would fall below the config's
    ``tau`` are re-expressed as VPU work, exactly as Octopus offloads the
    CNN's first layer to the SIMDU sub-lanes.

All tuning lives in :class:`repro.runtime.RuntimeConfig` — ambient via
``with octopus_runtime(cfg):`` or passed explicitly as ``config=``.  (The
old per-call ``policy=`` / ``use_pallas=`` / ``interpret=`` /
``accum_dtype=`` kwargs were removed on the PR 1 deprecation schedule.)
The utilization model itself lives in :mod:`repro.runtime.routing`; this
module re-exports it so existing imports (``router.route_matmul``,
``router.mxu_utilization``, ...) keep working.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime import (
    Route,
    RuntimeConfig,
    mxu_utilization,
    resolve_config,
    systolic_utilization,
)
from repro.runtime import routing as _routing

__all__ = [
    "Route",
    "matmul",
    "mxu_utilization",
    "route_matmul",
    "systolic_utilization",
]

# Deprecated aliases for the old module globals — the live values are fields
# of RuntimeConfig; these are kept only so old imports keep resolving.
MXU = RuntimeConfig.mxu_tile
FILL_DEPTH = RuntimeConfig.fill_depth
TAU = RuntimeConfig.tau
VPE_MAX_ELEMS = RuntimeConfig.vpe_max_elems


def route_matmul(m: int, k: int, n: int, *, config: Optional[RuntimeConfig] = None,
                 name: Optional[str] = None) -> Route:
    """Placement decision for an (m,k)x(k,n) matmul under ``config`` (the
    ambient runtime when None)."""
    return _routing.route_matmul(m, k, n, config=resolve_config(config), name=name)


def _vpe_mm(x: jax.Array, w: jax.Array, accum_dtype=jnp.float32) -> jax.Array:
    """(..., M, K) x (K, N) as broadcast-multiply + reduce (VPU path)."""
    prod = x[..., :, :, None].astype(accum_dtype) * w[..., None, :, :].astype(accum_dtype)
    return prod.sum(axis=-2)


def _arype_mm(x: jax.Array, w: jax.Array, accum_dtype=jnp.float32) -> jax.Array:
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())), preferred_element_type=accum_dtype
    )


def matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    activation: Optional[str] = None,
    out_dtype=None,
    config: Optional[RuntimeConfig] = None,
    route: Optional[Route] = None,
    name: Optional[str] = None,
) -> jax.Array:
    """Routed matmul: x (..., M, K) @ w (K, N) -> (..., M, N).

    Placement and execution are governed by ``config`` (default: the ambient
    :func:`repro.runtime.current_runtime`).  Pass ``route=`` to execute a
    pre-decided :class:`Route` (e.g. a :class:`RoutePlan` step) instead of
    re-deriving it.

    With ``config.use_pallas`` the call lowers through the Pallas engine
    kernels (TPU target; validated with ``interpret=True`` on CPU).
    Otherwise the two paths are expressed in jnp so XLA emits MXU dots vs
    VPU mul+reduce respectively.
    """
    cfg = resolve_config(config)
    *batch, m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    m_eff = int(np.prod(batch, dtype=np.int64)) * m if batch else m
    r = route if route is not None else _routing.route_matmul(m_eff, k, n, config=cfg, name=name)
    out_dtype = out_dtype or x.dtype
    acc = jnp.dtype(cfg.accum_dtype)

    if cfg.use_pallas:
        x2 = x.reshape(-1, k)
        if r.path == "vpe":
            from repro.kernels.vpe_smallmm import vpe_matmul

            out = vpe_matmul(x2, w, activation=activation or "none",
                             out_dtype=out_dtype, interpret=cfg.interpret)
        else:
            from repro.kernels.arype_matmul import arype_matmul

            out = arype_matmul(x2, w, activation=activation or "none",
                               out_dtype=out_dtype, interpret=cfg.interpret)
        return out.reshape(*batch, m, n)

    out = _vpe_mm(x, w, acc) if r.path == "vpe" else _arype_mm(x, w, acc)
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation == "silu":
        out = out * jax.nn.sigmoid(out)
    elif activation == "gelu":
        out = jax.nn.gelu(out)
    return out.astype(out_dtype)
