"""The Octopus placement router (paper §2.3, §3.2.3).

Every matmul in the framework goes through :func:`matmul`.  At trace time the
router inspects the *static* operand shapes, evaluates the paper's systolic
utilization model, and dispatches to one of the two engine paths:

  * **AryPE path** — MXU-aligned blocked matmul (throughput engine).  On a real
    TPU with ``use_pallas=True`` this is the fused-accumulation Pallas kernel;
    otherwise an XLA ``dot_general`` (which targets the MXU natively).
  * **VPE path** — broadcast-multiply + lane-reduce (latency engine / small
    shapes).  Shapes whose MXU utilization would fall below ``tau`` are
    re-expressed as VPU work, exactly as Octopus offloads the CNN's first
    layer to the SIMDU sub-lanes.

The utilization model mirrors the paper's analysis: a (M,K)x(K,N) matmul on a
``T×T`` systolic array achieves ``util = K/⌈K⌉_T · N/⌈N⌉_T`` MAC-occupancy
(fill of the stationary tile), with an additional M-side penalty for streams
shorter than the array's fill depth.  The paper's 32x32-array example — layer 1
(10,3)x(3,32): 9.3% — is reproduced by this model (see tests).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.util import ceil_div

# TPU MXU tile (the "systolic array size" of the target hardware).
MXU = 128
# Minimum stream length to fully hide the systolic fill latency.
FILL_DEPTH = 8
# Utilization threshold below which work routes to the VPE path.
TAU = 0.35
# VPE-path working-set cap (fp32 elements of the M*K*N product tile).
VPE_MAX_ELEMS = 1 << 21


@dataclass(frozen=True)
class Route:
    path: str  # "arype" | "vpe"
    util: float
    reason: str


def systolic_utilization(m: int, k: int, n: int, array: int) -> float:
    """The paper's utilization definition (§3.2.3): useful MACs over
    array-slots x stream-cycles for an (m,k)x(k,n) matmul on an array x array
    systolic grid.  Reproduces the paper's 9.3% for (10,3)x(3,32) on 32x32."""
    kb, nb = ceil_div(k, array), ceil_div(n, array)
    useful = m * k * n
    slots = kb * nb * m * array * array
    return useful / slots


def mxu_utilization(m: int, k: int, n: int, tile: int = MXU, fill: int = FILL_DEPTH) -> float:
    """TPU routing cost model: stationary-tile fill (K, N padding waste) plus
    the sublane granularity penalty on the streamed M dimension."""
    fill_k = k / (ceil_div(k, tile) * tile)
    fill_n = n / (ceil_div(n, tile) * tile)
    stream = m / (ceil_div(m, fill) * fill)
    return fill_k * fill_n * stream


def route_matmul(m: int, k: int, n: int, *, policy: str = "collaborative") -> Route:
    if policy == "arype_only":
        return Route("arype", mxu_utilization(m, k, n), "forced")
    if policy == "vpe_only":
        return Route("vpe", mxu_utilization(m, k, n), "forced")
    util = mxu_utilization(m, k, n)
    if util < TAU and m * k * n <= VPE_MAX_ELEMS:
        return Route("vpe", util, f"util {util:.3f} < {TAU} and working set fits VPU path")
    return Route("arype", util, f"util {util:.3f}")


def _vpe_mm(x: jax.Array, w: jax.Array, accum_dtype=jnp.float32) -> jax.Array:
    """(..., M, K) x (K, N) as broadcast-multiply + reduce (VPU path)."""
    prod = x[..., :, :, None].astype(accum_dtype) * w[..., None, :, :].astype(accum_dtype)
    return prod.sum(axis=-2)


def _arype_mm(x: jax.Array, w: jax.Array, accum_dtype=jnp.float32) -> jax.Array:
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())), preferred_element_type=accum_dtype
    )


def matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    policy: str = "collaborative",
    activation: Optional[str] = None,
    out_dtype=None,
    use_pallas: bool = False,
    interpret: bool = True,
    accum_dtype=jnp.float32,
) -> jax.Array:
    """Routed matmul: x (..., M, K) @ w (K, N) -> (..., M, N).

    ``use_pallas`` lowers through the Pallas engine kernels (TPU target;
    validated with interpret=True on CPU).  Otherwise the two paths are
    expressed in jnp so XLA emits MXU dots vs VPU mul+reduce respectively.
    """
    *batch, m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    m_eff = int(np.prod(batch, dtype=np.int64)) * m if batch else m
    r = route_matmul(m_eff, k, n, policy=policy)
    out_dtype = out_dtype or x.dtype

    if use_pallas:
        x2 = x.reshape(-1, k)
        if r.path == "vpe":
            from repro.kernels.vpe_smallmm import vpe_matmul

            out = vpe_matmul(x2, w, activation=activation or "none",
                             out_dtype=out_dtype, interpret=interpret)
        else:
            from repro.kernels.arype_matmul import arype_matmul

            out = arype_matmul(x2, w, activation=activation or "none",
                               out_dtype=out_dtype, interpret=interpret)
        return out.reshape(*batch, m, n)

    out = (_vpe_mm(x, w, accum_dtype) if r.path == "vpe"
           else _arype_mm(x, w, accum_dtype))
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation == "silu":
        out = out * jax.nn.sigmoid(out)
    elif activation == "gelu":
        out = jax.nn.gelu(out)
    return out.astype(out_dtype)
