"""The paper's primary contribution, in JAX: heterogeneous routed compute
(VPE/AryPE), collaborative execution, feature extraction, flow tracking, and
the control-domain decision module."""
from repro.core import cold_store, collaborative, decisions, feature_extractor, flow_tracker, router
