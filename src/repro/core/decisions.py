"""Control domain / RV-core analogue (paper §3.4): turn DL inference outputs
into data-plane rule-table updates (paper working-procedure steps 5-6)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

ACTIONS = ("allow", "deny", "mark")


@dataclass
class RuleTable:
    """The switch-facing rule table the control domain maintains."""

    rules: dict[int, dict] = field(default_factory=dict)
    generation: int = 0

    def update(self, flow_ids: np.ndarray, actions: np.ndarray, classes: Optional[np.ndarray] = None):
        self.generation += 1
        for i, fid in enumerate(np.asarray(flow_ids).tolist()):
            fid = int(fid)
            if classes is not None:
                cls = int(classes[i])
            else:  # packet-granularity update: keep the last known flow class
                prev = self.rules.get(fid)
                cls = prev["class"] if prev is not None else -1
            self.rules[fid] = {
                "action": ACTIONS[int(actions[i])],
                "class": cls,
                "generation": self.generation,
            }

    def lookup(self, flow_id: int) -> dict:
        return self.rules.get(int(flow_id), {"action": "allow", "class": -1, "generation": 0})


def decide_binary(logits: jax.Array, deny_threshold: float = 0.5) -> jax.Array:
    """Binary intrusion decision (use-case 1): logits (..., 2) -> 0 allow/1 deny."""
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    return (p[..., 1] > deny_threshold).astype(jnp.int32)


def decide_class(logits: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Classification decision (use-cases 2/3): -> (action=mark, class id)."""
    cls = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.full_like(cls, ACTIONS.index("mark")), cls
