"""Control domain / RV-core analogue (paper §3.4): turn DL inference outputs
into data-plane rule-table updates (paper working-procedure steps 5-6).

The decide step (step 5) is an extension point: a :class:`DecisionHead` maps
what the pipeline computed for one microbatch — the engines' logits and/or
the tracker's drained flow records — to data-plane actions.  Heads declare
``needs_logits``; a head with ``needs_logits == False`` is *feature-only*:
the pipeline skips that engine's inference entirely (the paper's
heavy-hitter-style telemetry use-cases, which never touch the DL domain).

Two head families share the protocol:

  * **packet heads** — ``decide(logits, packets) -> (P,) int32 actions``
    per ingested packet (:class:`BinaryHead`, the original use-case-1
    intrusion decision, and :class:`PassHead`, feature-only allow-all).
  * **flow heads** — ``decide(logits, drained) -> (actions, cls, scores)``
    per drained ready flow, all ``(R,)`` (:class:`ClassHead`, the original
    use-case-2/3 classification; :class:`AnomalyHead`, DDoS-style anomaly
    scoring thresholded into deny; :class:`TopKHead`, feature-only byte
    counters for heavy-hitter ranking).  ``scores`` is the head's float32
    per-flow score (softmax confidence / anomaly score / byte count) —
    surfaced as ``PipelineStepOutput.flow_scores`` for host-side scenario
    controllers (hysteresis, top-k reporting).

Heads are frozen dataclasses: hashable config values, safe inside the
(frozen) ``PipelineConfig`` jit cache key."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flow_features.ops import HIST

ACTIONS = ("allow", "deny", "mark")


@dataclass
class RuleTable:
    """The switch-facing rule table the control domain maintains."""

    rules: dict[int, dict] = field(default_factory=dict)
    generation: int = 0

    def update(self, flow_ids: np.ndarray, actions: np.ndarray, classes: Optional[np.ndarray] = None):
        self.generation += 1
        for i, fid in enumerate(np.asarray(flow_ids).tolist()):
            fid = int(fid)
            if classes is not None:
                cls = int(classes[i])
            else:  # packet-granularity update: keep the last known flow class
                prev = self.rules.get(fid)
                cls = prev["class"] if prev is not None else -1
            self.rules[fid] = {
                "action": ACTIONS[int(actions[i])],
                "class": cls,
                "generation": self.generation,
            }

    def lookup(self, flow_id: int) -> dict:
        return self.rules.get(int(flow_id), {"action": "allow", "class": -1, "generation": 0})


def decide_binary(logits: jax.Array, deny_threshold: float = 0.5) -> jax.Array:
    """Binary intrusion decision (use-case 1): logits (..., 2) -> 0 allow/1 deny."""
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    return (p[..., 1] > deny_threshold).astype(jnp.int32)


def decide_class(logits: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Classification decision (use-cases 2/3): -> (action=mark, class id)."""
    cls = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.full_like(cls, ACTIONS.index("mark")), cls


# ---------------------------------------------------------------------------
# Decision heads — the pluggable step-5 protocol
# ---------------------------------------------------------------------------

@runtime_checkable
class DecisionHead(Protocol):
    """What every head declares: a stable ``name`` (reports/registries) and
    whether the pipeline must run the corresponding engine's inference to
    feed it (``needs_logits``).  Feature-only heads receive ``logits=None``."""

    name: str
    needs_logits: bool


@dataclass(frozen=True)
class BinaryHead:
    """Packet head, use-case 1: softmax the packet engine's 2-way logits and
    deny when the attack-class probability strictly exceeds the threshold
    (``p == deny_threshold`` stays allow — the boundary is regression-tested
    to agree between the f32 and int8-emulate datapaths)."""

    deny_threshold: float = 0.5
    name: str = field(default="binary", init=False)
    needs_logits: bool = field(default=True, init=False)

    def decide(self, logits: jax.Array, packets) -> jax.Array:
        return decide_binary(logits, self.deny_threshold)


@dataclass(frozen=True)
class PassHead:
    """Feature-only packet head: allow every packet, never run the packet
    engine (telemetry scenarios where the per-packet DL verdict is unused)."""

    name: str = field(default="pass", init=False)
    needs_logits: bool = field(default=False, init=False)

    def decide(self, logits, packets) -> jax.Array:
        return jnp.zeros(packets.ts.shape, jnp.int32)


@dataclass(frozen=True)
class ClassHead:
    """Flow head, use-cases 2/3: argmax classification (action ``mark``),
    score = the winning class's softmax confidence."""

    name: str = field(default="class", init=False)
    needs_logits: bool = field(default=True, init=False)

    def decide(self, logits: jax.Array, drained
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
        actions, cls = decide_class(logits)
        p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        return actions, cls, jnp.max(p, axis=-1)


@dataclass(frozen=True)
class AnomalyHead:
    """Flow head, DDoS/anomaly scoring: score = the malicious class's softmax
    probability; ``score >= deny_threshold`` denies the flow, anything else
    marks it with its argmax class.  The raw per-flow scores surface in
    ``flow_scores`` so a host-side controller can add hysteresis (the
    on-device threshold alone would thrash the rule table on flapping
    flows — see ``repro.scenarios.ddos``)."""

    deny_threshold: float = 0.5
    malicious_class: int = 0
    name: str = field(default="anomaly", init=False)
    needs_logits: bool = field(default=True, init=False)

    def decide(self, logits: jax.Array, drained
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
        p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        score = p[..., self.malicious_class]
        cls = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        actions = jnp.where(score >= self.deny_threshold,
                            jnp.int32(ACTIONS.index("deny")),
                            jnp.int32(ACTIONS.index("mark")))
        return actions, cls, score


@dataclass(frozen=True)
class TopKHead:
    """Feature-only flow head, heavy-hitter telemetry: never run the flow
    engine; score every drained flow by its accumulated byte counter (the
    tracker's ``flow_size`` history lane), action ``mark``, class ``-1``
    (no DL verdict).  Resident flows — the other half of the top-k set —
    are read off the tracker state host-side (``repro.scenarios.heavy_hitter``)."""

    name: str = field(default="topk", init=False)
    needs_logits: bool = field(default=False, init=False)

    def decide(self, logits, drained
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
        score = drained.features[..., HIST["flow_size"]].astype(jnp.float32)
        cls = jnp.full(drained.tuple_id.shape, -1, jnp.int32)
        actions = jnp.full(drained.tuple_id.shape, ACTIONS.index("mark"),
                           jnp.int32)
        return actions, cls, score


PKT_HEADS = {"binary": BinaryHead, "pass": PassHead}
FLOW_HEADS = {"class": ClassHead, "anomaly": AnomalyHead, "topk": TopKHead}


def packet_head(name: str, **params) -> DecisionHead:
    """Registry constructor for packet heads (``PKT_HEADS``)."""
    if name not in PKT_HEADS:
        raise ValueError(f"packet head must be one of {tuple(PKT_HEADS)}, "
                         f"got {name!r}")
    return PKT_HEADS[name](**params)


def flow_head(name: str, **params) -> DecisionHead:
    """Registry constructor for flow heads (``FLOW_HEADS``)."""
    if name not in FLOW_HEADS:
        raise ValueError(f"flow head must be one of {tuple(FLOW_HEADS)}, "
                         f"got {name!r}")
    return FLOW_HEADS[name](**params)
