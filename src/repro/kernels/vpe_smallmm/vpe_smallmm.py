"""VPE-path Pallas kernel: small/skinny matmul as broadcast-multiply +
tree-reduce on the VPU, with a fused activation stage.

This is the TPU analogue of the paper's VPE SIMDU (§3.2.1): each sub-lane is a
4-wide multiplier bank feeding an adder tree plus an activation unit, used for
matmuls whose dims are too small to fill the systolic array (the
"under-utilization" regime, e.g. the first CNN layer's (w,3)x(3,32)).

On TPU a matmul with K or N « 128 wastes most of a 128x128 MXU pass; the same
contraction expressed as an elementwise product + lane reduction runs on the
8x128 VPU at full lane utilization.  The kernel keeps the whole (M-block, K, N)
working set in VMEM, multiplies with x broadcast along N, and reduces over K
with ``jnp.sum`` (lowered to the VPU adder tree).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _vpe_kernel(x_ref, w_ref, o_ref, *, activation: str):
    # x_ref: (bm, K), w_ref: (K, N) — K, N small (router guarantees).
    x = x_ref[...].astype(jnp.float32)  # (bm, K)
    w = w_ref[...].astype(jnp.float32)  # (K, N)
    # broadcast-multiply (VPU) then adder-tree reduce over K
    prod = x[:, :, None] * w[None, :, :]  # (bm, K, N)
    out = jnp.sum(prod, axis=1)  # (bm, N)
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation == "silu":
        out = out * jax.nn.sigmoid(out)
    elif activation == "gelu":
        out = jax.nn.gelu(out)
    o_ref[...] = out.astype(o_ref.dtype)


def _vpe_q_kernel(x_ref, w_ref, dq_ref, o_ref, *, activation: str):
    """Int8 variant: integer broadcast-multiply + int32 adder-tree reduce,
    dequant + activation fused at the end — the paper's fixed-point SIMDU
    sub-lane (int multiplier bank, int adder tree, activation unit).
    ``dq_ref`` is the (1, N) per-output-channel dequant row."""
    x = x_ref[...].astype(jnp.int32)  # (bm, K) int8 widened for the MAC
    w = w_ref[...].astype(jnp.int32)  # (K, N)
    prod = x[:, :, None] * w[None, :, :]  # (bm, K, N) exact int32 products
    acc = jnp.sum(prod, axis=1)  # (bm, N) int32
    out = acc.astype(jnp.float32) * dq_ref[0, :]
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation == "silu":
        out = out * jax.nn.sigmoid(out)
    elif activation == "gelu":
        out = jax.nn.gelu(out)
    o_ref[...] = out.astype(o_ref.dtype)


def vpe_mm(
    x: jax.Array,
    w: jax.Array,
    *,
    bm: int = 256,
    activation: str = "none",
    out_dtype=None,
    interpret: bool = True,
) -> jax.Array:
    """x: (M, K) @ w: (K, N), M a multiple of bm (ops.py pads), K*N small."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and m % bm == 0, (x.shape, w.shape, bm)
    kernel = functools.partial(_vpe_kernel, activation=activation)
    return pl.pallas_call(
        kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype or x.dtype),
        interpret=interpret,
    )(x, w)


def vpe_mm_q(
    x_q: jax.Array,
    w_q: jax.Array,
    dequant: jax.Array,
    *,
    bm: int = 256,
    activation: str = "none",
    out_dtype=jnp.float32,
    interpret: bool = True,
) -> jax.Array:
    """Int8 x_q: (M, K) @ w_q: (K, N) with int32 accumulation; M a multiple
    of bm (ops.py pads — zero int8 pads are exact).  ``dequant`` is the
    (1, N) per-output-channel ``scale_x * scale_w`` row."""
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2 and m % bm == 0, (x_q.shape, w_q.shape, bm)
    assert dequant.shape == (1, n), (dequant.shape, n)
    kernel = functools.partial(_vpe_q_kernel, activation=activation)
    return pl.pallas_call(
        kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(x_q, w_q, dequant)
