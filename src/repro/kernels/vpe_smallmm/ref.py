"""Pure-jnp oracle for the VPE small-matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_vpe_matmul(x: jax.Array, w: jax.Array, *, activation: str = "none", out_dtype=None) -> jax.Array:
    out = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation == "silu":
        out = out * jax.nn.sigmoid(out)
    elif activation == "gelu":
        out = jax.nn.gelu(out)
    return out.astype(out_dtype or x.dtype)
