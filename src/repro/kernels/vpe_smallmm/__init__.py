from repro.kernels.vpe_smallmm.ops import vpe_matmul, vpe_matmul_q
from repro.kernels.vpe_smallmm.ref import ref_vpe_matmul
