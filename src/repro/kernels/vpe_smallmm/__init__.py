from repro.kernels.vpe_smallmm.ops import vpe_matmul
from repro.kernels.vpe_smallmm.ref import ref_vpe_matmul
