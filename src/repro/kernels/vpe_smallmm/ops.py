"""Jit'd wrapper for the VPE small-matmul kernel: M-padding + block pick."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.common.util import round_up
from repro.kernels.vpe_smallmm import vpe_smallmm as _k
from repro.runtime import quant as _quant

# VMEM working-set budget for the (bm, K, N) product tile, in fp32 elements.
_VMEM_ELEMS = 1 << 20  # 4 MB


@functools.partial(jax.jit, static_argnames=("activation", "interpret", "out_dtype"))
def vpe_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    activation: str = "none",
    out_dtype=None,
    interpret: bool = True,
) -> jax.Array:
    m, k = x.shape
    _, n = w.shape
    bm = max(8, min(256, _VMEM_ELEMS // max(k * n, 1)))
    bm = max(8, (bm // 8) * 8)
    mp = round_up(m, bm)
    xp = jnp.pad(x, ((0, mp - m), (0, 0))) if mp != m else x
    out = _k.vpe_mm(
        xp, w, bm=bm, activation=activation, out_dtype=out_dtype or x.dtype, interpret=interpret
    )
    return out[:m]


@functools.partial(jax.jit, static_argnames=(
    "scale_x", "scale_w", "activation", "interpret", "out_dtype"))
def vpe_matmul_q(
    x: jax.Array,
    w: jax.Array,
    *,
    scale_x: float,
    scale_w,
    activation: str = "none",
    out_dtype=None,
    interpret: bool = True,
) -> jax.Array:
    """Quantized VPE small-matmul: f32 operands clip-rounded to symmetric
    int8 on the per-layer scales (``scale_w`` a float or a per-output-channel
    tuple), int32 accumulation in the kernel, f32 dequant before the
    activation."""
    m, k = x.shape
    _, n = w.shape
    xq = _quant.quantize_i8(x, scale_x)
    wq = _quant.quantize_i8(w, scale_w)
    dq = jnp.asarray(_quant.dequant_row(scale_x, scale_w, n))[None, :]
    bm = max(8, min(256, _VMEM_ELEMS // max(k * n, 1)))
    bm = max(8, (bm // 8) * 8)
    mp = round_up(m, bm)
    xq = jnp.pad(xq, ((0, mp - m), (0, 0))) if mp != m else xq
    out = _k.vpe_mm_q(
        xq, wq, dq, bm=bm,
        activation=activation, out_dtype=out_dtype or x.dtype, interpret=interpret,
    )
    return out[:m]
