from repro.kernels.arype_matmul.ops import (
    arype_matmul,
    arype_matmul_q,
    arype_matmul_unfused,
)
from repro.kernels.arype_matmul.ref import ref_matmul, ref_quantized_matmul
