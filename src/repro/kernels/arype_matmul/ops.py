"""Jit'd public wrappers for the AryPE matmul kernel: padding to MXU-aligned
blocks, dtype handling, fused-vs-unfused (collaborative ablation) entry points.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.common.util import round_up
from repro.kernels.arype_matmul import arype_matmul as _k
from repro.runtime import quant as _quant


def _pad2(x: jax.Array, m: int, n: int) -> jax.Array:
    pm, pn = m - x.shape[0], n - x.shape[1]
    if pm == 0 and pn == 0:
        return x
    return jnp.pad(x, ((0, pm), (0, pn)))


def _pick_blocks(m: int, k: int, n: int) -> tuple[int, int, int]:
    # MXU-aligned where possible; shrink for small problems so padding waste
    # stays bounded (the router should already have sent tiny shapes to VPE).
    bm = 128 if m >= 128 else max(8, round_up(m, 8))
    bn = 128 if n >= 128 else max(128, round_up(n, 128))  # lane dim stays 128
    bk = 128 if k >= 128 else max(128, round_up(k, 128))
    return bm, min(bn, 128), min(bk, 128)


@functools.partial(jax.jit, static_argnames=("activation", "interpret", "out_dtype"))
def arype_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    activation: str = "none",
    out_dtype=None,
    interpret: bool = True,
) -> jax.Array:
    """(M, K) @ (K, N) with fused K-block accumulation (collaborative mode)."""
    m, k = x.shape
    _, n = w.shape
    bm, bn, bk = _pick_blocks(m, k, n)
    mp, kp, np_ = round_up(m, bm), round_up(k, bk), round_up(n, bn)
    xp, wp = _pad2(x, mp, kp), _pad2(w, kp, np_)
    out = _k.mm_fused(
        xp, wp, bm=bm, bn=bn, bk=bk, activation=activation,
        out_dtype=out_dtype or x.dtype, interpret=interpret,
    )
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=(
    "scale_x", "scale_w", "activation", "interpret", "out_dtype"))
def arype_matmul_q(
    x: jax.Array,
    w: jax.Array,
    *,
    scale_x: float,
    scale_w,
    activation: str = "none",
    out_dtype=None,
    interpret: bool = True,
) -> jax.Array:
    """Quantized (M, K) @ (K, N): f32 operands clip-rounded to symmetric int8
    on the given per-layer scales (``scale_w`` a float or a per-output-channel
    tuple), contracted with fused int32 accumulation, dequantized to
    ``out_dtype`` before the activation.  Scales are static — they come from
    a calibration artifact and are fixed per layer."""
    m, k = x.shape
    _, n = w.shape
    xq = _quant.quantize_i8(x, scale_x)
    wq = _quant.quantize_i8(w, scale_w)
    dq = jnp.asarray(_quant.dequant_row(scale_x, scale_w, n))[None, :]
    bm, bn, bk = _pick_blocks(m, k, n)
    mp, kp, np_ = round_up(m, bm), round_up(k, bk), round_up(n, bn)
    xq, wq = _pad2(xq, mp, kp), _pad2(wq, kp, np_)
    dq = _pad2(dq, 1, np_)
    out = _k.mm_fused_q(
        xq, wq, dq, bm=bm, bn=bn, bk=bk,
        activation=activation, out_dtype=out_dtype or x.dtype, interpret=interpret,
    )
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("activation", "interpret", "out_dtype"))
def arype_matmul_unfused(
    x: jax.Array,
    w: jax.Array,
    *,
    activation: str = "none",
    out_dtype=None,
    interpret: bool = True,
) -> jax.Array:
    """'wo/ collaborating' ablation: partial K-blocks written to HBM, then a
    separate aggregation pass (paper Table 6 baseline)."""
    m, k = x.shape
    _, n = w.shape
    bm, bn, bk = _pick_blocks(m, k, n)
    mp, kp, np_ = round_up(m, bm), round_up(k, bk), round_up(n, bn)
    xp, wp = _pad2(x, mp, kp), _pad2(w, kp, np_)
    partials = _k.mm_unfused_partials(xp, wp, bm=bm, bn=bn, bk=bk, interpret=interpret)
    out = partials.sum(axis=0)  # separate aggregation pass (the VU's job, serialized)
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation == "silu":
        out = out * jax.nn.sigmoid(out)
    elif activation == "gelu":
        out = jax.nn.gelu(out)
    return out[:m, :n].astype(out_dtype or x.dtype)
