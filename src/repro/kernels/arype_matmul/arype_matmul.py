"""AryPE-path Pallas kernel: MXU-aligned blocked matmul with *fused* K-block
accumulation in VMEM scratch.

This is the TPU-native analogue of the paper's heterogeneous collaborative
computing (§3.2.3): on the FPGA, AryPE streams (l,k)x(k,k) tiles while the
VPE's vector unit aggregates partial blocks through an on-chip ping-pong
buffer, so the systolic array never stalls.  On TPU the same property is
obtained by carrying the partial block in a VMEM accumulator across the K grid
dimension (``acc_ref``): partial blocks never round-trip to HBM, and Pallas's
grid pipelining overlaps the next tile's HBM->VMEM copy with the current MXU
pass (the ping-pong buffer).

The *unfused* variant (`arype_matmul_unfused` in ops.py) reproduces the
paper's "wo/ collaborating" ablation: every K-block partial is written back to
HBM and aggregated in a separate pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_fused_kernel(x_ref, w_ref, o_ref, acc_ref, *, activation: str, n_k: int):
    """grid = (M/bm, N/bn, K/bk); K innermost so acc_ref revolves in VMEM."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        out = acc_ref[...]
        if activation == "relu":
            out = jnp.maximum(out, 0.0)
        elif activation == "silu":
            out = out * jax.nn.sigmoid(out)
        elif activation == "gelu":
            out = jax.nn.gelu(out)
        o_ref[...] = out.astype(o_ref.dtype)


def _mm_partial_kernel(x_ref, w_ref, o_ref):
    """Unfused ablation: each (i, j, l) grid cell writes its own partial block
    to HBM (out has a leading K-blocks dim); aggregation is a separate pass."""
    o_ref[0, :, :] = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


def mm_fused(
    x: jax.Array,
    w: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    activation: str = "none",
    out_dtype=None,
    interpret: bool = True,
) -> jax.Array:
    """x: (M, K) @ w: (K, N) -> (M, N).  Dims must be multiples of the blocks
    (ops.py pads).  ``interpret=True`` on CPU; on a real TPU pass False."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (x.shape, w.shape, bm, bn, bk)
    n_k = k // bk
    kernel = functools.partial(_mm_fused_kernel, activation=activation, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype or x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)


def mm_unfused_partials(
    x: jax.Array,
    w: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Returns partial blocks (K/bk, M, N) in fp32 — the 'wo/ collaborating'
    ablation where block aggregation is a separate HBM pass."""
    m, k = x.shape
    _, n = w.shape
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    return pl.pallas_call(
        _mm_partial_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda i, j, l: (l, i, j)),
        out_shape=jax.ShapeDtypeStruct((k // bk, m, n), jnp.float32),
        interpret=interpret,
    )(x, w)
