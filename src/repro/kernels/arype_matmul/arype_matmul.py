"""AryPE-path Pallas kernel: MXU-aligned blocked matmul with *fused* K-block
accumulation in VMEM scratch.

This is the TPU-native analogue of the paper's heterogeneous collaborative
computing (§3.2.3): on the FPGA, AryPE streams (l,k)x(k,k) tiles while the
VPE's vector unit aggregates partial blocks through an on-chip ping-pong
buffer, so the systolic array never stalls.  On TPU the same property is
obtained by carrying the partial block in a VMEM accumulator across the K grid
dimension (``acc_ref``): partial blocks never round-trip to HBM, and Pallas's
grid pipelining overlaps the next tile's HBM->VMEM copy with the current MXU
pass (the ping-pong buffer).

The *unfused* variant (`arype_matmul_unfused` in ops.py) reproduces the
paper's "wo/ collaborating" ablation: every K-block partial is written back to
HBM and aggregated in a separate pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_fused_kernel(x_ref, w_ref, o_ref, acc_ref, *, activation: str, n_k: int):
    """grid = (M/bm, N/bn, K/bk); K innermost so acc_ref revolves in VMEM."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        out = acc_ref[...]
        if activation == "relu":
            out = jnp.maximum(out, 0.0)
        elif activation == "silu":
            out = out * jax.nn.sigmoid(out)
        elif activation == "gelu":
            out = jax.nn.gelu(out)
        o_ref[...] = out.astype(o_ref.dtype)


def _mm_fused_q_kernel(x_ref, w_ref, dq_ref, o_ref, acc_ref, *, activation: str,
                       n_k: int):
    """Int8 variant of the fused kernel: int8 operand tiles, int32 VMEM
    accumulator across the K grid, dequant + activation in the epilogue.
    Mirrors the paper's fixed-point AryPE datapath (int MACs, one scale
    multiply on the way out).  ``dq_ref`` is the (1, bn) dequant row —
    ``scale_x * scale_w`` per output channel."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.int32
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        out = acc_ref[...].astype(jnp.float32) * dq_ref[0, :]
        if activation == "relu":
            out = jnp.maximum(out, 0.0)
        elif activation == "silu":
            out = out * jax.nn.sigmoid(out)
        elif activation == "gelu":
            out = jax.nn.gelu(out)
        o_ref[...] = out.astype(o_ref.dtype)


def _mm_partial_kernel(x_ref, w_ref, o_ref):
    """Unfused ablation: each (i, j, l) grid cell writes its own partial block
    to HBM (out has a leading K-blocks dim); aggregation is a separate pass."""
    o_ref[0, :, :] = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


def mm_fused(
    x: jax.Array,
    w: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    activation: str = "none",
    out_dtype=None,
    interpret: bool = True,
) -> jax.Array:
    """x: (M, K) @ w: (K, N) -> (M, N).  Dims must be multiples of the blocks
    (ops.py pads).  ``interpret=True`` on CPU; on a real TPU pass False."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (x.shape, w.shape, bm, bn, bk)
    n_k = k // bk
    kernel = functools.partial(_mm_fused_kernel, activation=activation, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype or x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)


def mm_fused_q(
    x_q: jax.Array,
    w_q: jax.Array,
    dequant: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    activation: str = "none",
    out_dtype=jnp.float32,
    interpret: bool = True,
) -> jax.Array:
    """Int8 x_q: (M, K) @ w_q: (K, N) -> f32-ish (M, N), int32 accumulation.

    ``dequant`` is the (1, N) per-output-channel ``scale_x * scale_w`` row;
    integer accumulation is exact, so block tiling/padding cannot perturb the
    result (zero int8 pads contribute zero int32 products)."""
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2, (x_q.shape, w_q.shape)
    assert dequant.shape == (1, n), (dequant.shape, n)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (x_q.shape, w_q.shape, bm, bn, bk)
    n_k = k // bk
    kernel = functools.partial(_mm_fused_q_kernel, activation=activation, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
            pl.BlockSpec((1, bn), lambda i, j, l: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, dequant)


def mm_unfused_partials(
    x: jax.Array,
    w: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Returns partial blocks (K/bk, M, N) in fp32 — the 'wo/ collaborating'
    ablation where block aggregation is a separate HBM pass."""
    m, k = x.shape
    _, n = w.shape
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    return pl.pallas_call(
        _mm_partial_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda i, j, l: (l, i, j)),
        out_shape=jax.ShapeDtypeStruct((k // bk, m, n), jnp.float32),
        interpret=interpret,
    )(x, w)
