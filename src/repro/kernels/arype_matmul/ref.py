"""Pure-jnp oracle for the AryPE matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_matmul(x: jax.Array, w: jax.Array, *, activation: str = "none", out_dtype=None) -> jax.Array:
    out = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32), preferred_element_type=jnp.float32)
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation == "silu":
        out = out * jax.nn.sigmoid(out)
    elif activation == "gelu":
        out = jax.nn.gelu(out)
    return out.astype(out_dtype or x.dtype)


def ref_quantized_matmul(x, w, *, scale_x: float, scale_w,
                         activation: str = "none"):
    """NumPy int32 oracle for the quantized engine paths: symmetric clip-round
    to int8 codes, exact int32 accumulation, f32 dequant, then activation.
    ``scale_w`` is a float or a per-output-channel tuple.  Integer
    accumulation is order-independent, so every tiling/padding of the kernel
    must match this bit-for-bit."""
    import numpy as np

    # Quantize in f32 exactly like the kernels do: the f64 division can round
    # the other way on ties, which would make the oracle spuriously off-by-one.
    sw = np.asarray(scale_w, np.float32)
    xq = np.clip(np.round(np.asarray(x, np.float32) / np.float32(scale_x)),
                 -127, 127).astype(np.int64)
    wq = np.clip(np.round(np.asarray(w, np.float32) / sw),
                 -127, 127).astype(np.int64)
    out = (xq @ wq).astype(np.float32) * (np.float32(scale_x) * sw)
    if activation == "relu":
        out = np.maximum(out, 0.0)
    return jnp.asarray(out)
