"""Flow-feature ALU-cluster Pallas kernel (paper §3.1).

The FPGA feature extractor keeps an 8k-entry flow-state table; for each packet
a 16-lane ALU cluster folds the packet's *meta register* into the flow's
*history register* with per-lane micro-ops {nop, wr, add, sub, max, min, inc}.

TPU adaptation: the whole flow-state table (8192 x 16 int32 = 512 KB) is VMEM
resident; packets stream through the grid in blocks; within a block the kernel
walks packets with ``fori_loop`` (updates to the same flow must be ordered —
this is the inherently sequential part the FPGA pipelines at line rate).  The
16 feature lanes update vectorized, mirroring the 16 parallel ALUs.

Micro-op encoding per lane j (program row j = [opcode, meta_src, hist_src]):
  0 nop : out = hist[hist_src]
  1 wr  : out = meta[meta_src]
  2 add : out = hist[hist_src] + meta[meta_src]
  3 sub : out = hist[hist_src] - meta[meta_src]
  4 max : out = max(hist[hist_src], meta[meta_src])
  5 min : out = min(hist[hist_src], meta[meta_src])
  6 inc : out = hist[hist_src] + 1
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

N_LANES = 16


def apply_alu_program(program: jax.Array, meta: jax.Array, hist: jax.Array) -> jax.Array:
    """Vectorized 16-lane ALU cluster.  program: (16, 3) int32; meta: (M,) int32;
    hist: (16,) int32 -> new hist (16,) int32."""
    opcode = program[:, 0]
    a = jnp.take(meta, program[:, 1], axis=0)  # meta source per lane
    b = jnp.take(hist, program[:, 2], axis=0)  # history source per lane
    return jnp.select(
        [opcode == 0, opcode == 1, opcode == 2, opcode == 3, opcode == 4, opcode == 5, opcode == 6],
        [b, a, b + a, b - a, jnp.maximum(b, a), jnp.minimum(b, a), b + 1],
        default=b,
    ).astype(jnp.int32)


def _flow_kernel(program_ref, slots_ref, meta_ref, init_state_ref, state_ref, *, block: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        state_ref[...] = init_state_ref[...]

    program = program_ref[...]

    def body(i, _):
        slot = slots_ref[i]
        hist = pl.load(state_ref, (pl.dslice(slot, 1), slice(None)))[0]
        meta = meta_ref[i, :]
        new = apply_alu_program(program, meta, hist)
        pl.store(state_ref, (pl.dslice(slot, 1), slice(None)), new[None, :])
        return 0

    lax.fori_loop(0, block, body, 0)


def flow_update(
    program: jax.Array,  # (16, 3) int32
    slots: jax.Array,  # (P,) int32 flow-table row per packet
    meta: jax.Array,  # (P, M) int32 meta registers
    init_state: jax.Array,  # (F, 16) int32 flow-state table
    *,
    block: int = 256,
    interpret: bool = True,
) -> jax.Array:
    p, m_width = meta.shape
    f = init_state.shape[0]
    assert p % block == 0, (p, block)
    kernel = functools.partial(_flow_kernel, block=block)
    return pl.pallas_call(
        kernel,
        grid=(p // block,),
        in_specs=[
            pl.BlockSpec((N_LANES, 3), lambda i: (0, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block, m_width), lambda i: (i, 0)),
            pl.BlockSpec((f, N_LANES), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((f, N_LANES), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((f, N_LANES), jnp.int32),
        interpret=interpret,
    )(program, slots, meta, init_state)
