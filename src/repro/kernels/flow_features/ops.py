"""Jit'd wrapper for the flow-feature kernel + the standard micro-op programs
that derive the paper's whole feature set (Table 7) from the meta set (Table 2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.util import round_up
from repro.kernels.flow_features import flow_features as _k

# Meta register layout (int32 lanes; paper: 13-byte register, see DESIGN.md for
# the 8-bit -> 32-bit lane adaptation).
META = {
    "pkt_size": 0,
    "arv_intv": 1,  # inter-arrival time (us); 0 for the first packet of a flow
    "dir": 2,  # 0/1
    "flags": 3,  # TCP/UDP/ICMP flags
    "ts": 4,  # arrival timestamp (us, truncated)
    "payload_len": 5,
    "one": 6,  # constant 1
    "zero": 7,  # constant 0
    "size_fwd": 8,  # pkt_size if dir==0 else 0
    "size_bwd": 9,  # pkt_size if dir==1 else 0
    "neg_pkt_size": 10,
    "neg_arv_intv": 11,
    "proto": 12,
}
META_WIDTH = 13

MICRO_OPS = {"nop": 0, "wr": 1, "add": 2, "sub": 3, "max": 4, "min": 5, "inc": 6}

# History-register (flow-state word) layout: 16 int32 lanes.
HIST = {
    "flow_dur": 0,  # sum of arv_intv                     (Table 7: #9)
    "pkt_count": 1,  # total number of packets            (#36)
    "flow_size": 2,  # sum of pkt_size                    (#6)
    "max_size": 3,  # max packet length                   (#11)
    "min_size": 4,  # min packet length                   (#12)
    "max_intv": 5,  # max inter-arrival                   (#19)
    "min_intv": 6,  # min inter-arrival                   (#20)
    "last_ts": 7,  # timestamp of latest packet (tracker state)
    "size_fwd": 8,  # per-direction flow size             (#7)
    "size_bwd": 9,
    "flags_acc": 10,  # accumulated flags                 (#28)
    "last_size": 11,
    "payload_bytes": 12,  # sum of payload_len            (#1-ish)
    "proto": 13,  # protocol type                         (#8)
    "spare14": 14,
    "spare15": 15,
}


def default_program_np() -> np.ndarray:
    """Host-side (numpy) twin of :func:`default_program` — usable inside jit
    traces for program-identity checks without creating traced constants."""
    O, M, H = MICRO_OPS, META, HIST
    rows = [
        (O["add"], M["arv_intv"], H["flow_dur"]),
        (O["inc"], M["zero"], H["pkt_count"]),
        (O["add"], M["pkt_size"], H["flow_size"]),
        (O["max"], M["pkt_size"], H["max_size"]),
        (O["min"], M["pkt_size"], H["min_size"]),
        (O["max"], M["arv_intv"], H["max_intv"]),
        (O["min"], M["arv_intv"], H["min_intv"]),
        (O["wr"], M["ts"], H["last_ts"]),
        (O["add"], M["size_fwd"], H["size_fwd"]),
        (O["add"], M["size_bwd"], H["size_bwd"]),
        (O["add"], M["flags"], H["flags_acc"]),
        (O["wr"], M["pkt_size"], H["last_size"]),
        (O["add"], M["payload_len"], H["payload_bytes"]),
        (O["wr"], M["proto"], H["proto"]),
        (O["nop"], M["zero"], H["spare14"]),
        (O["nop"], M["zero"], H["spare15"]),
    ]
    return np.array(rows, dtype=np.int32)


def default_program() -> jax.Array:
    """The micro-op program deriving the standard flow features (Table 7
    subset) from the meta set — one row per output lane: [op, meta_src, hist_src]."""
    return jnp.asarray(default_program_np())


def fold_features(
    program: jax.Array,
    slots: jax.Array,
    meta: jax.Array,
    feats: jax.Array,
    *,
    keep: jax.Array | None = None,
    block: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Fold a packet stream into a (F, 16) feature table through the Pallas
    ALU-cluster kernel, optionally dropping packets.

    ``keep`` (when given) is a (P,) bool mask: packets with ``keep == False``
    are redirected to a scratch row appended to the table, so they cannot
    touch any real flow's state (``wr``/``min`` lanes would otherwise corrupt
    it — zeroed meta is *not* a no-op).  This is how the tracker paths replay
    only the packets after a flow's last establish/evict event."""
    f = feats.shape[0]
    block = max(1, min(block, slots.shape[0]))
    if keep is None:
        return flow_feature_update(program, slots, meta, feats, block=block,
                                   interpret=interpret)
    ext = jnp.concatenate([feats, jnp.zeros((1, feats.shape[1]), jnp.int32)])
    out = flow_feature_update(program, jnp.where(keep, slots, f), meta, ext,
                              block=block, interpret=interpret)
    return out[:f]


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def flow_feature_update(
    program: jax.Array,
    slots: jax.Array,
    meta: jax.Array,
    init_state: jax.Array,
    *,
    block: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Fold a packet stream into the flow-state table.  Pads the packet axis
    with no-op packets (slot pointing at a scratch row)."""
    p = slots.shape[0]
    f = init_state.shape[0]
    pp = round_up(max(p, 1), block)
    if pp == p:
        return _k.flow_update(program, slots, meta, init_state, block=block,
                              interpret=interpret)
    # pad with packets aimed at a dedicated scratch row appended to the table
    # (so 'wr'/'add' lanes never corrupt a real flow's state)
    pad = pp - p
    slots = jnp.concatenate([slots, jnp.full((pad,), f, jnp.int32)])
    meta = jnp.concatenate([meta, jnp.zeros((pad, meta.shape[1]), jnp.int32)])
    state_ext = jnp.concatenate([init_state, jnp.zeros((1, init_state.shape[1]),
                                                       jnp.int32)])
    out = _k.flow_update(program, slots, meta, state_ext, block=block,
                         interpret=interpret)
    return out[:f]
