"""Pure-jnp oracle for the flow-feature ALU kernel: a lax.scan over packets."""
from __future__ import annotations

import jax
from jax import lax

from repro.kernels.flow_features.flow_features import apply_alu_program


def ref_flow_feature_update(
    program: jax.Array, slots: jax.Array, meta: jax.Array, init_state: jax.Array
) -> jax.Array:
    def step(state, packet):
        slot, m = packet
        hist = state[slot]
        new = apply_alu_program(program, m, hist)
        return state.at[slot].set(new), None

    state, _ = lax.scan(step, init_state, (slots, meta))
    return state
