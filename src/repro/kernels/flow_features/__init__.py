from repro.kernels.flow_features.ops import flow_feature_update, MICRO_OPS
from repro.kernels.flow_features.ref import ref_flow_feature_update
