"""Naive-attention oracle (materializes the full score matrix; test shapes only)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def ref_attention(
    q: jax.Array,  # (BH, Sq, D)
    k: jax.Array,
    v: jax.Array,
    *,
    mask: str = "causal",
    window: int = 0,
    kv_len: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    bh, sq, d = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / (d**0.5)
    kv_len = kv_len if kv_len is not None else sk
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32) * scale, k.astype(jnp.float32))
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    valid = kpos < kv_len
    if mask == "causal":
        valid &= qpos >= kpos
    elif mask == "local":
        valid &= (qpos >= kpos) & (qpos - kpos < window)
    s = jnp.where(valid[None], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.where(valid[None], jnp.exp(s - m), 0.0)
    l = p.sum(axis=-1, keepdims=True)
    l = jnp.where(l == 0.0, 1.0, l)
    out = jnp.einsum("bqk,bkd->bqd", p / l, v.astype(jnp.float32))
    return out.astype(q.dtype)
