"""Jit'd wrapper: GQA head handling, seq padding, block-size pick."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.common.util import round_up
from repro.kernels.flash_attention import flash_attention as _k


@functools.partial(
    jax.jit, static_argnames=("mask", "window", "kv_len", "interpret", "bq", "bk")
)
def flash_attention(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Sk, D)
    v: jax.Array,  # (B, Hkv, Sk, D)
    *,
    mask: str = "causal",
    window: int = 0,
    kv_len: int | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    # broadcast kv heads for GQA, fold heads into batch
    if g > 1:
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hq, sk, d)
    vf = v.reshape(b * hq, sk, d)
    bq_ = min(bq, sq)
    bk_ = min(bk, sk)
    sqp, skp = round_up(sq, bq_), round_up(sk, bk_)
    kv_len_eff = kv_len if kv_len is not None else sk
    if sqp != sq:
        qf = jnp.pad(qf, ((0, 0), (0, sqp - sq), (0, 0)))
    if skp != sk:
        kf = jnp.pad(kf, ((0, 0), (0, skp - sk), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, skp - sk), (0, 0)))
    out = _k.flash_fwd(
        qf, kf, vf, mask=mask, window=window, kv_len=kv_len_eff,
        bq=bq_, bk=bk_, interpret=interpret,
    )
    return out[:, :sq].reshape(b, hq, sq, d)
