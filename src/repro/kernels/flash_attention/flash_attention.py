"""Flash-attention forward Pallas kernel (online softmax, block-skipping).

Octopus connection: the paper's collaborative mode exists to keep the systolic
array streaming while partial-block aggregation happens elsewhere (§3.2.3).
Attention's softmax normalizer is exactly such an aggregation; the online
softmax carried in VMEM scratch (m/l/acc revolving over KV blocks) is the same
"never stall, never round-trip partials to HBM" structure, applied to the
(QK^T)V pipeline.  Causal/local block skipping implements the router's
utilization rule at the attention-block level: fully-masked MXU passes are not
issued at all.

Supported masks: "causal", "local" (sliding window, causal), "full" (bidir).
GQA is handled by the ops.py wrapper (kv head broadcast).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, mask: str, window: int, bq: int, bk: int, scale: float, n_k: int, kv_len: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk

    if mask == "causal":
        relevant = k_start <= q_start + bq - 1
    elif mask == "local":
        relevant = (k_start <= q_start + bq - 1) & (k_start + bk - 1 >= q_start - window + 1)
    else:
        relevant = k_start >= 0  # always true (traced-compatible)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale  # (bq, d)
        k = k_ref[0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0].astype(jnp.float32)  # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = kpos < kv_len
        if mask == "causal":
            valid &= qpos >= kpos
        elif mask == "local":
            valid &= (qpos >= kpos) & (qpos - kpos < window)
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[...]  # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)  # (bq, bk); masked -> 0
        #   (without the where, fully-masked rows hit exp(-inf - -inf) = 1)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> zeros, not NaN
        o_ref[0, :, :] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_fwd(
    q: jax.Array,  # (BH, Sq, D)
    k: jax.Array,  # (BH, Sk, D)
    v: jax.Array,  # (BH, Sk, D)
    *,
    mask: str = "causal",
    window: int = 0,
    kv_len: int | None = None,
    bq: int = 128,
    bk: int = 128,
    scale: float | None = None,
    interpret: bool = True,
) -> jax.Array:
    bh, sq, d = q.shape
    _, sk, _ = k.shape
    assert sq % bq == 0 and sk % bk == 0, (q.shape, k.shape, bq, bk)
    n_k = sk // bk
    scale = scale if scale is not None else 1.0 / (d**0.5)
    kv_len = kv_len if kv_len is not None else sk
    kernel = functools.partial(
        _flash_kernel, mask=mask, window=window, bq=bq, bk=bk,
        scale=scale, n_k=n_k, kv_len=kv_len,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, sq // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
