from repro.common.util import (
    ceil_div,
    round_up,
    tree_bytes,
    tree_param_count,
    fold_in_str,
    product,
)
