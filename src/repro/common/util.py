"""Small shared utilities: shape math, pytree accounting, rng helpers."""
from __future__ import annotations

import hashlib
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return ceil_div(a, b) * b


def product(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


def tree_param_count(tree: Any) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree) if hasattr(x, "shape"))


def tree_bytes(tree: Any) -> int:
    total = 0
    for x in jax.tree.leaves(tree):
        if hasattr(x, "shape") and hasattr(x, "dtype"):
            total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return total


def fold_in_str(key: jax.Array, s: str) -> jax.Array:
    """Deterministically fold a string into a PRNG key (stable across runs)."""
    h = int.from_bytes(hashlib.sha256(s.encode()).digest()[:4], "little")
    return jax.random.fold_in(key, h)


def human_bytes(n: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if abs(n) < 1024.0 or unit == "PB":
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n:.2f}PB"


def human_flops(n: float) -> str:
    for unit in ("", "K", "M", "G", "T", "P", "E"):
        if abs(n) < 1000.0 or unit == "E":
            return f"{n:.2f}{unit}FLOP"
        n /= 1000.0
    return f"{n:.2f}EFLOP"


def log2_int(n: int) -> int:
    assert n > 0 and (n & (n - 1)) == 0, f"{n} is not a power of two"
    return int(math.log2(n))
