"""The streaming in-network serving pipeline (paper §2.3 working procedure).

One continuous loop over packet microbatches — the paper's steps 1 -> 6 —
instead of the isolated per-call paths:

  1. parse        — ingest a :class:`PacketBatch` microbatch (the parser's
                    struct-of-arrays output; see ``repro.data.traffic``)
  2. track        — merge the batch into the hash-indexed flow table.  The
                    default tracker is the *segmented* update
                    (:func:`feature_extractor.segmented_update`): one
                    vectorized pass over the whole microbatch — sort by slot,
                    segment-reduce the feature lanes, rank-scatter the
                    series/payload memories — exactly how the paper's
                    extractor reaches 31 Mpkt/s by processing packets in
                    parallel.  In-batch slot collisions fall back to the
                    order-exact scan oracle per slot, so the result is always
                    bit-identical to ``tracker="scan"``
                    (:func:`flow_tracker.process_packets`, the FPGA's serial
                    semantics, kept as the differential reference).
  3. extract      — drain up to ``max_ready`` ready flows (count >= top_n)
                    from the table and recycle their slots
                    (:func:`flow_tracker.drain_ready`)
  4. infer        — per-packet metadata -> :class:`PacketEngine` (latency/VPE
                    side); emitted flow memories -> :class:`FlowEngine`
                    (throughput/AryPE side), both under the one runtime
                    config captured at construction
  5. decide       — logits -> allow/deny + class ids
  6. feed back    — decisions update the switch-facing rule table

Steps 2-5 compile into a single jit'd step whose :class:`TrackerState` is
donated — state flows across microbatches without copies.  All output shapes
are static (``batch_size`` packets in, ``max_ready`` masked flow rows out),
so the step is scan-friendly *and scanned*: with ``scan_len > 1`` the
pipeline dispatches ``scan_len`` microbatches per jit call (``lax.scan`` over
the fused step, donated carry, stacked drain outputs), amortizing the host
round-trip that otherwise dominates small-batch throughput.  Rule-table
feedback (step 6, host side) is then applied once per chunk, in step order —
decisions lag the wire by at most ``scan_len`` microbatches, the price of
dispatch amortization.  After warmup no call retraces (``trace_count`` stays
1; asserted in tests).

With ``overlap=True`` the loop goes one step further and stops serializing
host work with device work: ``step``/``step_many`` return an
:class:`InflightDispatch` handle immediately after *enqueueing* the jit call
(JAX dispatches asynchronously — the arrays come back as futures), and
``run`` becomes a double-buffered producer/consumer that stages chunk k+1
(batch pull, stacking, sharded ``partition_batch`` hashing) while chunk k
executes, waiting handles strictly in dispatch order.  Rule-table feedback
runs inside ``wait()`` — lagged by the one in-flight chunk but applied in
step order, so the run is bit-identical to the eager loop (differentially
tested).  :class:`PipelineStats` splits ``host_us`` vs ``device_us`` per
dispatch so the overlap is measured, not claimed: ``device_us`` is the
*exposed* device wait (what the host actually blocked on), which shrinks as
staging hides under execution.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Iterable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import cold_store
from repro.core import decisions
from repro.core import feature_extractor as fx
from repro.core import flow_tracker as ft
from repro.core.feature_extractor import packet_meta_features
from repro.kernels.flow_features.ops import default_program
from repro.models import paper_models
from repro.runtime import RoutePlan, RuntimeConfig, name_scope, resolve_config
from repro.serving.packet_path import FLOW_MODELS, FlowEngine, PacketEngine

TRACKERS = ("segmented", "scan")


@dataclass(frozen=True)
class PipelineConfig:
    """Static shapes + thresholds of the streaming loop (jit cache keys)."""

    batch_size: int = 32  # packets per microbatch (step granularity)
    max_ready: int = 8  # ready-flow rows drained per step
    flow_model: str = "cnn"  # "cnn" | "transformer"
    table_size: int = 1024  # flow-state table depth (paper: 8192)
    top_n: int = paper_models.CNN_SEQ  # ready threshold / series depth
    top_k: int = paper_models.TF_PKTS  # payload rows per flow
    pay_bytes: int = paper_models.TF_BYTES  # payload bytes per packet
    tracker: str = "segmented"  # "segmented" (vectorized) | "scan" (oracle)
    scan_len: int = 1  # microbatches fused per dispatch (lax.scan length)
    overlap: bool = False  # deferred-sync dispatch: step/step_many return an
    # InflightDispatch handle; run() double-buffers over it
    cold_size: int = 0  # second-level (cold) flow table slots; 0 disables
    cold_policy: str = "age"  # cold eviction policy: "age" | "lru"
    deny_threshold: float = 0.5  # default BinaryHead packet-deny threshold
    pkt_head: Optional[Any] = None  # packet DecisionHead (None -> BinaryHead)
    flow_head: Optional[Any] = None  # flow DecisionHead (None -> ClassHead)

    def __post_init__(self):
        # resolve the default heads here (not in the pipeline) so the frozen
        # config compares/hashes by the heads it will actually run with, and
        # deny_threshold reaches the default head exactly once
        if self.pkt_head is None:
            object.__setattr__(self, "pkt_head",
                               decisions.BinaryHead(self.deny_threshold))
        if self.flow_head is None:
            object.__setattr__(self, "flow_head", decisions.ClassHead())
        for role, head in (("pkt_head", self.pkt_head),
                           ("flow_head", self.flow_head)):
            if not isinstance(head, decisions.DecisionHead):
                raise ValueError(f"{role} must implement DecisionHead "
                                 f"(name + needs_logits), got {head!r}")
        if self.flow_model not in FLOW_MODELS:
            raise ValueError(f"flow_model must be one of {FLOW_MODELS}, "
                             f"got {self.flow_model!r}")
        if self.tracker not in TRACKERS:
            raise ValueError(f"tracker must be one of {TRACKERS}, "
                             f"got {self.tracker!r}")
        if self.batch_size <= 0 or not 0 < self.max_ready <= self.table_size:
            raise ValueError("batch_size and max_ready must be positive "
                             "(max_ready <= table_size)")
        if self.scan_len <= 0:
            raise ValueError(f"scan_len must be positive, got {self.scan_len}")
        if self.cold_size < 0:
            raise ValueError(f"cold_size must be >= 0, got {self.cold_size}")
        if self.cold_policy not in cold_store.COLD_POLICIES:
            raise ValueError(f"cold_policy must be one of "
                             f"{cold_store.COLD_POLICIES}, "
                             f"got {self.cold_policy!r}")
        # the flow engine consumes the tracker memories directly — their
        # depths must match the model's fixed input geometry.  A feature-only
        # flow head never runs the engine, so the tracker geometry is free
        # (heavy-hitter configs shrink top_n to tune the drain threshold).
        if not self.flow_head.needs_logits:
            return
        if self.flow_model == "cnn" and self.top_n != paper_models.CNN_SEQ:
            raise ValueError(f"cnn flow model needs top_n == {paper_models.CNN_SEQ} "
                             f"(got {self.top_n})")
        if self.flow_model == "transformer" and (
                self.top_k != paper_models.TF_PKTS
                or self.pay_bytes != paper_models.TF_BYTES):
            raise ValueError(
                f"transformer flow model needs top_k == {paper_models.TF_PKTS} and "
                f"pay_bytes == {paper_models.TF_BYTES} "
                f"(got {self.top_k}/{self.pay_bytes})")


class PipelineStepOutput(NamedTuple):
    """Device-side outputs of one fused step (static shapes).  Chunked
    dispatch (``step_many``) returns the same tuple with a leading
    ``scan_len`` axis on every leaf."""

    pkt_actions: jax.Array  # (batch_size,) int32 0 allow / 1 deny
    drained: ft.DrainResult  # max_ready rows + mask
    flow_actions: jax.Array  # (max_ready,) int32
    flow_cls: jax.Array  # (max_ready,) int32
    flow_scores: jax.Array  # (max_ready,) float32 — the flow head's score
    new_flows: jax.Array  # () int32 — flows established this step
    evicted: jax.Array  # () int32 — stale flows recycled by collision
    spilled: jax.Array  # () int32 — evictions spilled into the cold store
    promoted: jax.Array  # () int32 — cold entries promoted back into hot


class LatencyReservoir:
    """Bounded ring-buffer sample for percentile latency reporting.

    ``record_dispatch`` / the serving frontend feed every observed latency
    in; only the most recent ``capacity`` samples are retained, so p50/p99
    stay computable over an unbounded run without unbounded memory (the
    paper's dataplane equivalent: a fixed histogram SRAM, not a packet log).
    Idle reservoirs report ``nan`` — the ``PathStats.latency_us`` convention
    (0.0 would read as an impossibly fast path)."""

    __slots__ = ("capacity", "_buf", "_n")

    def __init__(self, capacity: int = 1024):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = capacity
        self._buf = np.empty(capacity, np.float64)
        self._n = 0  # total added; the ring holds the last min(n, capacity)

    def add(self, value: float) -> None:
        self._buf[self._n % self.capacity] = value
        self._n += 1

    def __len__(self) -> int:
        return min(self._n, self.capacity)

    @property
    def total_added(self) -> int:
        return self._n

    def percentile(self, q: float) -> float:
        """q-th percentile (0-100) of the retained sample; ``nan`` when
        nothing was recorded yet."""
        if self._n == 0:
            return float("nan")
        return float(np.percentile(self._buf[: len(self)], q))

    @property
    def p50(self) -> float:
        return self.percentile(50.0)

    @property
    def p99(self) -> float:
        return self.percentile(99.0)


@dataclass
class PipelineStats:
    """Sustained-loop counters, shared by the single-lane and sharded
    pipelines.  All mutation goes through :meth:`record_dispatch`, which
    counts per *actual* device dispatch: ``packets`` is the number of real
    packets ingested (a sharded dispatch also moves ``padded`` masked lane
    rows — those are deliberately not packets, so ``pkt_per_s`` stays an
    honest wire-rate), ``steps`` is pipeline steps (a chunked dispatch
    advances ``scan_len`` of them), ``dispatches`` is host->device round
    trips (a multi-round sharded step can issue several).

    Beyond the aggregate means (``dispatch_us``/``step_us``), every timed
    dispatch region also lands one sample in a bounded
    :class:`LatencyReservoir`, so tail latency (``p50_us``/``p99_us``) is
    reportable over unbounded runs — idle stats report ``nan``."""

    steps: int = 0
    total_s: float = 0.0
    packets: int = 0
    flows: int = 0  # ready flows emitted + classified
    new_flows: int = 0
    evicted: int = 0
    spilled: int = 0  # evictions captured by the cold store (cold_size > 0)
    promoted: int = 0  # cold entries re-established into hot
    dispatches: int = 0  # host->device round-trips (chunking lowers it below
    # steps; sharded overflow rounds raise it above)
    padded: int = 0  # dispatched-but-masked lane rows (sharding skew cost)
    host_s: float = 0.0  # host-side share: staging, enqueue, feedback, pulls
    device_s: float = 0.0  # EXPOSED device wait — what the host blocked on,
    # not raw execution time; overlap shrinks it by hiding staging under it
    lat: LatencyReservoir = field(default_factory=LatencyReservoir)

    def record_dispatch(self, dt: float, *, packets: int, steps: int = 1,
                        dispatches: int = 1, flows: int = 0,
                        new_flows: int = 0, evicted: int = 0,
                        spilled: int = 0, promoted: int = 0,
                        padded: int = 0, host_s: float = 0.0,
                        device_s: float = 0.0) -> None:
        """Fold one timed dispatch (or fused multi-step chunk) into the
        counters.  ``packets`` must be the real packet count — callers that
        dispatch padded lanes pass the keep-mask total, not the lane shape.
        ``host_s``/``device_s`` split ``dt`` into host work vs exposed device
        wait; callers that don't measure the split leave them 0 (the totals
        stay correct, only the attribution is unknown)."""
        self.total_s += dt
        self.packets += packets
        self.steps += steps
        self.dispatches += dispatches
        self.flows += flows
        self.new_flows += new_flows
        self.evicted += evicted
        self.spilled += spilled
        self.promoted += promoted
        self.padded += padded
        self.host_s += host_s
        self.device_s += device_s
        self.lat.add(dt * 1e6)  # one sample per timed region (us)

    @property
    def pkt_per_s(self) -> float:
        return self.packets / self.total_s if self.total_s > 0 else 0.0

    @property
    def flow_per_s(self) -> float:
        return self.flows / self.total_s if self.total_s > 0 else 0.0

    @property
    def step_us(self) -> float:
        return self.total_s / self.steps * 1e6 if self.steps else float("nan")

    @property
    def dispatch_us(self) -> float:
        """Wall time per host->device round trip — the latency the chunked /
        sharded dispatch modes actually amortize (``step_us`` divides by
        pipeline steps, which a fused chunk advances several at a time)."""
        return self.total_s / self.dispatches * 1e6 if self.dispatches else float("nan")

    @property
    def host_us(self) -> float:
        """Mean host-side time per dispatch: staging + enqueue + rule-table
        feedback (+ the producer pull when driven by ``run``)."""
        return self.host_s / self.dispatches * 1e6 if self.dispatches else float("nan")

    @property
    def device_us(self) -> float:
        """Mean *exposed* device wait per dispatch — the block the host
        could not hide.  Under ``overlap`` this drops below the raw device
        time because staging for the next chunk runs during execution."""
        return self.device_s / self.dispatches * 1e6 if self.dispatches else float("nan")

    @property
    def p50_us(self) -> float:
        """Median timed-dispatch wall time (``nan`` when idle)."""
        return self.lat.p50

    @property
    def p99_us(self) -> float:
        """99th-percentile timed-dispatch wall time (``nan`` when idle) —
        the bounded-tail claim the serving frontend is measured against."""
        return self.lat.p99


class InflightDispatch:
    """Handle for one deferred-sync dispatch (``PipelineConfig.overlap``).

    The device work is already *enqueued* when the handle exists (JAX async
    dispatch returned future arrays); nothing has been blocked on.
    :meth:`wait` blocks on the outputs, applies the rule-table feedback
    (step 6) and folds the dispatch into the pipeline stats — exactly what
    the eager path does inline.  Because the rule table never feeds into the
    device computation, a sequence of handles waited **in dispatch order**
    is bit-identical to the eager loop: feedback lags the wire by at most
    the in-flight dispatch, but lands in the same step order.

    ``wait`` is idempotent — the first call resolves and caches the
    :class:`PipelineStepOutput`, later calls return it (the dispatch is
    recorded in stats exactly once).  :meth:`add_host_time` attributes host
    work done on this dispatch's behalf while a previous one was in flight
    (the double-buffered ``run`` loop charges the batch pull here)."""

    __slots__ = ("steps", "packets", "_finish", "_host_extra_s", "_out")

    def __init__(self, finish, *, steps: int, packets: int):
        self._finish = finish  # closure(host_extra_s) -> PipelineStepOutput
        self.steps = steps  # pipeline steps this dispatch advances
        self.packets = packets  # real packets it carries
        self._host_extra_s = 0.0
        self._out: Optional[PipelineStepOutput] = None

    @property
    def done(self) -> bool:
        """True once :meth:`wait` has resolved this handle."""
        return self._out is not None

    def add_host_time(self, dt_s: float) -> None:
        """Charge host time spent on this dispatch's behalf (producer pull,
        staging) to its stats record.  No effect after :meth:`wait`."""
        self._host_extra_s += dt_s

    def wait(self) -> PipelineStepOutput:
        """Block until the device outputs are ready, apply feedback, record
        stats; return the step output.  Idempotent."""
        if self._out is None:
            self._out = self._finish(self._host_extra_s)
            self._finish = None  # drop the closure (it captures device refs)
        return self._out


class OctopusPipeline:
    """Streaming serving loop composing the tracker and both inference
    engines under one :class:`RuntimeConfig` (captured at construction, like
    the standalone paths — jit caches by shapes, not ambient context).

    ``run(traffic, steps=N)`` sustains :class:`TrackerState` across
    microbatches; the state argument is donated to the jit'd step, so the
    table updates in place instead of round-tripping fresh buffers.  With
    ``cfg.scan_len > 1`` the loop pulls ``scan_len`` microbatches at a time
    and dispatches them as one ``lax.scan`` over the fused step
    (:meth:`step_many`); a final partial chunk falls back to per-step
    dispatch (which compiles the single-step path separately)."""

    def __init__(self, packet_params: Any, flow_params: Any,
                 cfg: PipelineConfig = PipelineConfig(), *,
                 config: Optional[RuntimeConfig] = None,
                 program: Optional[jax.Array] = None):
        self.cfg = cfg
        self.runtime = resolve_config(config)
        self.packet_engine = PacketEngine(packet_params, config=self.runtime)
        self.flow_engine = FlowEngine(flow_params, cfg.flow_model,
                                      config=self.runtime)
        self.program = program if program is not None else default_program()
        if cfg.tracker == "segmented" and not self.runtime.use_pallas:
            fx.check_default_program(self.program)  # fail at construction
        self.rules = decisions.RuleTable()  # the switch-facing table (step 6)
        self.stats = PipelineStats()
        self.state = self._fresh_state()
        self.trace_count = 0  # bumps only when a jit entry point re-traces
        self._step_warmed = False
        self._step_fn = jax.jit(self._step, donate_argnums=(0,))
        self._chunk_fn = jax.jit(self._chunk, donate_argnums=(0,))
        self._masked_fn = jax.jit(self._masked_step, donate_argnums=(0,))
        self._warm_buckets: set[int] = set()  # bucket sizes compiled so far

    # ------------------------------------------------------------ traced core
    def _fresh_state(self):
        """State factory shared by construction, warmup scratch and reset —
        overridable (the sharded pipeline stacks per-lane banks here).
        Returns a plain :class:`~repro.core.flow_tracker.TrackerState` in
        hot-only mode (``cold_size == 0`` — byte-identical to the
        single-level pipeline), a :class:`~repro.core.cold_store.TwoLevelState`
        with the cold table attached otherwise."""
        hot = ft.init_state(self.cfg.table_size, self.cfg.top_n,
                            self.cfg.top_k, self.cfg.pay_bytes)
        if not self.cfg.cold_size:
            return hot
        return cold_store.TwoLevelState(
            hot=hot, cold=cold_store.init_cold(
                self.cfg.cold_size, self.cfg.top_n, self.cfg.top_k,
                self.cfg.pay_bytes))

    def _merge(self, hot: ft.TrackerState, packets: ft.PacketBatch,
               keep: Optional[jax.Array], *, fallback: str,
               with_spills: bool = False):
        """The raw tracker merge under ``cfg.tracker``: returns
        ``(hot, new, evicted)`` (plus the spill records when asked)."""
        if self.cfg.tracker == "segmented":
            out = fx.segmented_update(
                hot, packets, self.program, top_n=self.cfg.top_n,
                use_pallas=self.runtime.use_pallas,
                interpret=self.runtime.interpret, keep=keep,
                fallback=fallback, with_spills=with_spills)
            if with_spills:
                hot, seg, spills = out
                return hot, seg.new_flows, seg.evicted, spills
            hot, seg = out
            return hot, seg.new_flows, seg.evicted
        out = ft.process_packets(hot, packets, self.program,
                                 top_n=self.cfg.top_n, keep=keep,
                                 with_spills=with_spills)
        if with_spills:
            hot, outs, spills = out
            return (hot, outs.new_flow.sum().astype(jnp.int32),
                    outs.evicted.sum().astype(jnp.int32), spills)
        hot, outs = out
        return (hot, outs.new_flow.sum().astype(jnp.int32),
                outs.evicted.sum().astype(jnp.int32))

    def _track(self, state, packets: ft.PacketBatch,
               keep: Optional[jax.Array] = None, *, fallback: str = "auto"):
        """Step 2 only: merge one (optionally masked) microbatch into the
        tracker under ``cfg.tracker``.  Returns ``(state, new_flows,
        evicted, spilled, promoted)`` — the merge half of the lane contract,
        dispatched on its own by the sharded pipeline's overflow rounds.
        ``fallback`` is forwarded to the segmented tracker's collision
        branch (vmapped callers hoist it).

        In hot-only mode the state is a plain tracker bank, spills/promotes
        are constant zero, and the traced merge is identical to the
        single-level pipeline.  With ``cold_size > 0`` the two-level step
        semantics documented in :mod:`repro.core.cold_store` run around the
        same merge: promote -> merge (with spill records) -> spill -> scrub."""
        zero = jnp.int32(0)
        if not self.cfg.cold_size:
            state, new, ev = self._merge(state, packets, keep,
                                         fallback=fallback)
            return state, new, ev, zero, zero
        hot, cold = state.hot, state.cold
        hot, cold, promoted = cold_store.promote_pass(
            hot, cold, packets, keep, policy=self.cfg.cold_policy)
        hot, new, ev, spills = self._merge(hot, packets, keep,
                                           fallback=fallback,
                                           with_spills=True)
        cold, spilled = cold_store.apply_spills(
            cold, spills, policy=self.cfg.cold_policy)
        cold = cold_store.scrub_live(cold, hot, packets, keep)
        return (cold_store.TwoLevelState(hot, cold), new, ev, spilled,
                promoted)

    def _lane_core(self, state, packets: ft.PacketBatch,
                   keep: Optional[jax.Array] = None, *,
                   max_ready: Optional[int] = None, fallback: str = "auto"
                   ) -> tuple[Any, PipelineStepOutput]:
        """Steps 2-5 for ONE lane, the shard-shaped step contract: merge the
        (optionally keep-masked) packets, drain up to ``max_ready`` ready
        flows (the global budget, or one lane's split of it), run both
        engines, decide.  The single-lane pipeline calls it with the full
        batch and budget; the sharded pipeline vmaps / shard_maps it across
        hash-partitioned lanes.  Draining always happens on the hot bank —
        cold flows re-enter the hot table through promotion before they can
        emit."""
        state, new_flows, evicted, spilled, promoted = self._track(
            state, packets, keep, fallback=fallback)
        hot = state.hot if self.cfg.cold_size else state
        hot, drained = ft.drain_ready(
            hot, top_n=self.cfg.top_n,
            max_ready=self.cfg.max_ready if max_ready is None else max_ready)
        state = state._replace(hot=hot) if self.cfg.cold_size else hot
        pkt_actions = self._decide_pkt(packets)
        flow_actions, flow_cls, flow_scores = self._decide_flow(drained)
        return state, PipelineStepOutput(
            pkt_actions=pkt_actions,
            drained=drained,
            flow_actions=flow_actions,
            flow_cls=flow_cls,
            flow_scores=flow_scores,
            new_flows=new_flows,
            evicted=evicted,
            spilled=spilled,
            promoted=promoted,
        )

    # ------------------------------------------------------------ decide (5)
    def _decide_pkt(self, packets: ft.PacketBatch) -> jax.Array:
        """Step 4+5, packet side: run the packet engine only when the head
        consumes logits (feature-only heads skip the inference entirely),
        then let the head decide."""
        head = self.cfg.pkt_head
        logits = self.packet_engine.fn(
            self.packet_engine.params,
            packet_meta_features(packets)) if head.needs_logits else None
        return head.decide(logits, packets)

    def _decide_flow(self, drained: ft.DrainResult
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Step 4+5, flow side: prep + flow-engine inference only for
        logits-consuming heads, then the head maps (logits, drained rows) to
        (actions, classes, scores)."""
        head = self.cfg.flow_head
        if head.needs_logits:
            flow_x = self.flow_engine.prep(drained.series, drained.payload)
            logits = self.flow_engine.fn(self.flow_engine.params, flow_x)
        else:
            logits = None
        return head.decide(logits, drained)

    def _decide(self, packets: ft.PacketBatch, drained: ft.DrainResult
                ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
        """Both decide halves at once — the full step-5 extension point."""
        return (self._decide_pkt(packets),) + self._decide_flow(drained)

    def _step_core(self, state: ft.TrackerState,
                   packets: ft.PacketBatch) -> tuple[ft.TrackerState,
                                                     PipelineStepOutput]:
        """Steps 2-5 as one traced function (no trace counting — both jit
        entry points share it): the lane core at full batch + budget."""
        return self._lane_core(state, packets)

    def _step(self, state: ft.TrackerState,
              packets: ft.PacketBatch) -> tuple[ft.TrackerState, PipelineStepOutput]:
        self.trace_count += 1  # python side effect: runs per trace, not per call
        return self._step_core(state, packets)

    def _chunk(self, state: ft.TrackerState,
               stacked: ft.PacketBatch) -> tuple[ft.TrackerState, PipelineStepOutput]:
        """``scan_len`` fused steps in one dispatch: ``lax.scan`` over
        :meth:`_step_core` with the tracker state as carry.  Outputs come
        back stacked with a leading ``scan_len`` axis."""
        self.trace_count += 1  # python side effect: runs per trace, not per call
        return lax.scan(self._step_core, state, stacked)

    def _masked_step(self, state: ft.TrackerState, packets: ft.PacketBatch,
                     keep: jax.Array) -> tuple[ft.TrackerState,
                                               PipelineStepOutput]:
        """The serving frontend's bucket-shaped entry point: the full lane
        core over a *padded* microbatch whose tail rows carry ``keep ==
        False`` (the trackers drop them via the keep mask, so the state is
        bit-identical to merging only the kept rows).  jit caches one
        compiled entry per bucket shape — ``warm_bucket`` pre-compiles them
        so ragged arrivals never retrace."""
        self.trace_count += 1  # python side effect: runs per trace, not per call
        return self._lane_core(state, packets, keep)

    # -------------------------------------------------------------- host loop
    def warmup(self) -> None:
        """Compile the dispatch path ``run`` will use, on a throwaway state
        (the live table is untouched).  Compiles the chunked path when
        ``scan_len > 1``, else the single-step path; if a ``run`` later hits
        a partial final chunk, the single-step path is warmed on the spot —
        outside the timed region, so stats never include compilation."""
        scratch = self._fresh_state()
        if self.cfg.scan_len > 1:
            stacked = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (self.cfg.scan_len,) + a.shape),
                self._zero_batch())
            _, out = self._chunk_fn(scratch, stacked)
            jax.block_until_ready(out)
        else:
            self._warm_step()

    def _warm_step(self) -> None:
        """Compile the single-step path on scratch state (idempotent) so a
        partial-chunk fallback never pays compilation inside ``step``'s
        timing window."""
        if self._step_warmed:
            return
        scratch = self._fresh_state()
        _, out = self._step_fn(scratch, self._zero_batch())
        jax.block_until_ready(out)
        self._step_warmed = True

    def _zero_batch(self, n: Optional[int] = None) -> ft.PacketBatch:
        p, c = self.cfg.batch_size if n is None else n, self.cfg
        return ft.PacketBatch(
            ts=jnp.zeros((p,), jnp.int32), size=jnp.zeros((p,), jnp.int32),
            dir=jnp.zeros((p,), jnp.int32), flags=jnp.zeros((p,), jnp.int32),
            proto=jnp.zeros((p,), jnp.int32),
            tuple_hash=jnp.zeros((p,), jnp.int32),
            payload=jnp.zeros((p, c.pay_bytes), jnp.int32))

    def _check_batch(self, packets: ft.PacketBatch) -> int:
        n = int(packets.ts.shape[0])
        if n != self.cfg.batch_size:
            raise ValueError(f"microbatch must have batch_size="
                             f"{self.cfg.batch_size} packets, got {n}")
        return n

    def _feedback(self, tuple_hash: np.ndarray, pkt_actions: np.ndarray,
                  mask: np.ndarray, tuple_id: np.ndarray,
                  flow_actions: np.ndarray, flow_cls: np.ndarray) -> int:
        """Step 6 for one microbatch: decisions -> the switch-facing rule
        table.  Returns the number of emitted flows."""
        self.rules.update(tuple_hash, pkt_actions)
        n_flows = int(mask.sum())
        if n_flows:
            self.rules.update(tuple_id[mask], flow_actions[mask],
                              flow_cls[mask])
        return n_flows

    def _dispatch_step(self, packets: ft.PacketBatch) -> InflightDispatch:
        """Enqueue one microbatch (steps 2-5) without blocking — JAX async
        dispatch hands the outputs back as future arrays, so the host is
        free to stage the next chunk while this one executes.  The returned
        handle's ``wait`` blocks, applies feedback and records stats."""
        n = self._check_batch(packets)
        t0 = time.perf_counter()
        self.state, out = self._step_fn(self.state, packets)
        enqueue_s = time.perf_counter() - t0
        self._step_warmed = True  # compiled now, whatever the entry path

        def finish(host_extra_s: float) -> PipelineStepOutput:
            # block on the outputs only: under overlap the state has already
            # been donated to the next enqueued dispatch (same computation,
            # so `out` ready implies the state update finished too)
            t1 = time.perf_counter()
            jax.block_until_ready(out)
            device_s = time.perf_counter() - t1
            t2 = time.perf_counter()
            n_flows = self._feedback(
                np.asarray(packets.tuple_hash), np.asarray(out.pkt_actions),
                np.asarray(out.drained.mask),
                np.asarray(out.drained.tuple_id),
                np.asarray(out.flow_actions), np.asarray(out.flow_cls))
            host_s = (enqueue_s + host_extra_s
                      + (time.perf_counter() - t2))
            self.stats.record_dispatch(
                host_s + device_s, packets=n, flows=n_flows,
                new_flows=int(out.new_flows), evicted=int(out.evicted),
                spilled=int(out.spilled), promoted=int(out.promoted),
                host_s=host_s, device_s=device_s)
            return out

        return InflightDispatch(finish, steps=1, packets=n)

    def step(self, packets: ft.PacketBatch):
        """Run one microbatch through the loop and fold the decisions into
        the rule table.  ``packets`` must have ``batch_size`` rows (static
        shape — a different size would recompile).

        Returns the :class:`PipelineStepOutput` eagerly, or — with
        ``cfg.overlap`` — an :class:`InflightDispatch` that the caller waits
        in dispatch order (feedback is then lagged by the one in-flight
        dispatch, still bit-identical; see the class docstring)."""
        h = self._dispatch_step(packets)
        return h if self.cfg.overlap else h.wait()

    # ---------------------------------------------------- bucketed (masked)
    def warm_bucket(self, bucket: int) -> None:
        """Pre-compile the masked entry point for one bucket size on
        throwaway state (idempotent per size).  The serving frontend calls
        this for every configured bucket at startup, so ragged request sizes
        pad to a pre-warmed shape and ``trace_count`` stays flat."""
        if bucket <= 0:
            raise ValueError(f"bucket must be positive, got {bucket}")
        if bucket in self._warm_buckets:
            return
        scratch = self._fresh_state()
        _, out = self._masked_fn(scratch, self._zero_batch(bucket),
                                 jnp.zeros((bucket,), bool))
        jax.block_until_ready(out)
        self._warm_buckets.add(bucket)

    def step_masked(self, packets: ft.PacketBatch,
                    keep: np.ndarray) -> PipelineStepOutput:
        """One padded request batch through the loop: rows with ``keep ==
        False`` are padding — excluded from the tracker merge, the rule-table
        feedback and the packet stats (they count as ``padded``, like a
        sharded lane's skew rows).  The batch may be any pre-warmed bucket
        size; it is NOT tied to ``cfg.batch_size``."""
        bucket = int(np.asarray(packets.ts).shape[0])
        k = np.asarray(keep)
        if k.shape != (bucket,):
            raise ValueError(f"keep must have shape ({bucket},), got {k.shape}")
        n = int(k.sum())
        t0 = time.perf_counter()
        self.state, out = self._masked_fn(self.state, packets,
                                          jnp.asarray(k))
        t1 = time.perf_counter()
        jax.block_until_ready((self.state, out))
        t2 = time.perf_counter()
        self._warm_buckets.add(bucket)  # compiled now, whatever the path

        n_flows = self._feedback(
            np.asarray(packets.tuple_hash)[k], np.asarray(out.pkt_actions)[k],
            np.asarray(out.drained.mask), np.asarray(out.drained.tuple_id),
            np.asarray(out.flow_actions), np.asarray(out.flow_cls))
        t3 = time.perf_counter()

        host_s, device_s = (t1 - t0) + (t3 - t2), t2 - t1
        self.stats.record_dispatch(host_s + device_s, packets=n,
                                   flows=n_flows,
                                   new_flows=int(out.new_flows),
                                   evicted=int(out.evicted),
                                   spilled=int(out.spilled),
                                   promoted=int(out.promoted),
                                   padded=bucket - n,
                                   host_s=host_s, device_s=device_s)
        return out

    def _chunk_feedback(self, batches: Sequence[ft.PacketBatch],
                        out: PipelineStepOutput) -> int:
        """Step 6 for one fused chunk (stacked outputs, leading step axis),
        applied in step order so later verdicts overwrite earlier — shared by
        the single-lane and sharded chunked dispatches.  Returns the number
        of emitted flows.  The hashes come from the host-resident ``batches``;
        reading them back from the stacked device arrays would add a
        device->host transfer per chunk."""
        hashes = np.stack([np.asarray(b.tuple_hash) for b in batches])
        pkt_actions = np.asarray(out.pkt_actions)
        masks = np.asarray(out.drained.mask)
        tuple_ids = np.asarray(out.drained.tuple_id)
        flow_actions = np.asarray(out.flow_actions)
        flow_cls = np.asarray(out.flow_cls)
        n_flows = 0
        for j in range(len(batches)):
            n_flows += self._feedback(hashes[j], pkt_actions[j], masks[j],
                                      tuple_ids[j], flow_actions[j],
                                      flow_cls[j])
        return n_flows

    def _dispatch_chunk(self, batches: Sequence[ft.PacketBatch]
                        ) -> InflightDispatch:
        """Enqueue one fused ``scan_len`` chunk without blocking: the host
        stacking happens now (charged to ``host_us``), the ``lax.scan``
        dispatch returns future arrays, and the handle's ``wait`` blocks +
        applies the per-step feedback in order."""
        L = self.cfg.scan_len
        batches = list(batches)
        if len(batches) != L:
            raise ValueError(f"step_many needs exactly scan_len={L} "
                             f"microbatches, got {len(batches)}")
        for b in batches:
            self._check_batch(b)
        t0 = time.perf_counter()
        stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *batches)
        self.state, out = self._chunk_fn(self.state, stacked)
        enqueue_s = time.perf_counter() - t0
        n = L * self.cfg.batch_size

        def finish(host_extra_s: float) -> PipelineStepOutput:
            t1 = time.perf_counter()
            jax.block_until_ready(out)
            device_s = time.perf_counter() - t1
            t2 = time.perf_counter()
            n_flows = self._chunk_feedback(batches, out)
            host_s = (enqueue_s + host_extra_s
                      + (time.perf_counter() - t2))
            self.stats.record_dispatch(
                host_s + device_s, packets=n, steps=L, flows=n_flows,
                new_flows=int(np.asarray(out.new_flows).sum()),
                evicted=int(np.asarray(out.evicted).sum()),
                spilled=int(np.asarray(out.spilled).sum()),
                promoted=int(np.asarray(out.promoted).sum()),
                host_s=host_s, device_s=device_s)
            return out

        return InflightDispatch(finish, steps=L, packets=n)

    def step_many(self, batches: Sequence[ft.PacketBatch]):
        """Run exactly ``scan_len`` microbatches as ONE device dispatch
        (``lax.scan`` over the fused step) and fold all decisions into the
        rule table afterwards, in step order.  Returns the stacked outputs
        (leading ``scan_len`` axis) — or, with ``cfg.overlap``, an
        :class:`InflightDispatch` to be waited in dispatch order.  Feedback
        granularity is the chunk: rule-table updates land after the whole
        chunk computes."""
        h = self._dispatch_chunk(batches)
        return h if self.cfg.overlap else h.wait()

    def run(self, traffic: Iterable[ft.PacketBatch],
            steps: Optional[int] = None) -> PipelineStats:
        """Drive the loop from an iterable of microbatches (e.g. a
        :class:`repro.data.traffic.TrafficGenerator`, which streams forever —
        pass ``steps`` to bound it) and return the sustained stats.  With
        ``scan_len > 1`` microbatches dispatch in chunks of ``scan_len``; a
        final partial chunk (iterator exhausted or ``steps`` not a multiple)
        runs per-step.

        With ``cfg.overlap`` the loop is a double-buffered producer/consumer:
        chunk k+1 is pulled from the iterator and *enqueued* while chunk k
        executes on device, and chunk k's handle is waited (feedback + stats)
        only then — strictly in dispatch order, so the run is bit-identical
        to the eager loop.  The iterator pull is charged to ``host_us`` in
        BOTH modes, so overlap-on/off stats compare at the same boundary;
        wrap the source in :func:`repro.data.traffic.prefetch` to move batch
        *generation* onto a background thread as well."""
        it = iter(traffic)
        L = self.cfg.scan_len
        done = 0
        pending: Optional[InflightDispatch] = None

        def advance(handle: InflightDispatch, pull_s: float) -> None:
            nonlocal pending
            handle.add_host_time(pull_s)
            if not self.cfg.overlap:
                handle.wait()
                return
            if pending is not None:
                pending.wait()  # chunk k-1: lagged feedback, in step order
            pending = handle

        while steps is None or done < steps:
            want = L if steps is None else min(L, steps - done)
            # islice, not enumerate+break: never pull a batch beyond `steps`
            # (a generator reused across run() calls must not drop batches)
            t0 = time.perf_counter()
            chunk = list(itertools.islice(it, want))
            pull_s = time.perf_counter() - t0
            if not chunk:
                break
            if L > 1 and len(chunk) == L:
                advance(self._dispatch_chunk(chunk), pull_s)
            else:
                if L > 1:  # partial-chunk fallback: warm outside the timing
                    self._warm_step()
                for batch in chunk:
                    advance(self._dispatch_step(batch), pull_s)
                    pull_s = 0.0  # charge the pull to the first step only
            done += len(chunk)
        if pending is not None:
            pending.wait()  # drain the in-flight tail
        return self.stats

    def reset(self) -> None:
        """Fresh table, rule set and counters (compiled dispatches are kept)."""
        self.state = self._fresh_state()
        self.rules = decisions.RuleTable()
        self.stats = PipelineStats()

    # ------------------------------------------------------------- placement
    def plan(self) -> RoutePlan:
        """One RoutePlan over the matmuls the decision heads actually
        consume, in step order (packet engine under the ``pkt/`` name scope,
        then the flow engine under ``flow/``) — the single placement truth
        for the fused step.  Feature-only heads contribute no matmuls: the
        plan reflects the inference the step really dispatches.  The shapes
        are per scan iteration: chunked dispatch scans the same step body,
        so the placement is identical for every ``scan_len``."""
        use_pkt = self.cfg.pkt_head.needs_logits
        use_flow = self.cfg.flow_head.needs_logits

        def engines(px: jax.Array, fx_: jax.Array):
            out = []
            if use_pkt:
                with name_scope("pkt"):
                    out.append(self.packet_engine.fn(self.packet_engine.params, px))
            if use_flow:
                with name_scope("flow"):
                    out.append(self.flow_engine.fn(self.flow_engine.params, fx_))
            return tuple(out)

        return RoutePlan.trace(
            engines, self.packet_engine.abstract_input(self.cfg.batch_size),
            self.flow_engine.abstract_input(self.cfg.max_ready),
            config=self.runtime)

    def explain(self) -> str:
        """Placement report for the fused step: the combined plan plus the
        per-engine split (feature-only heads report their engine as
        skipped)."""
        plan = self.plan()
        pkt = plan.scoped("pkt", strip=True)
        flow = plan.scoped("flow", strip=True)
        c = self.cfg
        head = (f"OctopusPipeline: batch={c.batch_size} max_ready={c.max_ready} "
                f"flow_model={c.flow_model} table={c.table_size} top_n={c.top_n} "
                f"tracker={c.tracker} scan_len={c.scan_len}")
        if c.cold_size:
            head += f" cold={c.cold_size}({c.cold_policy})"
        head += f" heads={c.pkt_head.name}/{c.flow_head.name}"
        fmt = lambda p: ", ".join(f"{s.name}->{s.engine}" for s in p.steps)
        eng = lambda p, on: (f"({len(p)} matmuls): {fmt(p)}" if on
                             else "skipped (feature-only head)")
        return "\n".join([
            head, plan.explain(),
            f"  packet-engine {eng(pkt, c.pkt_head.needs_logits)}",
            f"  flow-engine {eng(flow, c.flow_head.needs_logits)}",
        ])
