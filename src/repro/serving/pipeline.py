"""The streaming in-network serving pipeline (paper §2.3 working procedure).

One continuous loop over packet microbatches — the paper's steps 1 -> 6 —
instead of the isolated per-call paths:

  1. parse        — ingest a :class:`PacketBatch` microbatch (the parser's
                    struct-of-arrays output; see ``repro.data.traffic``)
  2. track        — fold the batch into the hash-indexed flow table
                    (:func:`flow_tracker.process_packets`, order-exact scan)
  3. extract      — drain up to ``max_ready`` ready flows (count >= top_n)
                    from the table and recycle their slots
                    (:func:`flow_tracker.drain_ready`)
  4. infer        — per-packet metadata -> :class:`PacketEngine` (latency/VPE
                    side); emitted flow memories -> :class:`FlowEngine`
                    (throughput/AryPE side), both under the one runtime
                    config captured at construction
  5. decide       — logits -> allow/deny + class ids
  6. feed back    — decisions update the switch-facing rule table

Steps 2-5 compile into a single jit'd step function whose
:class:`TrackerState` is donated — state flows across microbatches without
copies, and after warmup no step retraces (asserted in tests via the
pipeline's ``trace_count``).  All output shapes are static (``batch_size``
packets, ``max_ready`` flow rows + validity mask), so the step is scan-
friendly by construction.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass
from typing import Any, Iterable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decisions
from repro.core import flow_tracker as ft
from repro.core.feature_extractor import packet_meta_features
from repro.kernels.flow_features.ops import default_program
from repro.models import paper_models
from repro.runtime import RoutePlan, RuntimeConfig, name_scope, resolve_config
from repro.serving.packet_path import FLOW_MODELS, FlowEngine, PacketEngine


@dataclass(frozen=True)
class PipelineConfig:
    """Static shapes + thresholds of the streaming loop (jit cache keys)."""

    batch_size: int = 32  # packets per microbatch (step granularity)
    max_ready: int = 8  # ready-flow rows drained per step
    flow_model: str = "cnn"  # "cnn" | "transformer"
    table_size: int = 1024  # flow-state table depth (paper: 8192)
    top_n: int = paper_models.CNN_SEQ  # ready threshold / series depth
    top_k: int = paper_models.TF_PKTS  # payload rows per flow
    pay_bytes: int = paper_models.TF_BYTES  # payload bytes per packet

    def __post_init__(self):
        if self.flow_model not in FLOW_MODELS:
            raise ValueError(f"flow_model must be one of {FLOW_MODELS}, "
                             f"got {self.flow_model!r}")
        if self.batch_size <= 0 or not 0 < self.max_ready <= self.table_size:
            raise ValueError("batch_size and max_ready must be positive "
                             "(max_ready <= table_size)")
        # the flow engine consumes the tracker memories directly — their
        # depths must match the model's fixed input geometry
        if self.flow_model == "cnn" and self.top_n != paper_models.CNN_SEQ:
            raise ValueError(f"cnn flow model needs top_n == {paper_models.CNN_SEQ} "
                             f"(got {self.top_n})")
        if self.flow_model == "transformer" and (
                self.top_k != paper_models.TF_PKTS
                or self.pay_bytes != paper_models.TF_BYTES):
            raise ValueError(
                f"transformer flow model needs top_k == {paper_models.TF_PKTS} and "
                f"pay_bytes == {paper_models.TF_BYTES} "
                f"(got {self.top_k}/{self.pay_bytes})")


class PipelineStepOutput(NamedTuple):
    """Device-side outputs of one fused step (static shapes)."""

    pkt_actions: jax.Array  # (batch_size,) int32 0 allow / 1 deny
    drained: ft.DrainResult  # max_ready rows + mask
    flow_actions: jax.Array  # (max_ready,) int32
    flow_cls: jax.Array  # (max_ready,) int32
    new_flows: jax.Array  # () int32 — flows established this step
    evicted: jax.Array  # () int32 — stale flows recycled by collision


@dataclass
class PipelineStats:
    """Sustained-loop counters (wall time covers the fused device step)."""

    steps: int = 0
    total_s: float = 0.0
    packets: int = 0
    flows: int = 0  # ready flows emitted + classified
    new_flows: int = 0
    evicted: int = 0

    @property
    def pkt_per_s(self) -> float:
        return self.packets / self.total_s if self.total_s > 0 else 0.0

    @property
    def flow_per_s(self) -> float:
        return self.flows / self.total_s if self.total_s > 0 else 0.0

    @property
    def step_us(self) -> float:
        return self.total_s / self.steps * 1e6 if self.steps else float("nan")


class OctopusPipeline:
    """Streaming serving loop composing the tracker and both inference
    engines under one :class:`RuntimeConfig` (captured at construction, like
    the standalone paths — jit caches by shapes, not ambient context).

    ``run(traffic, steps=N)`` sustains :class:`TrackerState` across
    microbatches; the state argument is donated to the jit'd step, so the
    table updates in place instead of round-tripping fresh buffers."""

    def __init__(self, packet_params: Any, flow_params: Any,
                 cfg: PipelineConfig = PipelineConfig(), *,
                 config: Optional[RuntimeConfig] = None,
                 program: Optional[jax.Array] = None):
        self.cfg = cfg
        self.runtime = resolve_config(config)
        self.packet_engine = PacketEngine(packet_params, config=self.runtime)
        self.flow_engine = FlowEngine(flow_params, cfg.flow_model,
                                      config=self.runtime)
        self.program = program if program is not None else default_program()
        self.rules = decisions.RuleTable()  # the switch-facing table (step 6)
        self.stats = PipelineStats()
        self.state = ft.init_state(cfg.table_size, cfg.top_n, cfg.top_k,
                                   cfg.pay_bytes)
        self.trace_count = 0  # bumps only when _step re-traces
        self._step_fn = jax.jit(self._step, donate_argnums=(0,))

    # ------------------------------------------------------------ traced core
    def _step(self, state: ft.TrackerState,
              packets: ft.PacketBatch) -> tuple[ft.TrackerState, PipelineStepOutput]:
        """Steps 2-5 as one traced function (state donated under jit)."""
        self.trace_count += 1  # python side effect: runs per trace, not per call
        state, outs = ft.process_packets(state, packets, self.program,
                                         top_n=self.cfg.top_n)
        state, drained = ft.drain_ready(state, top_n=self.cfg.top_n,
                                        max_ready=self.cfg.max_ready)
        pkt_logits = self.packet_engine.fn(self.packet_engine.params,
                                           packet_meta_features(packets))
        flow_x = self.flow_engine.prep(drained.series, drained.payload)
        flow_logits = self.flow_engine.fn(self.flow_engine.params, flow_x)
        flow_actions, flow_cls = decisions.decide_class(flow_logits)
        return state, PipelineStepOutput(
            pkt_actions=decisions.decide_binary(pkt_logits),
            drained=drained,
            flow_actions=flow_actions,
            flow_cls=flow_cls,
            new_flows=outs.new_flow.sum().astype(jnp.int32),
            evicted=outs.evicted.sum().astype(jnp.int32),
        )

    # -------------------------------------------------------------- host loop
    def warmup(self) -> None:
        """Compile the step for the canonical shapes on a throwaway state
        (the live table is untouched)."""
        scratch = ft.init_state(self.cfg.table_size, self.cfg.top_n,
                                self.cfg.top_k, self.cfg.pay_bytes)
        _, out = self._step_fn(scratch, self._zero_batch())
        jax.block_until_ready(out)

    def _zero_batch(self) -> ft.PacketBatch:
        p, c = self.cfg.batch_size, self.cfg
        return ft.PacketBatch(
            ts=jnp.zeros((p,), jnp.int32), size=jnp.zeros((p,), jnp.int32),
            dir=jnp.zeros((p,), jnp.int32), flags=jnp.zeros((p,), jnp.int32),
            proto=jnp.zeros((p,), jnp.int32),
            tuple_hash=jnp.zeros((p,), jnp.int32),
            payload=jnp.zeros((p, c.pay_bytes), jnp.int32))

    def step(self, packets: ft.PacketBatch) -> PipelineStepOutput:
        """Run one microbatch through the loop and fold the decisions into
        the rule table.  ``packets`` must have ``batch_size`` rows (static
        shape — a different size would recompile)."""
        n = int(packets.ts.shape[0])
        if n != self.cfg.batch_size:
            raise ValueError(f"microbatch must have batch_size="
                             f"{self.cfg.batch_size} packets, got {n}")
        t0 = time.perf_counter()
        self.state, out = self._step_fn(self.state, packets)
        jax.block_until_ready((self.state, out))
        dt = time.perf_counter() - t0

        # step 6: decisions feed back into the switch-facing rule table
        self.rules.update(np.asarray(packets.tuple_hash),
                          np.asarray(out.pkt_actions))
        mask = np.asarray(out.drained.mask)
        n_flows = int(mask.sum())
        if n_flows:
            self.rules.update(np.asarray(out.drained.tuple_id)[mask],
                              np.asarray(out.flow_actions)[mask],
                              np.asarray(out.flow_cls)[mask])

        s = self.stats
        s.steps += 1
        s.total_s += dt
        s.packets += n
        s.flows += n_flows
        s.new_flows += int(out.new_flows)
        s.evicted += int(out.evicted)
        return out

    def run(self, traffic: Iterable[ft.PacketBatch],
            steps: Optional[int] = None) -> PipelineStats:
        """Drive the loop from an iterable of microbatches (e.g. a
        :class:`repro.data.traffic.TrafficGenerator`, which streams forever —
        pass ``steps`` to bound it) and return the sustained stats."""
        # islice, not enumerate+break: never pull a batch beyond `steps` (a
        # generator reused across run() calls must not silently drop one)
        for batch in itertools.islice(iter(traffic), steps):
            self.step(batch)
        return self.stats

    def reset(self) -> None:
        """Fresh table, rule set and counters (compiled step is kept)."""
        self.state = ft.init_state(self.cfg.table_size, self.cfg.top_n,
                                   self.cfg.top_k, self.cfg.pay_bytes)
        self.rules = decisions.RuleTable()
        self.stats = PipelineStats()

    # ------------------------------------------------------------- placement
    def plan(self) -> RoutePlan:
        """One RoutePlan over both engines' matmuls, in step order (packet
        engine under the ``pkt/`` name scope, then the flow engine under
        ``flow/``) — the single placement truth for the fused step."""
        def both(px: jax.Array, fx: jax.Array):
            with name_scope("pkt"):
                a = self.packet_engine.fn(self.packet_engine.params, px)
            with name_scope("flow"):
                b = self.flow_engine.fn(self.flow_engine.params, fx)
            return a, b

        return RoutePlan.trace(
            both, self.packet_engine.abstract_input(self.cfg.batch_size),
            self.flow_engine.abstract_input(self.cfg.max_ready),
            config=self.runtime)

    def explain(self) -> str:
        """Placement report for the fused step: the combined plan plus the
        per-engine split."""
        plan = self.plan()
        pkt, flow = plan.scoped("pkt"), plan.scoped("flow")
        c = self.cfg
        head = (f"OctopusPipeline: batch={c.batch_size} max_ready={c.max_ready} "
                f"flow_model={c.flow_model} table={c.table_size} top_n={c.top_n}")
        fmt = lambda p: ", ".join(f"{s.name.split('/', 1)[1]}->{s.engine}"
                                  for s in p.steps)
        return "\n".join([
            head, plan.explain(),
            f"  packet-engine ({len(pkt)} matmuls): {fmt(pkt)}",
            f"  flow-engine ({len(flow)} matmuls): {fmt(flow)}",
        ])
