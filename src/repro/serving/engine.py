"""LM serving engine: slot-based continuous batching over a fixed decode
batch, per-slot lengths, prefill + lockstep decode.

This is the paper's task-granularity split at LM scale: the decode path is the
latency engine (one token per step, VPE-like), prefill/throughput batching is
the AryPE-like engine; both share the cache through the "memory fabric"
(sharded KV buffers).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.transformer import LM


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (P,) int32
    max_new: int = 16
    out_tokens: list = field(default_factory=list)
    done: bool = False


@dataclass
class ServeConfig:
    batch_slots: int = 4
    cache_len: int = 256
    greedy: bool = True
    eos_id: int = -1  # -1: never stop early


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params: Any, serve: ServeConfig):
        self.cfg = cfg
        self.model = LM(cfg)
        self.params = params
        self.sc = serve
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(2,))
        self.reset()

    def reset(self):
        self.cache = self.model.init_cache(self.sc.batch_slots, self.sc.cache_len)
        self.slots: list[Optional[Request]] = [None] * self.sc.batch_slots
        self.queue: list[Request] = []
        self.next_tok = np.zeros((self.sc.batch_slots, 1), np.int32)
        self.active = np.zeros((self.sc.batch_slots,), bool)

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        """Prefill queued requests into free slots (one at a time — per-slot
        prefill writes only that slot's cache rows via a masked batch)."""
        for i in range(self.sc.batch_slots):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                p = len(req.prompt)
                toks = np.zeros((self.sc.batch_slots, p), np.int32)
                toks[i] = req.prompt
                # reset this slot's length, prefill a full batch but only keep slot i
                lengths = np.array(jax.device_get(self.cache["lengths"]))
                single_cache = self.model.init_cache(self.sc.batch_slots, self.sc.cache_len)
                logits, new_cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)},
                                                  single_cache)
                self.cache = _merge_slot(self.cache, new_cache, i)
                lengths[i] = p
                self.cache["lengths"] = jnp.asarray(lengths)
                nt = int(jnp.argmax(logits[i, -1, : self.cfg.vocab_size]))
                self.next_tok[i, 0] = nt
                req.out_tokens.append(nt)
                self.slots[i] = req
                self.active[i] = True

    def step(self) -> int:
        """One lockstep decode step across active slots.  Returns #finished."""
        self._admit()
        if not self.active.any():
            return 0
        logits, self.cache = self._decode(
            self.params, {"tokens": jnp.asarray(self.next_tok)}, self.cache
        )
        nxt = np.asarray(jnp.argmax(logits[:, 0, : self.cfg.vocab_size], axis=-1))
        finished = 0
        for i, req in enumerate(self.slots):
            if req is None or not self.active[i]:
                continue
            tok = int(nxt[i])
            req.out_tokens.append(tok)
            self.next_tok[i, 0] = tok
            if len(req.out_tokens) >= req.max_new or tok == self.sc.eos_id:
                req.done = True
                self.slots[i] = None
                self.active[i] = False
                finished += 1
        return finished

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        done: list[Request] = []
        all_reqs = list(self.queue)
        for _ in range(max_steps):
            self.step()
            if not self.queue and not self.active.any():
                break
        return [r for r in all_reqs if r.done]


def _merge_slot(old_cache: dict, new_cache: dict, slot: int) -> dict:
    """Take slot `slot`'s rows from new_cache, keep everything else from old.
    Every cache leaf has its batch dim at 0 (unstacked) or 1 (stacked under the
    superblock scan); stacking is detected by shape[0] == num_superblocks."""

    def merge2(o, n, nsb):
        if not hasattr(o, "shape") or o.ndim == 0:
            return n if o.shape == () else o
        bdim = 1 if (o.ndim >= 2 and o.shape[0] == nsb) else 0
        idx = [slice(None)] * o.ndim
        idx[bdim] = slot
        return o.at[tuple(idx)].set(n[tuple(idx)])

    import functools

    nsb = None
    # infer num_superblocks from the blocks sub-tree leading dims
    blocks = old_cache.get("blocks", {})
    for leaf in jax.tree.leaves(blocks):
        nsb = leaf.shape[0]
        break
    out = dict(old_cache)
    for key in old_cache:
        if key == "lengths":
            out[key] = old_cache[key]
            continue
        out[key] = jax.tree.map(functools.partial(merge2, nsb=nsb),
                                old_cache[key], new_cache[key])
    return out
