"""The in-network serving paths (paper §2.3 Table 1):

  * PacketPath — packet-granularity, latency-critical: jit-cached inference on
    small batches (1-10 packets, one per PHY port), the VPE side of the
    paper's split.  Reports per-packet latency.
  * FlowPath — flow-granularity, throughput-critical: batched inference over
    all ready flows (up to the 8k flow table), the AryPE side.  Reports
    flows/sec.

Both wrap the end-to-end loop: feature extraction -> DL inference -> decision
(rule-table update), i.e. the paper's working procedure steps 1 -> 6.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decisions
from repro.core.feature_extractor import packet_meta_features
from repro.core.flow_tracker import PacketBatch
from repro.models import paper_models
from repro.runtime import RoutePlan, RuntimeConfig, resolve_config


@dataclass
class PathStats:
    calls: int = 0
    total_s: float = 0.0
    items: int = 0

    @property
    def latency_us(self) -> float:
        return self.total_s / max(self.calls, 1) * 1e6

    @property
    def throughput(self) -> float:
        return self.items / max(self.total_s, 1e-12)


class PacketPath:
    """Use-case 1: per-packet MLP intrusion detection.

    The runtime config is captured at construction (``config=`` or the then-
    ambient runtime) and baked into the jit'd callable — jit caches by shapes,
    not by ambient context, so later context changes must not retune it."""

    def __init__(self, params: Any, *, config: Optional[RuntimeConfig] = None):
        self.params = params
        self.runtime = resolve_config(config)
        self.rules = decisions.RuleTable()
        self._infer = jax.jit(
            lambda p, x: decisions.decide_binary(
                paper_models.mlp_apply(p, x, config=self.runtime))
        )
        self.stats = PathStats()

    def route_plan(self, batch: int = 1) -> RoutePlan:
        """Placement report for a batch of this size (no FLOPs executed)."""
        return RoutePlan.trace(
            lambda x: paper_models.mlp_apply(self.params, x, config=self.runtime),
            jax.ShapeDtypeStruct((batch, 6), jnp.float32), config=self.runtime)

    def warmup(self, batch: int = 1):
        x = jnp.zeros((batch, 6), jnp.float32)
        jax.block_until_ready(self._infer(self.params, x))

    def process(self, packets: PacketBatch) -> np.ndarray:
        feats = packet_meta_features(packets)
        t0 = time.perf_counter()
        out = jax.block_until_ready(self._infer(self.params, feats))
        dt = time.perf_counter() - t0
        self.stats.calls += 1
        self.stats.total_s += dt
        self.stats.items += feats.shape[0]
        actions = np.asarray(out)
        self.rules.update(np.asarray(packets.tuple_hash), actions)
        return actions


class FlowPath:
    """Use-cases 2/3: flow-granularity classification over ready flows."""

    def __init__(self, params: Any, model: str = "cnn", *,
                 config: Optional[RuntimeConfig] = None):
        self.params = params
        self.model = model
        self.runtime = resolve_config(config)
        self.rules = decisions.RuleTable()
        if model == "cnn":
            self._fn = lambda p, x: paper_models.cnn_apply(p, x, config=self.runtime)
        else:
            self._fn = lambda p, x: paper_models.transformer_apply(p, x, config=self.runtime)
        self._infer = jax.jit(self._fn)
        self.stats = PathStats()

    def _abstract_input(self, flows: int) -> jax.ShapeDtypeStruct:
        shape = ((flows, paper_models.CNN_SEQ) if self.model == "cnn"
                 else (flows, paper_models.TF_PKTS, paper_models.TF_BYTES))
        return jax.ShapeDtypeStruct(shape, jnp.float32)

    def route_plan(self, flows: int) -> RoutePlan:
        """Placement report for this many flows (no FLOPs executed)."""
        return RoutePlan.trace(lambda x: self._fn(self.params, x),
                               self._abstract_input(flows), config=self.runtime)

    def warmup(self, flows: int):
        x = jnp.zeros(self._abstract_input(flows).shape, jnp.float32)
        jax.block_until_ready(self._infer(self.params, x))

    def process(self, flow_inputs: jax.Array, flow_ids: np.ndarray) -> np.ndarray:
        t0 = time.perf_counter()
        logits = jax.block_until_ready(self._infer(self.params, flow_inputs))
        dt = time.perf_counter() - t0
        self.stats.calls += 1
        self.stats.total_s += dt
        self.stats.items += flow_inputs.shape[0]
        actions, cls = decisions.decide_class(logits)
        self.rules.update(flow_ids, np.asarray(actions), np.asarray(cls))
        return np.asarray(cls)
