"""The in-network serving paths (paper §2.3 Table 1):

  * PacketPath — packet-granularity, latency-critical: jit-cached inference on
    small batches (1-10 packets, one per PHY port), the VPE side of the
    paper's split.  Reports per-packet latency.
  * FlowPath — flow-granularity, throughput-critical: batched inference over
    all ready flows (up to the 8k flow table), the AryPE side.  Reports
    flows/sec.

Both wrap the end-to-end loop: feature extraction -> DL inference -> decision
(rule-table update), i.e. the paper's working procedure steps 1 -> 6.

The model-invoke cores live in :class:`PacketEngine` / :class:`FlowEngine`:
pure ``fn(params, x)`` callables (config captured at construction) that the
standalone paths jit individually and that the streaming
:class:`repro.serving.pipeline.OctopusPipeline` composes into one fused step
— and, with ``scan_len > 1``, into a ``lax.scan`` over that step, so the
engines' static input shapes (``batch_size`` packets, ``max_ready`` flow
rows) are what keeps the whole chunk retrace-free.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decisions
from repro.core.feature_extractor import packet_meta_features
from repro.core.flow_tracker import PacketBatch
from repro.models import paper_models
from repro.runtime import RoutePlan, RuntimeConfig, resolve_config

FLOW_MODELS = ("cnn", "transformer")


@dataclass
class PathStats:
    calls: int = 0
    total_s: float = 0.0
    items: int = 0
    host_s: float = 0.0  # host share: feature staging + dispatch enqueue
    device_s: float = 0.0  # exposed device wait (the block_until_ready)

    @property
    def latency_us(self) -> float:
        """Mean wall time per call; ``nan`` until something was processed
        (0.0 would read as an impossibly fast path)."""
        if self.calls == 0:
            return math.nan
        return self.total_s / self.calls * 1e6

    @property
    def host_us(self) -> float:
        """Mean host share per call; ``nan`` while idle."""
        return self.host_s / self.calls * 1e6 if self.calls else math.nan

    @property
    def device_us(self) -> float:
        """Mean exposed device wait per call; ``nan`` while idle."""
        return self.device_s / self.calls * 1e6 if self.calls else math.nan

    @property
    def throughput(self) -> float:
        """Items/sec; 0.0 until something was processed."""
        if self.items == 0:
            return 0.0
        return self.items / max(self.total_s, 1e-12)

    def record(self, dt_s: float, items: int, *, host_s: float = 0.0,
               device_s: float = 0.0) -> None:
        """Fold one timed call in.  Empty calls are dropped — a zero-item
        submit must not skew per-call latency or throughput.  The optional
        ``host_s``/``device_s`` attribute ``dt_s`` between host work and the
        exposed device wait (callers that don't measure leave them 0)."""
        if items == 0:
            return
        self.calls += 1
        self.total_s += dt_s
        self.items += items
        self.host_s += host_s
        self.device_s += device_s


class PacketEngine:
    """Model-invoke core of the packet path (use-case 1 MLP).

    The runtime config is captured at construction (``config=`` or the then-
    ambient runtime) and baked into every trace of :meth:`fn` — jit caches by
    shapes, not by ambient context, so later context changes must not retune
    an already-compiled consumer."""

    feature_dim = 6  # packet_meta_features output width

    def __init__(self, params: Any, *, config: Optional[RuntimeConfig] = None):
        self.params = params
        self.runtime = resolve_config(config)

    def fn(self, params: Any, x: jax.Array) -> jax.Array:
        """Pure logits core — trace/jit/compose freely."""
        return paper_models.mlp_apply(params, x, config=self.runtime)

    def decide(self, params: Any, x: jax.Array) -> jax.Array:
        """logits -> binary intrusion actions (0 allow / 1 deny)."""
        return decisions.decide_binary(self.fn(params, x))

    def abstract_input(self, batch: int) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct((batch, self.feature_dim), jnp.float32)

    def route_plan(self, batch: int = 1) -> RoutePlan:
        """Placement report for a batch of this size (no FLOPs executed)."""
        return RoutePlan.trace(lambda x: self.fn(self.params, x),
                               self.abstract_input(batch), config=self.runtime)


class FlowEngine:
    """Model-invoke core of the flow path (use-case 2 CNN on interval series,
    use-case 3 transformer on payload matrices)."""

    def __init__(self, params: Any, model: str = "cnn", *,
                 config: Optional[RuntimeConfig] = None):
        if model not in FLOW_MODELS:
            raise ValueError(f"model must be one of {FLOW_MODELS}, got {model!r}")
        self.params = params
        self.model = model
        self.runtime = resolve_config(config)
        self._apply = (paper_models.cnn_apply if model == "cnn"
                       else paper_models.transformer_apply)

    def fn(self, params: Any, x: jax.Array) -> jax.Array:
        """Pure logits core — trace/jit/compose freely."""
        return self._apply(params, x, config=self.runtime)

    def prep(self, series: jax.Array, payload: jax.Array) -> jax.Array:
        """Tracker memories -> model input: log1p interval series for the CNN,
        normalized payload bytes for the transformer."""
        if self.model == "cnn":
            return jnp.log1p(series.astype(jnp.float32))
        return payload.astype(jnp.float32) / 255.0

    def abstract_input(self, flows: int) -> jax.ShapeDtypeStruct:
        shape = ((flows, paper_models.CNN_SEQ) if self.model == "cnn"
                 else (flows, paper_models.TF_PKTS, paper_models.TF_BYTES))
        return jax.ShapeDtypeStruct(shape, jnp.float32)

    def route_plan(self, flows: int) -> RoutePlan:
        """Placement report for this many flows (no FLOPs executed)."""
        return RoutePlan.trace(lambda x: self.fn(self.params, x),
                               self.abstract_input(flows), config=self.runtime)


class PacketPath:
    """Use-case 1: per-packet MLP intrusion detection (standalone wrapper
    around :class:`PacketEngine` + stats + rule table)."""

    def __init__(self, params: Any, *, config: Optional[RuntimeConfig] = None):
        self.engine = PacketEngine(params, config=config)
        self.rules = decisions.RuleTable()
        self._infer = jax.jit(self.engine.decide)
        self.stats = PathStats()

    @property
    def params(self) -> Any:
        return self.engine.params

    @property
    def runtime(self) -> RuntimeConfig:
        return self.engine.runtime

    def route_plan(self, batch: int = 1) -> RoutePlan:
        return self.engine.route_plan(batch)

    def warmup(self, batch: int = 1):
        x = jnp.zeros((batch, self.engine.feature_dim), jnp.float32)
        jax.block_until_ready(self._infer(self.params, x))

    def process(self, packets: PacketBatch) -> np.ndarray:
        feats = packet_meta_features(packets)
        if feats.shape[0] == 0:  # empty submit: no inference, no stats skew
            return np.zeros((0,), np.int32)
        t0 = time.perf_counter()
        fut = self._infer(self.params, feats)  # async dispatch: enqueue only
        t1 = time.perf_counter()
        out = jax.block_until_ready(fut)
        t2 = time.perf_counter()
        self.stats.record(t2 - t0, feats.shape[0],
                          host_s=t1 - t0, device_s=t2 - t1)
        actions = np.asarray(out)
        self.rules.update(np.asarray(packets.tuple_hash), actions)
        return actions


class FlowPath:
    """Use-cases 2/3: flow-granularity classification over ready flows
    (standalone wrapper around :class:`FlowEngine` + stats + rule table)."""

    def __init__(self, params: Any, model: str = "cnn", *,
                 config: Optional[RuntimeConfig] = None):
        self.engine = FlowEngine(params, model, config=config)
        self.rules = decisions.RuleTable()
        self._infer = jax.jit(self.engine.fn)
        self.stats = PathStats()

    @property
    def params(self) -> Any:
        return self.engine.params

    @property
    def model(self) -> str:
        return self.engine.model

    @property
    def runtime(self) -> RuntimeConfig:
        return self.engine.runtime

    def route_plan(self, flows: int) -> RoutePlan:
        return self.engine.route_plan(flows)

    def warmup(self, flows: int):
        x = jnp.zeros(self.engine.abstract_input(flows).shape, jnp.float32)
        jax.block_until_ready(self._infer(self.params, x))

    def process(self, flow_inputs: jax.Array, flow_ids: np.ndarray) -> np.ndarray:
        if flow_inputs.shape[0] == 0:  # empty submit: no inference, no stats skew
            return np.zeros((0,), np.int32)
        t0 = time.perf_counter()
        fut = self._infer(self.params, flow_inputs)  # async dispatch
        t1 = time.perf_counter()
        logits = jax.block_until_ready(fut)
        t2 = time.perf_counter()
        self.stats.record(t2 - t0, flow_inputs.shape[0],
                          host_s=t1 - t0, device_s=t2 - t1)
        actions, cls = decisions.decide_class(logits)
        self.rules.update(flow_ids, np.asarray(actions), np.asarray(cls))
        return np.asarray(cls)
