"""Sharded multi-lane serving pipeline (paper §2.2 / §4: parallel extractor
lanes over a multi-bank memory fabric).

:class:`ShardedOctopusPipeline` horizontally scales the streaming loop by
hash-partitioning incoming packets into ``num_shards`` lanes
(``shard = tuple_hash % num_shards`` — a flow's packets always land in the
same shard, so there is **no cross-shard flow state**), running each lane's
step core over its own :class:`~repro.core.flow_tracker.TrackerState` bank,
and merging the per-lane drain results into one masked emission, so
``decide`` and the rule-table feedback are unchanged downstream.

Lane execution backend (selected through ``repro.runtime.platform``):

  * ``"shard_map"`` — one device per lane on a ``lanes`` mesh axis
    (:func:`repro.launch.mesh.make_lanes_mesh`): each lane's tracker bank
    lives on its own device, the software shape of the paper's per-bank
    extractor lanes.
  * ``"vmap"``      — single-device fallback: lanes are batched.  For the
    ``"scan"`` tracker this still cuts the sequential depth from the global
    batch to the per-lane capacity (``vmap`` of a ``lax.scan`` is one scan
    with a batched body), which is where the CPU-smoke scaling comes from.

Exactness contract (differentially tested against the single-lane oracle in
``tests/test_sharded.py``): whenever (a) flows that share a table slot also
share a shard — always true under collision-free traffic, and for any
same-shard collision — and (b) the drain budget keeps up with the ready rate
(no lane ever holds back a ready flow: the global ``max_ready`` splits into
``max_ready / num_shards`` per lane, so a backlogged lane drains later than
the oracle's global lowest-slots-first order would, shifting the emitted
count/feature snapshot), the union of drained flows, the residual per-shard
table contents, and every per-flow decision are bit-identical to
:class:`~repro.serving.pipeline.OctopusPipeline` consuming the same stream.
The differential tests assert the no-backlog precondition on both sides
instead of trusting it.
Each lane keeps a full ``table_size`` bank with the *same* slot mapping as
the single-lane table, so a flow's slot number is shard-invariant; what a
lane cannot see is an eviction by a flow of another shard, which is exactly
the cross-shard collision case excluded above.

Skew handling: per-lane capacity (``lane_batch``) defaults to the full
``batch_size`` — skew-proof, one fused dispatch per step.  A smaller
``lane_batch`` trades padding for rounds: overflowing lanes spill into
merge-only rounds ahead of the fused drain step
(:func:`repro.data.traffic.partition_batch` splits each lane's FIFO into
capacity-sized windows; the tracker merge composes sequentially, so the
result stays bit-exact and the drain still happens once per global batch).
"""
from __future__ import annotations

import time
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map

from repro.core import feature_extractor as fx
from repro.core import flow_tracker as ft
from repro.data.traffic import ShardedBatch, partition_batch, shard_of
from repro.distributed import sharding as shd
from repro.launch.mesh import make_lanes_mesh
from repro.runtime import RoutePlan, RuntimeConfig, lane_scope, name_scope, platform
from repro.serving.pipeline import (
    InflightDispatch,
    OctopusPipeline,
    PipelineConfig,
    PipelineStepOutput,
)

LANE_BACKENDS = ("vmap", "shard_map")


class ShardedOctopusPipeline(OctopusPipeline):
    """Hash-partitioned multi-lane :class:`OctopusPipeline`.

    Same public surface as the single-lane pipeline — ``step`` takes the
    same global ``batch_size`` microbatch and returns a merged
    :class:`PipelineStepOutput` with identical shapes (``pkt_actions`` in
    original batch order; ``max_ready`` drained rows = ``num_shards`` lanes
    × ``max_ready / num_shards`` budget each) — so the differential harness
    can drive both from one seeded :class:`~repro.data.traffic.TrafficGenerator`.
    """

    def __init__(self, packet_params: Any, flow_params: Any,
                 cfg: PipelineConfig = PipelineConfig(), *,
                 num_shards: int,
                 lane_batch: Optional[int] = None,
                 backend: Optional[str] = None,
                 config: Optional[RuntimeConfig] = None,
                 program: Optional[jax.Array] = None):
        if num_shards <= 0:
            raise ValueError(f"num_shards must be positive, got {num_shards}")
        if cfg.max_ready % num_shards:
            raise ValueError(
                f"max_ready={cfg.max_ready} must divide evenly into "
                f"num_shards={num_shards} lane budgets")
        self.num_shards = num_shards
        self.lane_ready = cfg.max_ready // num_shards
        self.lane_batch = cfg.batch_size if lane_batch is None else int(lane_batch)
        if not 0 < self.lane_batch <= cfg.batch_size:
            raise ValueError(f"lane_batch must be in [1, {cfg.batch_size}], "
                             f"got {self.lane_batch}")
        if cfg.scan_len > 1 and self.lane_batch != cfg.batch_size:
            raise ValueError("scan_len > 1 needs the skew-proof lane_batch "
                             "== batch_size (overflow rounds are dispatched "
                             "per step, not scanned)")
        self.backend = backend if backend is not None else \
            platform.lanes_backend(num_shards)
        if self.backend not in LANE_BACKENDS:
            raise ValueError(f"backend must be one of {LANE_BACKENDS}, "
                             f"got {self.backend!r}")
        # the mesh must exist before super().__init__ constructs the state
        # through the _fresh_state hook
        self.mesh = make_lanes_mesh(num_shards) \
            if self.backend == "shard_map" else None
        super().__init__(packet_params, flow_params, cfg, config=config,
                         program=program)
        self._step_fn = jax.jit(self._sharded_step, donate_argnums=(0,))
        self._chunk_fn = jax.jit(self._sharded_chunk, donate_argnums=(0,))
        self._merge_fn = jax.jit(self._sharded_merge, donate_argnums=(0,))
        self._merge_warmed = False

    # ----------------------------------------------------------- lane plumbing
    def _fresh_state(self):
        """Stacked per-lane tracker banks (leading ``num_shards`` axis), each
        a full ``table_size`` table so slot numbering is shard-invariant.
        With ``cold_size > 0`` every lane also owns a private cold bank (the
        tiling maps over the whole two-level pytree) — spills and promotes
        stay lane-local, like every other piece of flow state.  Under
        shard_map the banks are pre-placed on the ``lanes`` axis so the
        carried state never reshards."""
        one = super()._fresh_state()
        stacked = jax.tree_util.tree_map(
            lambda a: jnp.tile(a[None], (self.num_shards,) + (1,) * a.ndim), one)
        if self.mesh is not None:
            stacked = jax.device_put(
                stacked, shd.lanes_shardings(self.mesh, stacked))
        return stacked

    def _over_lanes(self, fn):
        """Map a per-lane function over the leading shard axis of every
        argument: ``vmap`` on single-device hosts, ``shard_map`` on the
        ``lanes`` mesh.  Under shard_map each device holds exactly one lane
        (local leading block of size 1), which is squeezed away so the lane
        body runs *unbatched* — its table updates stay dynamic-update-slices
        (in place) instead of vmap's batched scatters, which is where the
        per-device lanes win their throughput."""
        if self.backend == "vmap":
            return jax.vmap(fn)

        def body(*args):
            out = fn(*jax.tree_util.tree_map(lambda x: x[0], args))
            return jax.tree_util.tree_map(lambda x: x[None], out)

        spec = shd.lanes_spec()
        return shard_map(body, mesh=self.mesh, in_specs=spec, out_specs=spec)

    def _merge_out(self, outs: PipelineStepOutput, src: jax.Array, *,
                   batch: Optional[int] = None) -> PipelineStepOutput:
        """Per-lane outputs (leading ``num_shards`` axis) -> one merged
        step output with the single-lane shapes: packet actions scattered
        back to original batch order (padding rows carry ``src ==
        batch_size`` and drop), lane drain rows concatenated into the global
        ``max_ready`` emission.  ``batch`` overrides the scatter target size
        for bucket-shaped masked steps (default: the config batch)."""
        B = self.cfg.batch_size if batch is None else batch
        pkt_actions = jnp.zeros((B,), jnp.int32).at[src.reshape(-1)].set(
            outs.pkt_actions.reshape(-1), mode="drop")
        flat = lambda a: a.reshape((self.cfg.max_ready,) + a.shape[2:])
        return PipelineStepOutput(
            pkt_actions=pkt_actions,
            drained=jax.tree_util.tree_map(flat, outs.drained),
            flow_actions=flat(outs.flow_actions),
            flow_cls=flat(outs.flow_cls),
            flow_scores=flat(outs.flow_scores),
            new_flows=outs.new_flows.sum().astype(jnp.int32),
            evicted=outs.evicted.sum().astype(jnp.int32),
            spilled=outs.spilled.sum().astype(jnp.int32),
            promoted=outs.promoted.sum().astype(jnp.int32),
        )

    # ------------------------------------------------------------ traced cores
    def _lanes_cond(self, make_lane, states, shards, keep):
        """Run ``make_lane(fallback)`` over every lane.  For the segmented
        tracker under vmap, the collision-fallback branch is hoisted out
        here: a vmapped ``lax.cond`` lowers to a select that runs the scan
        oracle on every batch, so instead ONE cond on "any lane collides"
        picks between the two statically-selected vmapped variants —
        collision-free batches (the common case) never touch the scan."""
        if self.cfg.tracker != "segmented" or self.backend != "vmap":
            return self._over_lanes(make_lane("auto"))(states, shards, keep)
        collides = jax.vmap(
            lambda p, k: fx.batch_collisions(p, self.cfg.table_size, k)
        )(shards, keep).any()
        return lax.cond(
            collides,
            lambda s, p, k: self._over_lanes(make_lane("always"))(s, p, k),
            lambda s, p, k: self._over_lanes(make_lane("never"))(s, p, k),
            states, shards, keep)

    def _sharded_core(self, states: ft.TrackerState, shards: ft.PacketBatch,
                      keep: jax.Array, src: jax.Array, *,
                      batch: Optional[int] = None
                      ) -> tuple[ft.TrackerState, PipelineStepOutput]:
        """One full sharded step: every lane runs the shard-shaped
        ``_lane_core`` (merge + lane-budget drain + both engines + decide)
        on its partition, then the lane outputs merge."""
        def make_lane(fb):
            return lambda st, p, k: self._lane_core(
                st, p, k, max_ready=self.lane_ready, fallback=fb)

        states, outs = self._lanes_cond(make_lane, states, shards, keep)
        return states, self._merge_out(outs, src, batch=batch)

    def _sharded_step(self, states, shards, keep, src):
        self.trace_count += 1  # python side effect: runs per trace, not per call
        return self._sharded_core(states, shards, keep, src)

    def _sharded_chunk(self, states, shards, keep, src):
        """``scan_len`` sharded steps in one dispatch (lockstep lanes only:
        every scanned step is a single round)."""
        self.trace_count += 1  # python side effect: runs per trace, not per call
        return lax.scan(lambda st, xs: self._sharded_core(st, *xs),
                        states, (shards, keep, src))

    def _masked_step(self, states, shards, keep, src):
        """Bucket-shaped sharded entry point: lane shapes are (S, bucket) —
        the masked dispatch always partitions at full bucket capacity (single
        round, skew-proof), so the merge scatter target is the bucket, read
        off the static lane shape."""
        self.trace_count += 1  # python side effect: runs per trace, not per call
        return self._sharded_core(states, shards, keep, src,
                                  batch=src.shape[1])

    def _sharded_merge(self, states, shards, keep):
        """Merge-only overflow round (step 2 + the per-packet engine): folds
        one spill window into every lane's bank without draining — the drain
        and flow engine run once per global batch, in the final round, so
        multi-round steps stay bit-exact to the oracle."""
        self.trace_count += 1  # python side effect: runs per trace, not per call

        def make_lane(fb):
            def lane(st, p, k):
                st, new, ev, sp, pr = self._track(st, p, k, fallback=fb)
                return st, new, ev, sp, pr, self._decide_pkt(p)

            return lane

        return self._lanes_cond(make_lane, states, shards, keep)

    # -------------------------------------------------------------- host loop
    def _partition(self, packets: ft.PacketBatch) -> list[ShardedBatch]:
        lane_batch = None if self.lane_batch == self.cfg.batch_size \
            else self.lane_batch
        return partition_batch(packets, self.num_shards, lane_batch=lane_batch)

    def _padded_rows(self, rounds: Sequence[ShardedBatch]) -> int:
        """Masked lane rows this step will dispatch.  Pure arithmetic —
        conservation guarantees the kept rows across all rounds are exactly
        the global batch, so no device readback is needed on the hot loop."""
        return (len(rounds) * self.num_shards * self.lane_batch
                - self.cfg.batch_size)

    def _dispatch_step(self, packets: ft.PacketBatch) -> InflightDispatch:
        """One global microbatch through all lanes, deferred-sync: the hash
        partition and EVERY round's enqueue (overflow merges + the fused
        drain step) happen now, without a single device readback — the old
        eager loop blocked on each merge round's counters mid-step.  The
        handle's ``wait`` blocks once, overlays the multi-round packet
        verdicts, applies feedback and records stats."""
        n = self._check_batch(packets)
        t0 = time.perf_counter()
        rounds = self._partition(packets)
        merge_outs = []
        for sb in rounds[:-1]:
            (self.state, new, ev, sp, pr,
             acts) = self._merge_fn(self.state, sb.shards, sb.keep)
            merge_outs.append((sb, new, ev, sp, pr, acts))
        last = rounds[-1]
        self.state, out = self._step_fn(self.state, last.shards, last.keep,
                                        last.src)
        enqueue_s = time.perf_counter() - t0
        self._step_warmed = True

        def finish(host_extra_s: float) -> PipelineStepOutput:
            t1 = time.perf_counter()
            jax.block_until_ready(out)
            device_s = time.perf_counter() - t1
            t2 = time.perf_counter()
            merged = out
            if merge_outs:  # overlay earlier rounds' packet verdicts
                pkt_merged = np.zeros((n,), np.int32)
                total_new = total_ev = total_sp = total_pr = 0
                for sb, new, ev, sp, pr, acts in merge_outs:
                    total_new += int(np.asarray(new).sum())
                    total_ev += int(np.asarray(ev).sum())
                    total_sp += int(np.asarray(sp).sum())
                    total_pr += int(np.asarray(pr).sum())
                    k = np.asarray(sb.keep)
                    pkt_merged[np.asarray(sb.src)[k]] = np.asarray(acts)[k]
                pos = np.asarray(last.src)[np.asarray(last.keep)]
                pkt_merged[pos] = np.asarray(out.pkt_actions)[pos]
                merged = out._replace(
                    pkt_actions=jnp.asarray(pkt_merged),
                    new_flows=jnp.int32(total_new + int(out.new_flows)),
                    evicted=jnp.int32(total_ev + int(out.evicted)),
                    spilled=jnp.int32(total_sp + int(out.spilled)),
                    promoted=jnp.int32(total_pr + int(out.promoted)))

            n_flows = self._feedback(
                np.asarray(packets.tuple_hash),
                np.asarray(merged.pkt_actions),
                np.asarray(merged.drained.mask),
                np.asarray(merged.drained.tuple_id),
                np.asarray(merged.flow_actions),
                np.asarray(merged.flow_cls))
            host_s = (enqueue_s + host_extra_s
                      + (time.perf_counter() - t2))
            self.stats.record_dispatch(
                host_s + device_s, packets=n, dispatches=len(rounds),
                flows=n_flows, new_flows=int(merged.new_flows),
                evicted=int(merged.evicted), spilled=int(merged.spilled),
                promoted=int(merged.promoted),
                padded=self._padded_rows(rounds),
                host_s=host_s, device_s=device_s)
            return merged

        return InflightDispatch(finish, steps=1, packets=n)

    def _dispatch_chunk(self, batches: Sequence[ft.PacketBatch]
                        ) -> InflightDispatch:
        """Exactly ``scan_len`` global microbatches enqueued as one device
        dispatch (``lax.scan`` over the fused sharded step — lockstep lanes,
        so every scanned step is one round); partition hashing happens now,
        feedback in the handle's ``wait``, in step order."""
        L = self.cfg.scan_len
        batches = list(batches)
        if len(batches) != L:
            raise ValueError(f"step_many needs exactly scan_len={L} "
                             f"microbatches, got {len(batches)}")
        if self.lane_batch != self.cfg.batch_size:
            # multi-round partitions cannot stack into one scanned dispatch
            # (overflow rounds would be dropped); the constructor pins
            # scan_len == 1 for this mode, so the chunk is a single step —
            # route it through the per-step dispatch, which enqueues every
            # round, and add the leading step axis on resolution
            inner = self._dispatch_step(batches[0])

            def finish(host_extra_s: float) -> PipelineStepOutput:
                inner.add_host_time(host_extra_s)
                out = inner.wait()  # records the dispatch in stats itself
                return jax.tree_util.tree_map(
                    lambda a: jnp.asarray(a)[None], out)

            return InflightDispatch(finish, steps=1,
                                    packets=self.cfg.batch_size)
        for b in batches:
            self._check_batch(b)
        t0 = time.perf_counter()
        parts = [self._partition(b)[0] for b in batches]  # lockstep: 1 round
        shards, keep, src = (jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                                    *leaves)
                             for leaves in zip(*parts))
        self.state, out = self._chunk_fn(self.state, shards, keep, src)
        enqueue_s = time.perf_counter() - t0
        n = L * self.cfg.batch_size
        # parts holds one single-round partition PER STEP — padding is per
        # step, not one multi-round step's worth
        padded = sum(self._padded_rows([p]) for p in parts)

        def finish(host_extra_s: float) -> PipelineStepOutput:
            t1 = time.perf_counter()
            jax.block_until_ready(out)
            device_s = time.perf_counter() - t1
            t2 = time.perf_counter()
            n_flows = self._chunk_feedback(batches, out)
            host_s = (enqueue_s + host_extra_s
                      + (time.perf_counter() - t2))
            self.stats.record_dispatch(
                host_s + device_s, packets=n, steps=L, flows=n_flows,
                new_flows=int(np.asarray(out.new_flows).sum()),
                evicted=int(np.asarray(out.evicted).sum()),
                spilled=int(np.asarray(out.spilled).sum()),
                promoted=int(np.asarray(out.promoted).sum()),
                padded=padded, host_s=host_s, device_s=device_s)
            return out

        return InflightDispatch(finish, steps=L, packets=n)

    def _zero_parts(self, bucket: Optional[int] = None) -> ShardedBatch:
        C = self.lane_batch if bucket is None else bucket
        S = self.num_shards
        B = self.cfg.batch_size if bucket is None else bucket
        pkt = jax.tree_util.tree_map(
            lambda a: jnp.zeros((S, C) + a.shape[1:], a.dtype),
            self._zero_batch())
        return ShardedBatch(shards=pkt, keep=jnp.zeros((S, C), bool),
                            src=jnp.full((S, C), B, jnp.int32))

    # ---------------------------------------------------- bucketed (masked)
    def warm_bucket(self, bucket: int) -> None:
        """Pre-compile the masked sharded entry for one bucket size: lane
        shapes (num_shards, bucket), single round."""
        if bucket <= 0:
            raise ValueError(f"bucket must be positive, got {bucket}")
        if bucket in self._warm_buckets:
            return
        scratch = self._fresh_state()
        zb = self._zero_parts(bucket)
        _, out = self._masked_fn(scratch, zb.shards, zb.keep, zb.src)
        jax.block_until_ready(out)
        self._warm_buckets.add(bucket)

    def step_masked(self, packets: ft.PacketBatch,
                    keep: np.ndarray) -> PipelineStepOutput:
        """One padded request batch through all lanes.  The keep mask is
        folded into the hash partition (padding rows land in no lane), and
        the partition runs at full bucket capacity — always one round, so a
        bucket compiles exactly one entry whatever the skew."""
        bucket = int(np.asarray(packets.ts).shape[0])
        k = np.asarray(keep, bool)
        if k.shape != (bucket,):
            raise ValueError(f"keep must have shape ({bucket},), got {k.shape}")
        n = int(k.sum())
        t0 = time.perf_counter()
        sb = partition_batch(packets, self.num_shards, keep=k)[0]
        self.state, out = self._masked_fn(self.state, sb.shards, sb.keep,
                                          sb.src)
        t1 = time.perf_counter()
        jax.block_until_ready((self.state, out))
        t2 = time.perf_counter()
        self._warm_buckets.add(bucket)

        n_flows = self._feedback(
            np.asarray(packets.tuple_hash)[k], np.asarray(out.pkt_actions)[k],
            np.asarray(out.drained.mask), np.asarray(out.drained.tuple_id),
            np.asarray(out.flow_actions), np.asarray(out.flow_cls))
        t3 = time.perf_counter()

        host_s, device_s = (t1 - t0) + (t3 - t2), t2 - t1
        self.stats.record_dispatch(
            host_s + device_s, packets=n, flows=n_flows,
            new_flows=int(out.new_flows),
            evicted=int(out.evicted), spilled=int(out.spilled),
            promoted=int(out.promoted),
            padded=self.num_shards * bucket - n,
            host_s=host_s, device_s=device_s)
        return out

    def warmup(self) -> None:
        """Compile the dispatch paths ``run`` will use on throwaway state:
        the chunked path when ``scan_len > 1``, else the fused step (plus the
        merge-only round when a smaller ``lane_batch`` makes overflow rounds
        possible)."""
        scratch = self._fresh_state()
        zb = self._zero_parts()
        if self.cfg.scan_len > 1:
            stacked = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (self.cfg.scan_len,) + a.shape),
                zb)
            _, out = self._chunk_fn(scratch, stacked.shards, stacked.keep,
                                    stacked.src)
            jax.block_until_ready(out)
        else:
            if self.lane_batch < self.cfg.batch_size:
                scratch, *_ = self._merge_fn(scratch, zb.shards, zb.keep)
                self._merge_warmed = True
            _, out = self._step_fn(scratch, zb.shards, zb.keep, zb.src)
            jax.block_until_ready(out)
            self._step_warmed = True

    def _warm_step(self) -> None:
        if self._step_warmed:
            return
        scratch = self._fresh_state()
        zb = self._zero_parts()
        if self.lane_batch < self.cfg.batch_size and not self._merge_warmed:
            scratch, *_ = self._merge_fn(scratch, zb.shards, zb.keep)
            self._merge_warmed = True
        _, out = self._step_fn(scratch, zb.shards, zb.keep, zb.src)
        jax.block_until_ready(out)
        self._step_warmed = True

    # ------------------------------------------------------------- placement
    def plan(self) -> RoutePlan:
        """One RoutePlan across every lane's engines, each lane traced under
        its own ``lane<i>/`` scope (``plan().scoped("lane0")`` extracts one
        lane).  Shapes are per lane: the packet engine sees the lane capacity
        ``lane_batch``, the flow engine the lane drain budget."""
        use_pkt = self.cfg.pkt_head.needs_logits
        use_flow = self.cfg.flow_head.needs_logits

        def all_lanes(px: jax.Array, fx_: jax.Array):
            out = []
            for i in range(self.num_shards):
                with lane_scope(i):
                    if use_pkt:
                        with name_scope("pkt"):
                            out.append(self.packet_engine.fn(
                                self.packet_engine.params, px))
                    if use_flow:
                        with name_scope("flow"):
                            out.append(self.flow_engine.fn(
                                self.flow_engine.params, fx_))
            return out

        return RoutePlan.trace(
            all_lanes, self.packet_engine.abstract_input(self.lane_batch),
            self.flow_engine.abstract_input(self.lane_ready),
            config=self.runtime)

    def explain(self) -> str:
        """Placement report for the multi-lane step: the lane topology plus
        the composite per-lane plan."""
        plan = self.plan()
        c = self.cfg
        head = (f"ShardedOctopusPipeline: lanes={self.num_shards} "
                f"backend={self.backend} lane_batch={self.lane_batch} "
                f"lane_ready={self.lane_ready} batch={c.batch_size} "
                f"max_ready={c.max_ready} flow_model={c.flow_model} "
                f"table={c.table_size}x{self.num_shards} top_n={c.top_n} "
                f"tracker={c.tracker} scan_len={c.scan_len}")
        if c.cold_size:
            head += f" cold={c.cold_size}x{self.num_shards}({c.cold_policy})"
        head += f" heads={c.pkt_head.name}/{c.flow_head.name}"
        lines = [head, plan.explain()]
        for i in range(self.num_shards):
            sub = plan.scoped(f"lane{i}", strip=True)
            pkt = sub.scoped("pkt")
            flow = sub.scoped("flow")
            lines.append(f"  lane{i}: {len(pkt)} pkt + {len(flow)} flow "
                         f"matmuls, {sub.macs()} MACs")
        return "\n".join(lines)


__all__ = ["ShardedOctopusPipeline", "LANE_BACKENDS", "partition_batch",
           "shard_of"]
