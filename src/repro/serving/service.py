"""Async batch serving frontend over the streaming pipelines.

The paper's Octopus sits on the data plane and absorbs whatever arrival
pattern the wire delivers; :class:`~repro.serving.pipeline.OctopusPipeline`
is the compute analogue, but its ``run()`` loop is synchronous and fed by a
single generator.  Serving many concurrent clients with uneven, bursty
arrivals is a queueing problem in front of a fixed-shape inference engine —
the shape dataplane co-processors (and batch LLM servers like SHARK's
``service_v1``) all share:

  * a **request queue** accepting per-client packet microbatches of
    arbitrary size (:meth:`OctopusService.submit`),
  * a **batcher** that coalesces queued requests and pads the coalesced
    batch to the nearest pre-warmed ``bucket`` size — every bucket's masked
    entry point is compiled at startup, so ragged arrivals *never retrace*
    (``trace_count`` stays flat after :meth:`start`; asserted in tests),
  * **inflight buffer pooling**: the host staging arrays a dispatch packs
    requests into are reused per bucket, not reallocated per request,
  * **admission control**: when queued packets exceed ``depth_budget``, new
    submissions either get an explicit :class:`Rejected` result (``"shed"``)
    or wait for space (``"block"``), policy-selectable,
  * **latency observability**: per-client and global p50/p99 queue-wait and
    end-to-end latency (bounded :class:`~repro.serving.pipeline.LatencyReservoir`
    samples) plus queue-depth high-water marks in :class:`ServiceStats`.

The device dispatch stays *serialized* — the tracker state is a sequential
carry, there is exactly one engine — but with ``ServiceConfig.offload``
(the default) it runs on a single-thread executor instead of the event
loop: clients keep enqueueing while a device step executes, instead of only
in the ``batch_wait_s`` grace window, so the next dispatch coalesces what
arrived *during* the current one.  All bookkeeping (futures, queue depth,
admission events) stays on the loop side — only the pack + ``step_masked``
block moves off it.  A failing dispatch resolves every coalesced request's
future with the error, returns the staging buffer to the pool, and restores
the queue depth, so admission control never wedges and the service keeps
serving (regression-tested).  ``asyncio`` here buys exactly what the
paper's wire interface buys the FPGA: many independent arrival processes
multiplexed into one fixed-shape compute loop.  Clients run closed-loop
(``await submit(...)``) and the batcher's coalescing is where concurrency
turns into throughput: N clients awaiting together become one padded bucket
dispatch instead of N tiny ones.

Correctness: a request of size ``b < bucket`` padded-then-served produces
verdicts and tracker state **bit-identical** to serving it through the
unpadded synchronous pipeline (the keep-mask machinery from the sharded
lanes; differentially tested in ``tests/test_service.py``).
"""
from __future__ import annotations

import asyncio
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Optional, Union

import jax.numpy as jnp
import numpy as np

from repro.core.flow_tracker import PacketBatch
from repro.data.traffic import TrafficGenerator
from repro.serving.pipeline import LatencyReservoir, OctopusPipeline

ADMISSION_POLICIES = ("shed", "block")

# PacketBatch scalar (per-packet) int32 leaves, in field order; payload is
# the one 2-D leaf and is staged separately.
_SCALAR_FIELDS = ("ts", "size", "dir", "flags", "proto", "tuple_hash")


@dataclass(frozen=True)
class ServiceConfig:
    """Knobs of the serving frontend (see docs/ARCHITECTURE.md for the
    knob table)."""

    buckets: tuple[int, ...] = (32, 64, 128, 256)  # pre-warmed batch shapes
    depth_budget: int = 1024  # max queued packets before admission control
    admission: str = "shed"  # "shed" -> Rejected result | "block" -> await
    batch_wait_s: float = 0.0  # grace the batcher waits to coalesce more
    sample_capacity: int = 1024  # latency reservoir depth (per scope)
    pool_depth: int = 4  # staging buffers retained per bucket
    offload: bool = True  # run pack + device dispatch on an executor thread
    # (event loop stays free to accept submits); False = inline (the old
    # behavior, kept for the overlap-on/off bench twins)

    def __post_init__(self):
        if not self.buckets or any(b <= 0 for b in self.buckets):
            raise ValueError(f"buckets must be positive, got {self.buckets}")
        if tuple(sorted(set(self.buckets))) != tuple(self.buckets):
            raise ValueError(f"buckets must be strictly increasing, "
                             f"got {self.buckets}")
        if self.admission not in ADMISSION_POLICIES:
            raise ValueError(f"admission must be one of {ADMISSION_POLICIES}, "
                             f"got {self.admission!r}")
        if self.depth_budget <= 0 or self.pool_depth <= 0:
            raise ValueError("depth_budget and pool_depth must be positive")
        if self.batch_wait_s < 0:
            raise ValueError(f"batch_wait_s must be >= 0, "
                             f"got {self.batch_wait_s}")


@dataclass(frozen=True)
class ServeResult:
    """One served request: per-packet verdicts in the request's own order."""

    client_id: int
    # (n,) int32 packet-head verdicts (default binary head: 0 allow / 1 deny;
    # pluggable heads — PipelineConfig.pkt_head — define their own codes)
    pkt_actions: np.ndarray
    bucket: int  # largest bucket this request ACTUALLY dispatched in — the
    # coalesced dispatch's bucket, not the request's own size class (0 for
    # the empty-submit fast path, which never dispatches)
    queue_wait_s: float  # enqueue -> dispatch start
    e2e_s: float  # enqueue -> verdicts ready
    buckets: tuple[int, ...] = ()  # per-chunk dispatch buckets, in order
    # (an oversize submit splits into several chunks; each records its own)


@dataclass(frozen=True)
class Rejected:
    """Admission-control shed: the queue was over budget when this request
    arrived.  An explicit result, not an exception — shedding is a normal
    dataplane outcome the client is expected to handle (retry, back off)."""

    client_id: int
    packets: int  # size of the rejected request
    queue_depth: int  # queued packets at rejection time
    depth_budget: int


SubmitOutcome = Union[ServeResult, Rejected]


@dataclass
class ClientStats:
    """Per-client slice of the service counters."""

    requests: int = 0
    submitted: int = 0  # packets offered (incl. shed)
    served: int = 0  # packets that got verdicts
    shed: int = 0  # packets rejected by admission control
    wait: LatencyReservoir = field(default_factory=LatencyReservoir)
    e2e: LatencyReservoir = field(default_factory=LatencyReservoir)


@dataclass
class ServiceStats:
    """Global service counters + per-client breakdown.  The latency
    reservoirs sample in **microseconds**; idle percentiles are ``nan``
    (the ``PipelineStats`` convention)."""

    requests: int = 0
    served_requests: int = 0
    shed_requests: int = 0
    submitted: int = 0  # packets offered
    served: int = 0  # packets dispatched + answered
    shed: int = 0  # packets rejected
    dispatches: int = 0  # bucket dispatches issued
    coalesced: int = 0  # requests merged into those dispatches
    padded: int = 0  # bucket pad rows dispatched (masked)
    depth_hwm: int = 0  # queue-depth high-water mark (packets)
    pool_hits: int = 0
    pool_misses: int = 0
    failed_dispatches: int = 0  # dispatches whose step raised
    failed: int = 0  # packets answered with an error instead of verdicts
    host_s: float = 0.0  # dispatch host share: staging-buffer pack + slicing
    device_s: float = 0.0  # dispatch device share: the masked-step block
    started_at: float = 0.0  # perf_counter anchor set by start(); 0 = never
    stopped_at: float = 0.0  # freeze anchor set by stop(); 0 while running
    wait: LatencyReservoir = field(default_factory=LatencyReservoir)
    e2e: LatencyReservoir = field(default_factory=LatencyReservoir)
    clients: dict[int, ClientStats] = field(default_factory=dict)

    def client(self, client_id: int) -> ClientStats:
        st = self.clients.get(client_id)
        if st is None:
            cap = self.wait.capacity
            st = self.clients[client_id] = ClientStats(
                wait=LatencyReservoir(cap), e2e=LatencyReservoir(cap))
        return st

    @property
    def wall_s(self) -> float:
        """Service wall clock, snapshotted at READ time while the service
        runs and frozen at :meth:`OctopusService.stop`.  (It used to be a
        field refreshed only inside the dispatcher, so any read after the
        last dispatch — an idle tail, a post-run report — used a stale
        clock and overstated ``pkt_per_s``.)"""
        if not self.started_at:
            return 0.0
        end = self.stopped_at if self.stopped_at else time.perf_counter()
        return max(end - self.started_at, 0.0)

    @property
    def pkt_per_s(self) -> float:
        """Sustained served packet rate over the service's wall clock."""
        wall = self.wall_s
        return self.served / wall if wall > 0 else 0.0

    @property
    def host_us(self) -> float:
        """Mean host share per dispatch (pack + result slicing)."""
        return self.host_s / self.dispatches * 1e6 if self.dispatches else float("nan")

    @property
    def device_us(self) -> float:
        """Mean device share per dispatch (the masked-step block)."""
        return self.device_s / self.dispatches * 1e6 if self.dispatches else float("nan")


class _BufferPool:
    """Per-bucket pool of host staging arrays (one PacketBatch worth of
    numpy leaves + a keep mask).  ``jnp.asarray`` copies host memory into
    the device buffer at dispatch and the dispatcher blocks on the result,
    so a released buffer is safe to refill immediately — requests reuse the
    staging arrays instead of allocating fresh ones per dispatch."""

    def __init__(self, pay_bytes: int, depth: int, stats: ServiceStats):
        self.pay_bytes = pay_bytes
        self.depth = depth
        self.stats = stats
        self._free: dict[int, list[dict]] = {}

    def acquire(self, bucket: int) -> dict:
        free = self._free.setdefault(bucket, [])
        if free:
            self.stats.pool_hits += 1
            return free.pop()
        self.stats.pool_misses += 1
        buf = {f: np.zeros(bucket, np.int32) for f in _SCALAR_FIELDS}
        buf["payload"] = np.zeros((bucket, self.pay_bytes), np.int32)
        buf["keep"] = np.zeros(bucket, bool)
        return buf

    def release(self, buf: dict) -> None:
        free = self._free.setdefault(len(buf["keep"]), [])
        if len(free) < self.depth:
            free.append(buf)


@dataclass
class _Pending:
    """One queued request chunk (a submit larger than the largest bucket
    splits into several, each at most one bucket)."""

    client_id: int
    leaves: dict  # host numpy views of the PacketBatch leaves
    n: int
    enqueued_at: float
    future: asyncio.Future
    dispatched_at: float = 0.0
    bucket: int = 0  # the bucket this chunk actually dispatched in


class OctopusService:
    """Asyncio serving frontend over an :class:`OctopusPipeline` (or
    :class:`~repro.serving.sharded.ShardedOctopusPipeline` — both expose the
    same ``warm_bucket``/``step_masked`` masked entry surface).

    Lifecycle::

        service = OctopusService(pipeline, ServiceConfig(buckets=(32, 64)))
        await service.start()        # pre-warms every bucket entry point
        result = await service.submit(batch, client_id=7)
        await service.stop()         # drains the queue, then stops

    or ``async with OctopusService(...) as service: ...``.
    """

    def __init__(self, pipeline: OctopusPipeline,
                 cfg: ServiceConfig = ServiceConfig()):
        self.pipeline = pipeline
        self.cfg = cfg
        self.stats = ServiceStats(
            wait=LatencyReservoir(cfg.sample_capacity),
            e2e=LatencyReservoir(cfg.sample_capacity))
        self._pool = _BufferPool(pipeline.cfg.pay_bytes, cfg.pool_depth,
                                 self.stats)
        self._queue: deque[_Pending] = deque()
        self._depth = 0  # queued packets
        self._work: Optional[asyncio.Event] = None
        self._space: Optional[asyncio.Event] = None
        self._dispatcher: Optional[asyncio.Task] = None
        self._executor: Optional[ThreadPoolExecutor] = None
        self._stopping = False

    # ------------------------------------------------------------- lifecycle
    @property
    def trace_count(self) -> int:
        """The pipeline's retrace counter — flat after :meth:`start` is the
        no-retrace-on-ragged-arrivals proof."""
        return self.pipeline.trace_count

    @property
    def queue_depth(self) -> int:
        """Currently queued packets (admission control's input)."""
        return self._depth

    async def start(self) -> None:
        """Pre-compile every bucket's masked entry point (outside any timed
        region) and start the dispatcher task (plus its single-thread
        dispatch executor when ``cfg.offload``)."""
        if self._dispatcher is not None:
            raise RuntimeError("service already started")
        for b in self.cfg.buckets:
            self.pipeline.warm_bucket(b)
        self._work = asyncio.Event()
        self._space = asyncio.Event()
        self._stopping = False
        if self.cfg.offload:
            # exactly one worker: the tracker state is a sequential carry,
            # so dispatches must serialize — the thread only exists to keep
            # the event loop free while a device step blocks
            self._executor = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="octopus-dispatch")
        self.stats.started_at = time.perf_counter()
        self.stats.stopped_at = 0.0
        self._dispatcher = asyncio.create_task(self._dispatch_loop())

    async def stop(self) -> None:
        """Drain the queue (every accepted request still gets its result),
        then stop the dispatcher and freeze the wall clock."""
        if self._dispatcher is None:
            return
        self._stopping = True
        self._work.set()
        await self._dispatcher
        self._dispatcher = None
        if self._executor is not None:
            self._executor.shutdown(wait=True)
            self._executor = None
        self.stats.stopped_at = time.perf_counter()

    async def __aenter__(self) -> "OctopusService":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # ---------------------------------------------------------------- submit
    def _host_leaves(self, packets: PacketBatch) -> dict:
        leaves = {f: np.asarray(getattr(packets, f)) for f in _SCALAR_FIELDS}
        leaves["payload"] = np.asarray(packets.payload)
        if leaves["payload"].shape[1:] != (self.pipeline.cfg.pay_bytes,):
            raise ValueError(
                f"payload width {leaves['payload'].shape[1:]} does not match "
                f"the pipeline's pay_bytes={self.pipeline.cfg.pay_bytes}")
        return leaves

    async def submit(self, packets: PacketBatch,
                     client_id: int = 0) -> SubmitOutcome:
        """Queue one microbatch (any size) and await its verdicts.

        Admission control runs *before* anything is enqueued, against the
        whole request: ``"shed"`` returns :class:`Rejected` immediately when
        the queue is over budget, ``"block"`` waits for space.  A request
        larger than the largest bucket is split into bucket-sized chunks
        that dispatch in order (still one result)."""
        if self._dispatcher is None:
            raise RuntimeError("service not started (use `async with` or "
                               "`await service.start()`)")
        leaves = self._host_leaves(packets)
        n = int(leaves["ts"].shape[0])
        if n == 0:  # empty submits answer immediately and skew nothing
            return ServeResult(client_id, np.zeros(0, np.int32), 0, 0.0, 0.0)
        gstats = self.stats
        cstats = gstats.client(client_id)
        gstats.requests += 1
        cstats.requests += 1
        gstats.submitted += n
        cstats.submitted += n

        if self._depth + n > self.cfg.depth_budget:
            if self.cfg.admission == "shed":
                gstats.shed_requests += 1
                cstats.shed += n
                gstats.shed += n
                return Rejected(client_id, n, self._depth,
                                self.cfg.depth_budget)
            while self._depth + n > self.cfg.depth_budget:
                self._space.clear()
                await self._space.wait()

        # enqueue every chunk before the first await, so admission order is
        # submission order (a gather of submits sheds deterministically)
        top = self.cfg.buckets[-1]
        loop = asyncio.get_running_loop()
        now = time.perf_counter()
        chunks: list[_Pending] = []
        for off in range(0, n, top):
            m = min(top, n - off)
            sl = {k: v[off:off + m] for k, v in leaves.items()}
            chunks.append(_Pending(client_id, sl, m, now, loop.create_future()))
        self._queue.extend(chunks)
        self._depth += n
        gstats.depth_hwm = max(gstats.depth_hwm, self._depth)
        self._work.set()

        # return_exceptions so every chunk's error is consumed here — one
        # failed dispatch fails the whole request (partial verdicts would be
        # unusable), without "exception never retrieved" noise from siblings
        results = await asyncio.gather(*(c.future for c in chunks),
                                       return_exceptions=True)
        errors = [r for r in results if isinstance(r, BaseException)]
        if errors:
            raise errors[0]
        done = time.perf_counter()
        actions = np.concatenate(results)
        wait_s = chunks[0].dispatched_at - now
        e2e_s = done - now
        gstats.served_requests += 1
        gstats.served += n
        cstats.served += n
        for st in (gstats, cstats):
            st.wait.add(wait_s * 1e6)
            st.e2e.add(e2e_s * 1e6)
        buckets = tuple(c.bucket for c in chunks)
        return ServeResult(client_id, actions, max(buckets), wait_s, e2e_s,
                           buckets)

    # ------------------------------------------------------------- dispatcher
    def _bucket_for(self, n: int) -> int:
        for b in self.cfg.buckets:
            if n <= b:
                return b
        raise AssertionError(f"chunk of {n} exceeds the largest bucket "
                             f"{self.cfg.buckets[-1]}")  # pragma: no cover

    def _take_coalesced(self) -> list[_Pending]:
        """Pop a FIFO run of requests that fits the largest bucket (always
        at least one — chunks never exceed it)."""
        top = self.cfg.buckets[-1]
        reqs = [self._queue.popleft()]
        total = reqs[0].n
        while self._queue and total + self._queue[0].n <= top:
            nxt = self._queue.popleft()
            reqs.append(nxt)
            total += nxt.n
        return reqs

    def _dispatch_blocking(self, reqs: list[_Pending]
                           ) -> tuple[np.ndarray, dict, int, float, float]:
        """The blocking half of one dispatch — pack a coalesced run into a
        pooled staging buffer, pad to the bucket, run the masked step.  Runs
        on the dispatch executor under ``cfg.offload`` (inline otherwise);
        it touches no asyncio state, only the pipeline and the pool.  On a
        failing step the buffer is returned to the pool HERE (this side owns
        it); futures and queue depth are the loop side's to restore.
        Returns ``(actions, buf, bucket, host_s, device_s)``."""
        total = sum(r.n for r in reqs)
        bucket = self._bucket_for(total)
        t0 = time.perf_counter()
        buf = self._pool.acquire(bucket)
        try:
            off = 0
            for r in reqs:
                for f in _SCALAR_FIELDS:
                    buf[f][off:off + r.n] = r.leaves[f]
                buf["payload"][off:off + r.n] = r.leaves["payload"]
                off += r.n
            for f in _SCALAR_FIELDS:  # zero the pad tail: stale rows out
                buf[f][total:] = 0
            buf["payload"][total:] = 0
            buf["keep"][:total] = True
            buf["keep"][total:] = False

            t_dispatch = time.perf_counter()
            for r in reqs:
                r.dispatched_at = t_dispatch
                r.bucket = bucket
            batch = PacketBatch(
                **{f: jnp.asarray(buf[f]) for f in _SCALAR_FIELDS},
                payload=jnp.asarray(buf["payload"]))
            t1 = time.perf_counter()
            out = self.pipeline.step_masked(batch, buf["keep"])
            t2 = time.perf_counter()
            actions = np.asarray(out.pkt_actions)
            host_s = (t1 - t0) + (time.perf_counter() - t2)
            return actions, buf, bucket, host_s, t2 - t1
        except BaseException:
            self._pool.release(buf)
            raise

    async def _dispatch_one(self, reqs: list[_Pending]) -> None:
        """One full dispatch: run the blocking half (off-loop under
        ``cfg.offload``), then answer every coalesced request with its slice
        of the verdicts — or, if the step raised, with the error.  Queue
        depth and the space event are restored on BOTH paths, so admission
        control never wedges on a failing dispatch."""
        total = sum(r.n for r in reqs)
        try:
            if self._executor is not None:
                actions, buf, bucket, host_s, device_s = \
                    await asyncio.get_running_loop().run_in_executor(
                        self._executor, self._dispatch_blocking, reqs)
            else:
                actions, buf, bucket, host_s, device_s = \
                    self._dispatch_blocking(reqs)
        except Exception as e:
            self.stats.failed_dispatches += 1
            self.stats.failed += total
            for r in reqs:
                if not r.future.done():
                    r.future.set_exception(e)
        else:
            off = 0
            for r in reqs:
                r.future.set_result(actions[off:off + r.n].copy())
                off += r.n
            self._pool.release(buf)
            self.stats.dispatches += 1
            self.stats.coalesced += len(reqs)
            self.stats.padded += bucket - total
            self.stats.host_s += host_s
            self.stats.device_s += device_s
        finally:
            self._depth -= total
            self._space.set()

    async def _dispatch_loop(self) -> None:
        while True:
            await self._work.wait()
            if not self._queue:
                if self._stopping:
                    return
                self._work.clear()
                continue
            if self.cfg.batch_wait_s > 0:
                # coalescing grace: let concurrent clients land their
                # submits before the bucket is chosen
                await asyncio.sleep(self.cfg.batch_wait_s)
            else:
                # yield once so a gather of submits enqueues as one wave
                await asyncio.sleep(0)
            if not self._queue:
                continue
            await self._dispatch_one(self._take_coalesced())


async def serve_stream(service: OctopusService, gen: TrafficGenerator, *,
                       requests: int,
                       client_id: Optional[int] = None) -> list[SubmitOutcome]:
    """Closed-loop client: submit ``requests`` microbatches from one seeded
    generator sequentially (each awaited before the next — the arrival
    process a real port presents) and return the outcomes.  Run several of
    these under ``asyncio.gather`` for a multi-client load."""
    cid = gen.client_id if client_id is None else client_id
    results: list[SubmitOutcome] = []
    for batch in gen.batches(requests):
        results.append(await service.submit(batch, client_id=cid))
    return results


__all__ = ["OctopusService", "ServiceConfig", "ServiceStats", "ClientStats",
           "ServeResult", "Rejected", "ADMISSION_POLICIES", "serve_stream"]
