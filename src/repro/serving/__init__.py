from repro.serving.engine import ServeEngine, ServeConfig, Request
from repro.serving.packet_path import (
    FlowEngine,
    FlowPath,
    PacketEngine,
    PacketPath,
    PathStats,
)
from repro.serving.pipeline import (
    InflightDispatch,
    LatencyReservoir,
    OctopusPipeline,
    PipelineConfig,
    PipelineStats,
    PipelineStepOutput,
)
from repro.serving.service import (
    ADMISSION_POLICIES,
    OctopusService,
    Rejected,
    ServeResult,
    ServiceConfig,
    ServiceStats,
    serve_stream,
)
from repro.serving.sharded import LANE_BACKENDS, ShardedOctopusPipeline
