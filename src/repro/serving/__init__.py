from repro.serving.engine import ServeEngine, ServeConfig, Request
from repro.serving.packet_path import PacketPath, FlowPath
