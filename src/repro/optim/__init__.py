from repro.optim.optimizers import (
    Optimizer,
    adamw,
    adafactor,
    sgd,
    make_optimizer,
    clip_by_global_norm,
    cosine_schedule,
)
