"""Pytree optimizers built from scratch (no optax): AdamW, Adafactor (factored
second moment — the memory-frugal choice for the 1T-param MoE), SGD-momentum,
global-norm clipping and LR schedules.

Optimizer states inherit the parameter's sharding (same tree structure), so
ZeRO-style sharded states come for free from the param sharding rules.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    # update(grads, state, params, step) -> (new_params, new_state)


def cosine_schedule(base_lr: float, warmup: int, total: int, min_ratio: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(np.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return lr


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

class AdamState(NamedTuple):
    mu: Any
    nu: Any


def adamw(lr: Callable | float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.01) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(mu=jax.tree.map(z, params), nu=jax.tree.map(z, params))

    def update(grads, state, params, step):
        stepf = step.astype(jnp.float32) + 1.0
        lr_t = lr_fn(step)
        c1 = 1.0 - b1 ** stepf
        c2 = 1.0 - b2 ** stepf

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            mhat = m2 / c1
            vhat = v2 / c2
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m2, v2

        out = jax.tree.map(upd, grads, state.mu, state.nu, params)
        new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, AdamState(mu=new_m, nu=new_v)

    return Optimizer(init=init, update=update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; beta1=0 -> no first moment)
# ---------------------------------------------------------------------------

class FactoredState(NamedTuple):
    vr: Any  # row stats (or full v for <2D leaves)
    vc: Any  # col stats (or () for <2D leaves)


def adafactor(lr: Callable | float, eps: float = 1e-30, clip_threshold: float = 1.0,
              weight_decay: float = 0.0, decay: float = 0.8) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        def vr(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def vc(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((), jnp.float32)

        return FactoredState(vr=jax.tree.map(vr, params), vc=jax.tree.map(vc, params))

    def update(grads, state, params, step):
        stepf = step.astype(jnp.float32) + 1.0
        beta2 = 1.0 - stepf ** (-decay)
        lr_t = lr_fn(step)

        def upd(g, vr, vc, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if p.ndim >= 2:
                vr2 = beta2 * vr + (1 - beta2) * g2.mean(axis=-1)
                vc2 = beta2 * vc + (1 - beta2) * g2.mean(axis=-2)
                rfac = jax.lax.rsqrt(
                    vr2 / jnp.maximum(vr2.mean(axis=-1, keepdims=True), eps)
                )[..., None]
                cfac = jax.lax.rsqrt(vc2)[..., None, :]
                u = g * rfac * cfac
            else:
                vr2 = beta2 * vr + (1 - beta2) * g2
                vc2 = vc
                u = g * jax.lax.rsqrt(vr2)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            delta = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), vr2, vc2

        out = jax.tree.map(upd, grads, state.vr, state.vc, params)
        new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_r = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_c = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, FactoredState(vr=new_r, vc=new_c)

    return Optimizer(init=init, update=update)


# ---------------------------------------------------------------------------
# SGD + momentum
# ---------------------------------------------------------------------------

def sgd(lr: Callable | float, momentum: float = 0.9) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params, step):
        lr_t = lr_fn(step)

        def upd(g, m, p):
            m2 = momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * m2).astype(p.dtype), m2

        out = jax.tree.map(upd, grads, state, params)
        new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, new_m

    return Optimizer(init=init, update=update)


def make_optimizer(name: str, lr, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(lr, **kw)
    if name == "adafactor":
        return adafactor(lr, **kw)
    if name == "sgd":
        return sgd(lr, **kw)
    raise ValueError(f"unknown optimizer {name}")
