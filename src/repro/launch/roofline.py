"""Roofline-term extraction from compiled dry-run artifacts.

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s            [s]
  memory term     = HLO_bytes_per_device / HBM_bw                 [s]
  collective term = collective_bytes_per_device / link_bw         [s]

``compiled.cost_analysis()`` on the SPMD-partitioned module reports *per
device* flops/bytes (verified empirically: a (32,256)x(256,512) matmul on 8
devices reports total/8).  Collective bytes are parsed from the compiled HLO
text: for each all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute, the result shapes (per-device shards) are converted to
per-device link traffic with the standard algorithmic factors.

Caveat recorded in EXPERIMENTS.md: Pallas custom-calls are invisible to
cost_analysis, so cells lowered through kernels add their analytic flops.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=dict)
    bytes_by_kind: dict = field(default_factory=dict)
    effective_bytes: float = 0.0  # per device, algorithmic-factor adjusted
    raw_bytes: float = 0.0

    def add(self, kind: str, nbytes: float, group: int):
        self.counts[kind] = self.counts.get(kind, 0) + 1
        self.bytes_by_kind[kind] = self.bytes_by_kind.get(kind, 0.0) + nbytes
        g = max(group, 2)
        if kind == "all-reduce":
            eff = 2.0 * nbytes * (g - 1) / g
        elif kind == "all-gather":
            eff = nbytes * (g - 1) / g
        elif kind == "reduce-scatter":
            eff = nbytes * (g - 1)
        elif kind == "all-to-all":
            eff = nbytes * (g - 1) / g
        else:  # collective-permute
            eff = nbytes
        self.effective_bytes += eff
        self.raw_bytes += nbytes


def _line_result_bytes(line: str, op_pos: int) -> float:
    """Sum the dtype[shape] result tokens on the LHS of the op keyword."""
    lhs = line[:op_pos]
    if "=" in lhs:
        lhs = lhs.split("=", 1)[1]
    total = 0.0
    for m in _SHAPE_RE.finditer(lhs):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str, num_partitions: int) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if not stripped.startswith("%") and not stripped.startswith("ROOT"):
            continue
        for kind in _COLL_KINDS:
            # match "<kind>(" or "<kind>-start(" as the op; skip -done/other refs
            idx = -1
            for suffix in ("(", "-start("):
                probe = f" {kind}{suffix}"
                idx = stripped.find(probe)
                if idx >= 0:
                    break
            if idx < 0:
                continue
            nbytes = _line_result_bytes(stripped, idx)
            g = num_partitions
            m = _GROUPS_RE.search(stripped)
            if m:
                g = int(m.group(2))
            else:
                m2 = _GROUPS_BRACE_RE.search(stripped)
                if m2:
                    g = len([x for x in m2.group(1).split(",") if x.strip() != ""])
            stats.add(kind, nbytes, g)
            break
    return stats


@dataclass
class Roofline:
    label: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_eff: float
    collective_counts: dict
    model_flops_total: float
    memory: dict
    compile_s: float = 0.0
    notes: str = ""

    @property
    def compute_term_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def memory_term_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_term_s(self) -> float:
        return self.collective_bytes_eff / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_term_s,
            "memory": self.memory_term_s,
            "collective": self.collective_term_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_fraction(self) -> float:
        hlo_total = self.flops_per_device * self.chips
        return self.model_flops_total / hlo_total if hlo_total else 0.0

    @property
    def step_time_bound_s(self) -> float:
        return max(self.compute_term_s, self.memory_term_s, self.collective_term_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chips' peak-FLOPs roofline achieved at the modeled
        step time, counting only useful (MODEL) flops."""
        t = self.step_time_bound_s
        if t <= 0:
            return 0.0
        achieved = self.model_flops_total / t
        peak = self.chips * PEAK_FLOPS_BF16
        return achieved / peak

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(
            compute_term_s=self.compute_term_s,
            memory_term_s=self.memory_term_s,
            collective_term_s=self.collective_term_s,
            bottleneck=self.bottleneck,
            useful_flops_fraction=self.useful_flops_fraction,
            step_time_bound_s=self.step_time_bound_s,
            roofline_fraction=self.roofline_fraction,
        )
        return d


def cost_dict(compiled) -> dict:
    """compiled.cost_analysis() returns a one-element list of dicts in the
    pinned JAX (a bare dict in newer versions); normalize to a dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def analyze_compiled(label: str, mesh_name: str, chips: int, compiled,
                     model_flops: float, compile_s: float, notes: str = "") -> Roofline:
    ca = cost_dict(compiled)
    ma = compiled.memory_analysis()
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    txt = compiled.as_text()
    coll = parse_collectives(txt, chips)
    memory = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_bytes_est": int(ma.argument_size_in_bytes + ma.temp_size_in_bytes
                              + ma.output_size_in_bytes - ma.alias_size_in_bytes),
    }
    return Roofline(
        label=label,
        mesh=mesh_name,
        chips=chips,
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_bytes_eff=coll.effective_bytes,
        collective_counts={k: [coll.counts[k], coll.bytes_by_kind[k]] for k in coll.counts},
        model_flops_total=model_flops,
        memory=memory,
        compile_s=compile_s,
        notes=notes,
    )
