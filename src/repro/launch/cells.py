"""(arch x shape) cell definitions for the dry-run: abstract inputs
(ShapeDtypeStructs — no allocation), step functions, and sharding assignments.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SHAPES, ArchConfig, ShapeSpec, get_config
from repro.distributed import sharding as shd
from repro.models.transformer import LM
from repro.optim import cosine_schedule, make_optimizer
from repro.train.steps import make_train_step


def abstract_batch(cfg: ArchConfig, shape: ShapeSpec, *, with_labels: bool) -> dict:
    b = shape.global_batch
    s = 1 if shape.kind == "decode" else shape.seq_len
    batch: dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.frontend == "audio_frames":
        batch["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.frontend == "vision_patches":
        batch["vision"] = jax.ShapeDtypeStruct((b, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    if with_labels:
        batch["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return batch


class Cell(NamedTuple):
    label: str
    fn: Callable
    args: tuple  # abstract args
    in_shardings: tuple
    donate_argnums: tuple
    model_flops: float  # analytic MODEL_FLOPS for the step
    notes: str
    cfg: Any = None


def _param_count(abstract_params: Any) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(abstract_params))


def active_param_count(cfg: ArchConfig, abstract_params: Any) -> int:
    """Active params per token (MoE: only routed-in experts count)."""
    total = _param_count(abstract_params)
    if not cfg.num_experts:
        return total
    flat, _ = jax.tree_util.tree_flatten_with_path(abstract_params)
    expert_params = sum(
        int(np.prod(leaf.shape)) for path, leaf in flat
        if any(k in jax.tree_util.keystr(path) for k in ("w_gate", "w_up", "w_down"))
        and "sh_" not in jax.tree_util.keystr(path)
    )
    frac = cfg.experts_per_token / cfg.num_experts
    return int(total - expert_params + expert_params * frac)


def model_flops_for(cfg: ArchConfig, shape: ShapeSpec, abstract_params: Any) -> float:
    """6*N_active*D for train; 2*N_active*D for prefill; 2*N_active*B per
    decode step (standard parameter-flops accounting; attention flops excluded,
    reported separately in the roofline notes)."""
    n_act = active_param_count(cfg, abstract_params)
    if shape.kind == "train":
        return 6.0 * n_act * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.global_batch * shape.seq_len
    return 2.0 * n_act * shape.global_batch  # decode: one token per sample


def build_cell(arch: str, shape_name: str, mesh, *, pallas: bool = False,
               overrides: Optional[dict] = None,
               analysis_nsb: Optional[int] = None,
               use_pallas: Optional[bool] = None) -> Cell:
    if use_pallas is not None:  # deprecated spelling, one release
        import warnings

        warnings.warn("build_cell(use_pallas=...) is deprecated; use pallas=",
                      DeprecationWarning, stacklevel=2)
        pallas = use_pallas
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    if pallas:
        cfg = cfg.replace(use_pallas=True)
    if analysis_nsb is not None:
        # HLO-cost-analysis mode: unrolled layers + naive attention + unrolled
        # chunk scans, truncated to `analysis_nsb` superblocks.  Total cost is
        # extrapolated as base + (NSB-1) * (cost(2) - cost(1)) by the caller.
        cfg = cfg.replace(
            scan_layers=False,
            attn_impl="blockwise",  # production impl, chunk scans unrolled
            inner_unroll=True,
            num_superblocks=analysis_nsb,
        )
    shape = SHAPES[shape_name]
    model = LM(cfg)
    specs = model.specs()
    from repro.models.spec import abstract_params as abst, logical_axes

    params_abs = abst(specs)
    axes = logical_axes(specs)
    report: list = []
    param_sh = shd.shardings_for(axes, params_abs, cfg, mesh, report)
    mflops = model_flops_for(cfg, shape, params_abs)
    notes = "; ".join(f"{n}:{d} {a} {msg}" for n, d, a, msg in report[:8])

    if shape.kind == "train":
        batch_abs = abstract_batch(cfg, shape, with_labels=True)
        batch_sh = shd.input_shardings(mesh, batch_abs, cfg)
        lr = cosine_schedule(3e-4, 100, 10_000)
        opt = make_optimizer(cfg.optimizer, lr)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        opt_sh = shd.opt_shardings(param_sh, params_abs, opt_abs)
        step_fn = make_train_step(cfg, opt)
        step_abs = jax.ShapeDtypeStruct((), jnp.int32)
        return Cell(
            label=f"{arch}/{shape_name}",
            fn=step_fn,
            args=(params_abs, opt_abs, step_abs, batch_abs),
            in_shardings=(param_sh, opt_sh, None, batch_sh),
            donate_argnums=(0, 1),
            model_flops=mflops,
            notes=notes,
            cfg=cfg,
        )

    cache_len = shape.seq_len
    batch_abs = abstract_batch(cfg, shape, with_labels=False)
    batch_sh = shd.input_shardings(mesh, batch_abs, cfg)
    cache_abs = jax.eval_shape(
        functools.partial(model.init_cache, shape.global_batch, cache_len)
    )
    cache_sh = shd.cache_shardings(cache_abs, cfg, mesh)

    if shape.kind == "prefill":
        fn = model.prefill
        return Cell(
            label=f"{arch}/{shape_name}",
            fn=fn,
            args=(params_abs, batch_abs, cache_abs),
            in_shardings=(param_sh, batch_sh, cache_sh),
            donate_argnums=(2,),
            model_flops=mflops,
            notes=notes,
            cfg=cfg,
        )

    fn = model.decode_step
    return Cell(
        label=f"{arch}/{shape_name}",
        fn=fn,
        args=(params_abs, batch_abs, cache_abs),
        in_shardings=(param_sh, batch_sh, cache_sh),
        donate_argnums=(2,),
        model_flops=mflops,
        notes=notes,
        cfg=cfg,
    )


def all_cells() -> list[tuple[str, str]]:
    """Every (arch, shape) pair that applies (skips documented in DESIGN.md)."""
    from repro.configs import list_archs

    out = []
    for arch in list_archs():
        cfg = get_config(arch)
        for shape_name in cfg.shape_cells():
            out.append((arch, shape_name))
    return out
