"""Render EXPERIMENTS.md tables from the dry-run JSON artifacts.

  PYTHONPATH=src python -m repro.launch.report --dir experiments/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load_all(dir_: str) -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(f) as fh:
            d = json.load(fh)
        d["_file"] = os.path.basename(f)
        out.append(d)
    return out


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def dryrun_table(rows: list[dict]) -> str:
    out = ["| cell | mesh | chips | compile | FLOPs/dev | bytes/dev | coll bytes/dev | peak mem/dev | collectives |",
           "|---|---|---|---|---|---|---|---|---|"]
    for d in rows:
        colls = ",".join(f"{k}x{v[0]}" for k, v in sorted(d["collective_counts"].items()))
        out.append(
            f"| {d['label']} | {d['mesh']} | {d['chips']} | {d['compile_s']:.0f}s "
            f"| {d['flops_per_device']:.2e} | {d['bytes_per_device']:.2e} "
            f"| {d['collective_bytes_eff']:.2e} "
            f"| {d['memory']['peak_bytes_est']/2**30:.1f}GiB | {colls} |")
    return "\n".join(out)


def lever_note(d: dict) -> str:
    """One sentence: what would move the dominant term down."""
    label = d["label"]
    is_decode = "decode" in label or "500k" in label
    is_moe = any(a in label for a in ("kimi", "granite"))
    b = d["bottleneck"]
    if b == "compute":
        return "compute-bound: raise MXU utilization (fused kernels, larger per-chip batch)"
    if b == "memory":
        if is_decode:
            return "weights/KV-bound decode: inherent at this batch; quantized KV or larger decode batch"
        return "fuse attention/softmax intermediates into VMEM (Pallas flash) + bf16 AV"
    if is_moe:
        return "bf16 psums; replace residual all-reduce with reduce-scatter; EP all-to-all for dispatch"
    return "shrink TP psums (bf16 accum / SP) or trade TP for DP at this model size"


def roofline_table(rows: list[dict]) -> str:
    out = ["| cell | compute | memory | collective | bottleneck | useful-FLOPs frac | roofline frac | what moves the dominant term |",
           "|---|---|---|---|---|---|---|---|"]
    for d in rows:
        if d["mesh"] != "single":
            continue
        out.append(
            f"| {d['label']} | {fmt_s(d['compute_term_s'])} | {fmt_s(d['memory_term_s'])} "
            f"| {fmt_s(d['collective_term_s'])} | {d['bottleneck']} "
            f"| {d['useful_flops_fraction']:.2f} | {d['roofline_fraction']*100:.2f}% "
            f"| {lever_note(d)} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--what", default="both", choices=["dryrun", "roofline", "both"])
    args = ap.parse_args()
    rows = load_all(args.dir)
    if args.what in ("dryrun", "both"):
        print("## Dry-run census\n")
        print(dryrun_table(rows))
        print()
    if args.what in ("roofline", "both"):
        print("## Roofline terms (single-pod, per train/serve step)\n")
        print(roofline_table(rows))


if __name__ == "__main__":
    main()
