"""Serving launcher: spin up the slot-based continuous-batching engine on a
(reduced) arch and run a batch of synthetic requests end-to-end.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --requests 8
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax

    from repro.configs import get_config, reduced_config
    from repro.models import LM
    from repro.serving import Request, ServeConfig, ServeEngine

    cfg = reduced_config(get_config(args.arch))
    if not cfg.supports_decode:
        raise SystemExit(f"{args.arch} is encoder-only; no decode path")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    eng = ServeEngine(cfg, params, ServeConfig(batch_slots=args.slots,
                                               cache_len=args.cache_len))
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, args.prompt_len),
                           max_new=args.max_new))
    t0 = time.perf_counter()
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {len(done)}/{args.requests} requests, {total_tokens} tokens "
          f"in {dt:.2f}s -> {total_tokens/dt:.1f} tok/s")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.out_tokens[:10]}...")


if __name__ == "__main__":
    main()
