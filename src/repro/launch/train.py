"""Training launcher: builds the mesh, shards params/optimizer/batches, and
runs the fault-tolerant training loop.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --steps 200 \
      --reduced --batch 8 --seq 128 --ckpt /tmp/ckpt

--reduced runs the arch's smoke-scale config on the host devices (the CPU
container path); full-scale configs are for real pods — their distribution
setup is identical, only the mesh differs (see dryrun.py for the compile-level
proof on 256/512 chips).
"""
from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", type=str, default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (sets XLA_FLAGS; must be first)")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}"
        )

    import jax  # noqa: F401  (initialize after XLA_FLAGS is set)

    from repro.configs import get_config, reduced_config
    from repro.data.tokens import TokenPipelineConfig
    from repro.train.loop import Trainer, TrainLoopConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    loop = TrainLoopConfig(
        total_steps=args.steps,
        checkpoint_every=args.ckpt_every,
        checkpoint_dir=args.ckpt,
        lr=args.lr,
        accum_steps=args.accum,
    )
    data = TokenPipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed,
    )
    trainer = Trainer(cfg, loop, data)
    out = trainer.run(seed=args.seed)
    print(f"[train] final loss {out['final_loss']:.4f} "
          f"median step {out['median_step_time_s']*1e3:.1f} ms "
          f"stragglers {out['straggler_steps']}")


if __name__ == "__main__":
    main()
