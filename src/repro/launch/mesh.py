"""Production mesh construction.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  512 chips as (pod=2, data=16, model=16) — the pod axis is the
outer data-parallel / pipeline axis (slowest links).

Defined as functions (never module-level constants) so importing this module
never touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax use.
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n: int) -> dict:
    # jax.sharding.AxisType only exists in newer JAX; the pinned version's
    # make_mesh has no axis_types kwarg and defaults to the same semantics.
    axis_type = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (axis_type.Auto,) * n} if axis_type is not None else {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_lanes_mesh(num_lanes: int):
    """1-D ``lanes`` mesh over the first ``num_lanes`` local devices — the
    serving pipeline's parallel-lane axis (paper §2.2: parallel extractor
    lanes over the multi-bank memory fabric).  Unlike the production meshes
    this may use a subset of the devices: lanes are a serving concept, not a
    training topology."""
    import numpy as np

    devices = jax.devices()
    if num_lanes > len(devices):
        raise ValueError(f"need {num_lanes} devices for a lanes mesh, "
                         f"have {len(devices)}")
    return jax.sharding.Mesh(np.asarray(devices[:num_lanes]), ("lanes",))


def make_host_mesh(data: int = 2, model: int = 4, pod: int = 0):
    """Small mesh for CPU integration tests (requires the host-device flag)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                             **_axis_type_kwargs(3))
    return jax.make_mesh((data, model), ("data", "model"), **_axis_type_kwargs(2))


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
