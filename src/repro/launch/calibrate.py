"""Measure the arype/vpe crossover on this backend and persist it.

    PYTHONPATH=src python -m repro.launch.calibrate                 # cache path
    PYTHONPATH=src python -m repro.launch.calibrate --out calib.json
    PYTHONPATH=src python -m repro.launch.calibrate --smoke         # CI subset

Sweeps the (m, k, n) timing grid (``repro.runtime.autotune``), fits the
measured crossover into calibrated ``tau`` / ``vpe_max_elems``, writes the
backend-keyed artifact, then reports — per paper use-case model — every layer
whose placement under the calibrated thresholds diverges from the analytic
defaults (the full placements come from ``RoutePlan.explain``).
"""
from __future__ import annotations

import argparse
import sys

from repro.core.collaborative import usecase2_layers, usecase3_layers
from repro.runtime import (
    DEFAULT_RUNTIME,
    RoutePlan,
    RuntimeConfig,
    autotune,
    platform,
)

# Paper-model matmul stacks the report diffs (MLP per-packet batch 8; the
# flow use-cases at 1000 tracked flows, the paper's Table 6 operating point).
_MLP_LAYERS = [("w0", 8, 6, 12), ("w1", 8, 12, 6), ("w2", 8, 6, 3), ("w3", 8, 3, 2)]


def _model_stacks(flows: int) -> list[tuple[str, list[tuple[str, int, int, int]]]]:
    return [
        ("usecase1_mlp(batch=8)", _MLP_LAYERS),
        (f"usecase2_cnn(flows={flows})", usecase2_layers(flows)),
        (f"usecase3_transformer(flows={flows})", usecase3_layers(flows)),
    ]


def divergence_report(calibrated: RuntimeConfig, *, flows: int = 1000,
                      analytic: RuntimeConfig = DEFAULT_RUNTIME,
                      verbose: bool = False) -> str:
    """Per paper-model layer, where calibrated placement diverges from the
    analytic default (and the full calibrated plan when ``verbose``)."""
    lines = []
    for label, layers in _model_stacks(flows):
        a_plan = RoutePlan.from_layers(layers, config=analytic)
        c_plan = RoutePlan.from_layers(layers, config=calibrated)
        moved = [(a, c) for a, c in zip(a_plan.steps, c_plan.steps)
                 if a.engine != c.engine]
        lines.append(f"{label}:")
        if not moved:
            lines.append("  placement unchanged by calibration")
        for a, c in moved:
            lines.append(f"  {a.name}  ({a.m},{a.k},{a.n})  "
                         f"{a.engine} -> {c.engine}  (util={c.route.util:.3f})")
        if verbose:
            lines.extend("  " + ln for ln in c_plan.explain().splitlines())
    return "\n".join(lines)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="calibrate tau/vpe_max_elems from measured crossover points")
    ap.add_argument("--out", default=None,
                    help="artifact path (default: the backend-keyed cache path, "
                         f"{autotune.cache_path()})")
    ap.add_argument("--smoke", action="store_true",
                    help="8-point grid, 2 timing iters (CI / smoke tests)")
    ap.add_argument("--iters", type=int, default=None,
                    help="timing iterations per shape per path (default 5; 2 with --smoke)")
    ap.add_argument("--flows", type=int, default=1000,
                    help="tracked flows for the paper-model divergence report")
    ap.add_argument("--verbose", action="store_true",
                    help="print the full calibrated RoutePlan per model")
    args = ap.parse_args(argv)

    fp = platform.fingerprint()
    print(f"[calibrate] platform: {platform.fingerprint_id(fp)} "
          f"(pallas={'yes' if platform.pallas_available() else 'no'}, "
          f"interpret_default={platform.interpret_default()})")
    iters = args.iters if args.iters is not None else (2 if args.smoke else 5)
    grid = autotune.default_grid(smoke=args.smoke)
    print(f"[calibrate] sweeping {len(grid)} (m,k,n) shapes x 2 engine paths "
          f"({iters} iters each)...")
    calib = autotune.calibrate(grid, iters=iters)
    path = autotune.save_calibration(calib, args.out)

    n_vpe = sum(1 for t in calib.timings if t.vpe_wins)
    print(f"[calibrate] vpe won {n_vpe}/{len(calib.timings)} shapes")
    print(f"[calibrate] analytic: tau={DEFAULT_RUNTIME.tau} "
          f"vpe_max_elems={DEFAULT_RUNTIME.vpe_max_elems}")
    print(f"[calibrate] measured: tau={calib.tau:.4f} "
          f"vpe_max_elems={calib.vpe_max_elems}")
    print(f"[calibrate] artifact: {path}")
    print()
    print("placement divergence (analytic -> calibrated):")
    print(divergence_report(calib.apply(RuntimeConfig()), flows=args.flows,
                            verbose=args.verbose))
    return 0


if __name__ == "__main__":
    sys.exit(main())
