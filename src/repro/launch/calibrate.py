"""Measure the arype/vpe crossover on this backend and persist it.

    PYTHONPATH=src python -m repro.launch.calibrate                 # cache path
    PYTHONPATH=src python -m repro.launch.calibrate --out calib.json
    PYTHONPATH=src python -m repro.launch.calibrate --smoke         # CI subset

Sweeps the (m, k, n) timing grid (``repro.runtime.autotune``), fits the
measured crossover into calibrated ``tau`` / ``vpe_max_elems``, writes the
backend-keyed artifact, then reports — per paper use-case model — every layer
whose placement under the calibrated thresholds diverges from the analytic
defaults (the full placements come from ``RoutePlan.explain``).

With ``--quant`` (on by default) the run also fits the int8 datapath's
per-layer scales from a seeded :class:`TrafficGenerator` sample pushed through
both engines (:func:`calibrate_quant_scales`), persists them in the same
artifact, and prints a decision-flip divergence report
(:func:`quant_divergence_report`) comparing the quantized pipeline against the
f32 oracle on the same stream.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
from typing import Sequence, Tuple

from repro.core.collaborative import usecase2_layers, usecase3_layers
from repro.runtime import (
    DEFAULT_RUNTIME,
    QuantScales,
    RoutePlan,
    RuntimeConfig,
    autotune,
    platform,
)

# Paper-model matmul stacks the report diffs (MLP per-packet batch 8; the
# flow use-cases at 1000 tracked flows, the paper's Table 6 operating point).
_MLP_LAYERS = [("w0", 8, 6, 12), ("w1", 8, 12, 6), ("w2", 8, 6, 3), ("w3", 8, 3, 2)]


def _model_stacks(flows: int) -> list[tuple[str, list[tuple[str, int, int, int]]]]:
    return [
        ("usecase1_mlp(batch=8)", _MLP_LAYERS),
        (f"usecase2_cnn(flows={flows})", usecase2_layers(flows)),
        (f"usecase3_transformer(flows={flows})", usecase3_layers(flows)),
    ]


def divergence_report(calibrated: RuntimeConfig, *, flows: int = 1000,
                      analytic: RuntimeConfig = DEFAULT_RUNTIME,
                      verbose: bool = False) -> str:
    """Per paper-model layer, where calibrated placement diverges from the
    analytic default (and the full calibrated plan when ``verbose``)."""
    lines = []
    for label, layers in _model_stacks(flows):
        a_plan = RoutePlan.from_layers(layers, config=analytic)
        c_plan = RoutePlan.from_layers(layers, config=calibrated)
        moved = [(a, c) for a, c in zip(a_plan.steps, c_plan.steps)
                 if a.engine != c.engine]
        lines.append(f"{label}:")
        if not moved:
            lines.append("  placement unchanged by calibration")
        for a, c in moved:
            lines.append(f"  {a.name}  ({a.m},{a.k},{a.n})  "
                         f"{a.engine} -> {c.engine}  (util={c.route.util:.3f})")
        if verbose:
            lines.extend("  " + ln for ln in c_plan.explain().splitlines())
    return "\n".join(lines)


def _traffic_config(table_size: int = 256, seed: int = 7):
    from repro.data.traffic import TrafficConfig

    # Dense per-flow traffic (few concurrent flows sharing each microbatch)
    # so flows actually mature to ready within a short calibration drive —
    # the flow engines only ever classify drained (count >= top_n) flows, so
    # sparse traffic would leave the quant sample with no decision rows.
    return TrafficConfig(batch_size=32, active_flows=8, elephant_fraction=0.4,
                         table_size=table_size, seed=seed)


def calibrate_quant_scales(*, steps: int = 16, traffic=None,
                           flow_models: Sequence[str] = ("cnn", "transformer"),
                           max_flip_rate: float | None = 0.01,
                           ) -> QuantScales:
    """Fit per-layer symmetric int8 scales from a seeded traffic sample.

    Drives an f32 pipeline over ``steps`` :class:`TrafficGenerator`
    microbatches so the flow engines see *tracker-shaped* inputs (drained
    series/payload rows, not synthetic tensors), then replays the engine
    applications eagerly under :func:`repro.runtime.quant.record_scales` to
    collect max-abs statistics for every routed matmul — per-tensor for
    activations, per-output-channel for weights.

    When ``max_flip_rate`` is set, a greedy sensitivity pass then prunes the
    table per decision stream: for the packet MLP (allow/deny via
    :func:`decisions.decide_binary`) and each flow model (class argmax)
    independently, the layer whose removal most reduces that stream's
    decision flips on the calibration sample is dropped — an absent table
    entry routes to the f32 path at serve time — until the stream's sample
    flip rate is at or below the target.  The streams are independent models
    over disjoint layer sets, so per-stream pruning never trades one
    stream's accuracy against another's.  Returns the fitted (possibly
    pruned) :class:`QuantScales` table.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import decisions
    from repro.core.feature_extractor import packet_meta_features
    from repro.data.traffic import TrafficGenerator
    from repro.models import paper_models
    from repro.runtime import record_scales, resolve_config, runtime_overrides
    from repro.serving import OctopusPipeline, PipelineConfig

    tcfg = traffic if traffic is not None else _traffic_config()
    gen = TrafficGenerator(tcfg)
    batches = [gen.next_batch() for _ in range(steps)]
    pkt_params = paper_models.init_paper_model("mlp", jax.random.PRNGKey(0))
    pkt_x = jnp.concatenate([packet_meta_features(b) for b in batches], axis=0)
    flow_samples = []  # (apply_fn, flow_params, flow_x, real_rows) per model

    with runtime_overrides(quantize=False), record_scales() as rec:
        paper_models.mlp_apply(pkt_params, pkt_x)
        for model in flow_models:
            flow_params = paper_models.init_paper_model(model, jax.random.PRNGKey(1))
            pcfg = PipelineConfig(batch_size=tcfg.batch_size, max_ready=8,
                                  flow_model=model, table_size=tcfg.table_size)
            pipe = OctopusPipeline(pkt_params, flow_params, pcfg)
            top_n = pipe.state.series.shape[1]
            rows = []
            for b in batches:
                out = pipe.step(b)
                mask = np.asarray(out.drained.mask)
                if mask.any():
                    x = pipe.flow_engine.prep(out.drained.series,
                                              out.drained.payload)
                    rows.append(np.asarray(x)[mask])
                # Ready-but-not-yet-drained slots (past the max_ready cap)
                # are decision-eligible too — they classify as-is on a later
                # drain.  Immature slots are excluded: the engines never see
                # a flow before count >= top_n, so sampling half-filled
                # series would measure sensitivity on impossible inputs.
                ready = np.asarray(pipe.state.count) >= top_n
                if ready.any():
                    x = pipe.flow_engine.prep(pipe.state.series,
                                              pipe.state.payload)
                    rows.append(np.asarray(x)[ready])
            if rows:
                flow_x = jnp.asarray(np.concatenate(rows, axis=0))
            else:  # degenerate sample: fall back to a zero row (eps-guarded)
                shape = pipe.flow_engine.abstract_input(1).shape
                flow_x = jnp.zeros(shape, jnp.float32)
            apply_fn = (paper_models.cnn_apply if model == "cnn"
                        else paper_models.transformer_apply)
            flow_samples.append((apply_fn, flow_params, flow_x, bool(rows)))
            apply_fn(flow_params, flow_x)
    full = rec.scales()
    if max_flip_rate is None or not full.entries:
        return full

    # Greedy per-stream sensitivity pruning on the calibration sample.
    # Decisions are what the data plane acts on, so flips — not logit
    # error — are the cost.
    base = resolve_config(None).replace(quantize=False, quant_scales=None)

    def _stream_layers(fn, params, x) -> Tuple[str, ...]:
        with runtime_overrides(quantize=False), record_scales() as r:
            fn(params, x[:1], config=base)
        return tuple(r.stats)

    def _prune_stream(names: Tuple[str, ...], decide) -> set:
        ref = decide(base)
        target = max_flip_rate * ref.size

        def flips(active) -> int:
            qcfg = base.replace(quantize=True,
                                quant_scales=full.subset(tuple(active)))
            return int((decide(qcfg) != ref).sum())

        dropped: set = set()
        active = [n for n in names if n in full.names()]
        while active and flips(active) > target:
            scored = [(n, flips([m for m in active if m != n]))
                      for n in active]
            drop, _ = min(scored, key=lambda kv: kv[1])
            active.remove(drop)
            dropped.add(drop)
        return dropped

    dropped: set = set()
    dropped |= _prune_stream(
        _stream_layers(paper_models.mlp_apply, pkt_params, pkt_x),
        lambda cfg: np.asarray(decisions.decide_binary(
            paper_models.mlp_apply(pkt_params, pkt_x, config=cfg))))
    for fn, fp, fx, real in flow_samples:
        if not real:  # zero-row fallback: no decisions to measure against
            continue
        dropped |= _prune_stream(
            _stream_layers(fn, fp, fx),
            lambda cfg, fn=fn, fp=fp, fx=fx: np.asarray(
                jnp.argmax(fn(fp, fx, config=cfg), axis=-1)))
    return full.subset(tuple(n for n in full.names() if n not in dropped))


def quant_divergence_report(scales: QuantScales, *, steps: int = 10,
                            traffic=None, flow_model: str = "cnn",
                            ) -> Tuple[str, dict]:
    """Quantized-vs-f32 differential on the seeded stream: drives two
    identically-seeded pipelines (one f32, one int8 under ``scales``) and
    reports the decision-flip counts — packet allow/deny and flow class —
    plus whether tracker state stayed bit-exact (it must: only engine
    outputs quantize).  Returns ``(report_text, metrics)``."""
    import jax
    import numpy as np

    from repro.data.traffic import TrafficGenerator
    from repro.models import paper_models
    from repro.runtime import runtime_overrides
    from repro.serving import OctopusPipeline, PipelineConfig

    tcfg = traffic if traffic is not None else _traffic_config()
    pkt_params = paper_models.init_paper_model("mlp", jax.random.PRNGKey(0))
    flow_params = paper_models.init_paper_model(flow_model, jax.random.PRNGKey(1))
    pcfg = PipelineConfig(batch_size=tcfg.batch_size, max_ready=8,
                          flow_model=flow_model, table_size=tcfg.table_size)
    with runtime_overrides(quantize=False):
        ref = OctopusPipeline(pkt_params, flow_params, pcfg)
    with runtime_overrides(quantize=True, quant_scales=scales):
        q = OctopusPipeline(pkt_params, flow_params, pcfg)

    gen_a, gen_b = TrafficGenerator(tcfg), TrafficGenerator(tcfg)
    pkt_flips = pkt_total = flow_flips = flow_total = 0
    state_exact = True
    for _ in range(steps):
        ba, bb = gen_a.next_batch(), gen_b.next_batch()
        oa, ob = ref.step(ba), q.step(bb)
        pkt_a, pkt_b = np.asarray(oa.pkt_actions), np.asarray(ob.pkt_actions)
        pkt_flips += int((pkt_a != pkt_b).sum())
        pkt_total += pkt_a.size
        mask = np.asarray(oa.drained.mask)
        cls_a, cls_b = np.asarray(oa.flow_cls), np.asarray(ob.flow_cls)
        flow_flips += int((cls_a[mask] != cls_b[mask]).sum())
        flow_total += int(mask.sum())
        for la, lb in zip(jax.tree_util.tree_leaves(ref.state),
                          jax.tree_util.tree_leaves(q.state)):
            if not np.array_equal(np.asarray(la), np.asarray(lb)):
                state_exact = False
    metrics = {
        "pkt_flips": pkt_flips, "pkt_total": pkt_total,
        "flow_flips": flow_flips, "flow_total": flow_total,
        "pkt_flip_rate": pkt_flips / max(pkt_total, 1),
        "flow_flip_rate": flow_flips / max(flow_total, 1),
        "tracker_bit_exact": state_exact,
    }
    text = (
        f"int8-vs-f32 differential ({flow_model}, {steps} microbatches, "
        f"scales {scales.fingerprint}):\n"
        f"  decision flips: pkt {pkt_flips}/{pkt_total} "
        f"({100 * metrics['pkt_flip_rate']:.2f}%), "
        f"flow {flow_flips}/{flow_total} "
        f"({100 * metrics['flow_flip_rate']:.2f}%)\n"
        f"  tracker state bit-exact: {'yes' if state_exact else 'NO'}")
    return text, metrics


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="calibrate tau/vpe_max_elems from measured crossover points")
    ap.add_argument("--out", default=None,
                    help="artifact path (default: the backend-keyed cache path, "
                         f"{autotune.cache_path()})")
    ap.add_argument("--smoke", action="store_true",
                    help="8-point grid, 2 timing iters (CI / smoke tests)")
    ap.add_argument("--iters", type=int, default=None,
                    help="timing iterations per shape per path (default 5; 2 with --smoke)")
    ap.add_argument("--flows", type=int, default=1000,
                    help="tracked flows for the paper-model divergence report")
    ap.add_argument("--verbose", action="store_true",
                    help="print the full calibrated RoutePlan per model")
    ap.add_argument("--quant", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="also fit int8 per-layer scales from a traffic "
                         "sample and report decision flips (--no-quant skips)")
    ap.add_argument("--quant-steps", type=int, default=None,
                    help="traffic microbatches for scale fitting "
                         "(default 16; 6 with --smoke)")
    args = ap.parse_args(argv)

    fp = platform.fingerprint()
    print(f"[calibrate] platform: {platform.fingerprint_id(fp)} "
          f"(pallas={'yes' if platform.pallas_available() else 'no'}, "
          f"interpret_default={platform.interpret_default()})")
    iters = args.iters if args.iters is not None else (2 if args.smoke else 5)
    grid = autotune.default_grid(smoke=args.smoke)
    print(f"[calibrate] sweeping {len(grid)} (m,k,n) shapes x 2 engine paths "
          f"({iters} iters each)...")
    calib = autotune.calibrate(grid, iters=iters)
    if args.quant:
        q_steps = args.quant_steps if args.quant_steps is not None else (
            6 if args.smoke else 16)
        flow_models = ("cnn",) if args.smoke else ("cnn", "transformer")
        print(f"[calibrate] fitting int8 scales from {q_steps} traffic "
              f"microbatches ({', '.join(flow_models)})...")
        scales = calibrate_quant_scales(steps=q_steps, flow_models=flow_models)
        calib = dataclasses.replace(calib, quant_scales=scales)
    path = autotune.save_calibration(calib, args.out)

    n_vpe = sum(1 for t in calib.timings if t.vpe_wins)
    print(f"[calibrate] vpe won {n_vpe}/{len(calib.timings)} shapes")
    print(f"[calibrate] analytic: tau={DEFAULT_RUNTIME.tau} "
          f"vpe_max_elems={DEFAULT_RUNTIME.vpe_max_elems}")
    print(f"[calibrate] measured: tau={calib.tau:.4f} "
          f"vpe_max_elems={calib.vpe_max_elems}")
    print(f"[calibrate] artifact: {path}")
    print()
    print("placement divergence (analytic -> calibrated):")
    print(divergence_report(calib.apply(RuntimeConfig()), flows=args.flows,
                            verbose=args.verbose))
    if args.quant and calib.quant_scales is not None:
        print(f"[calibrate] int8 scales: {calib.quant_scales.fingerprint} "
              f"({len(calib.quant_scales.entries)} layers)")
        q_steps = args.quant_steps if args.quant_steps is not None else (
            6 if args.smoke else 10)
        text, _ = quant_divergence_report(calib.quant_scales, steps=q_steps)
        print()
        print(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
