import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)
"""Multi-pod dry-run driver.

For every (architecture x input-shape) cell, builds the production mesh
(single-pod 16x16 = 256 chips, multi-pod 2x16x16 = 512 chips), jits the cell's
step function with explicit in_shardings, ``.lower().compile()``s it on 512
placeholder host devices, and records:

  * memory_analysis()  -> bytes per device (fits-in-HBM evidence)
  * cost_analysis()    -> per-device FLOPs / bytes (roofline numerators)
  * compiled HLO text  -> collective op census (collective roofline term)

Results are written to experiments/dryrun/<cell>__<mesh>.json and summarized
by ``python -m repro.launch.dryrun --all`` (one subprocess per cell for
isolation) or run inline for a single cell.

NOTE: the XLA_FLAGS line above MUST run before any other import touches jax.
"""
import argparse
import json
import subprocess
import sys
import time


def _compile_cell(cell, mesh):
    import jax

    t0 = time.perf_counter()
    with mesh:
        from repro.distributed.act import use_act_sharding

        with use_act_sharding(mesh, cell.cfg):
            jitted = jax.jit(
                cell.fn,
                in_shardings=cell.in_shardings,
                donate_argnums=cell.donate_argnums,
            )
            lowered = jitted.lower(*cell.args)
            compiled = lowered.compile()
    return compiled, time.perf_counter() - t0


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: str,
             pallas: bool = False, overrides_json: str = "",
             analysis: bool = True, tag: str = "") -> dict:
    from repro.configs import get_config
    from repro.launch import mesh as meshmod
    from repro.launch.cells import build_cell
    from repro.launch.roofline import analyze_compiled, cost_dict, parse_collectives

    mesh = meshmod.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    overrides = json.loads(overrides_json) if overrides_json else None

    # 1. PRODUCTION compile: proves the distribution config; memory analysis.
    cell = build_cell(arch, shape, mesh, pallas=pallas, overrides=overrides)
    compiled, dt = _compile_cell(cell, mesh)
    rf = analyze_compiled(cell.label, mesh_kind, chips, compiled,
                          cell.model_flops, dt, cell.notes)

    # 2. ANALYSIS compiles (nsb=1, nsb=2, unrolled): XLA counts while-loop
    # bodies once, so the production module under-reports flops; the unrolled
    # delta between 2 and 1 superblocks gives the exact per-superblock cost.
    # (The roofline table is single-pod only; multi-pod runs skip analysis.)
    if analysis and mesh_kind != "multi":
        nsb = get_config(arch).num_superblocks
        costs = {}
        for n in (1, 2):
            acell = build_cell(arch, shape, mesh, pallas=pallas,
                               overrides=overrides, analysis_nsb=n)
            acomp, adt = _compile_cell(acell, mesh)
            ca = cost_dict(acomp)
            coll = parse_collectives(acomp.as_text(), chips)
            costs[n] = dict(
                flops=float(ca.get("flops", 0.0)),
                bytes=float(ca.get("bytes accessed", 0.0)),
                coll=coll.effective_bytes,
                counts=dict(coll.counts),
                bytes_by_kind=dict(coll.bytes_by_kind),
                compile_s=adt,
            )
        d_flops = costs[2]["flops"] - costs[1]["flops"]
        d_bytes = costs[2]["bytes"] - costs[1]["bytes"]
        d_coll = costs[2]["coll"] - costs[1]["coll"]
        rf.flops_per_device = costs[1]["flops"] + (nsb - 1) * d_flops
        rf.bytes_per_device = costs[1]["bytes"] + (nsb - 1) * d_bytes
        rf.collective_bytes_eff = costs[1]["coll"] + (nsb - 1) * max(d_coll, 0.0)
        rf.notes = (rf.notes + f" | analysis: nsb1={costs[1]['flops']:.3e}f "
                    f"nsb2={costs[2]['flops']:.3e}f extrapolated x{nsb}").strip(" |")

    result = rf.to_dict()
    if analysis and mesh_kind != "multi":
        # per-kind raw collective bytes, extrapolated to full depth
        kinds = set(costs[1]["bytes_by_kind"]) | set(costs[2]["bytes_by_kind"])
        result["collective_bytes_by_kind_extrapolated"] = {
            k: costs[1]["bytes_by_kind"].get(k, 0.0)
            + (nsb - 1) * (costs[2]["bytes_by_kind"].get(k, 0.0)
                           - costs[1]["bytes_by_kind"].get(k, 0.0))
            for k in kinds
        }
        result["collective_counts_analysis"] = {
            k: [costs[1]["counts"].get(k, 0),
                costs[2]["counts"].get(k, 0)] for k in kinds
        }
    print(f"[dryrun] {cell.label} mesh={mesh_kind} chips={chips} "
          f"compile={dt:.1f}s flops/dev={rf.flops_per_device:.3e} "
          f"bytes/dev={rf.bytes_per_device:.3e} "
          f"coll_eff={rf.collective_bytes_eff:.3e} "
          f"peak_mem={result['memory']['peak_bytes_est']/2**30:.2f}GiB "
          f"bottleneck={rf.bottleneck}")
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    fname = f"{arch}__{shape}__{mesh_kind}{suffix}.json".replace("/", "_")
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--mesh", type=str, default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="run every applicable cell")
    ap.add_argument("--out", type=str, default="experiments/dryrun")
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--overrides", type=str, default="", help="JSON ArchConfig overrides")
    ap.add_argument("--tag", type=str, default="", help="suffix for the output file (hillclimb variants)")
    ap.add_argument("--no-analysis", action="store_true",
                    help="production compile only (skip the unrolled nsb=1/2 passes)")
    ap.add_argument("--jobs", type=int, default=2, help="parallel subprocesses for --all")
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()

    if args.all:
        from repro.launch.cells import all_cells

        cells = all_cells()
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        jobs = []
        for arch, shape in cells:
            for mk in meshes:
                jobs.append((arch, shape, mk))
        print(f"[dryrun] {len(jobs)} cell-compiles queued")
        procs: list[tuple[tuple, subprocess.Popen]] = []
        failures = []
        t_all = time.perf_counter()

        def drain(block_until_below: int):
            while len([p for _, p in procs if p.poll() is None]) >= block_until_below:
                time.sleep(2.0)
            for job, p in list(procs):
                if p.poll() is not None:
                    if p.returncode != 0:
                        failures.append(job)
                        print(f"[dryrun] FAIL {job} rc={p.returncode}")
                    procs.remove((job, p))

        for job in jobs:
            arch, shape, mk = job
            fname = os.path.join(args.out, f"{arch}__{shape}__{mk}.json")
            if os.path.exists(fname):
                print(f"[dryrun] skip (cached) {job}")
                continue
            drain(args.jobs)
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mk, "--out", args.out]
            if args.use_pallas:
                cmd.append("--use-pallas")
            p = subprocess.Popen(cmd, env={**os.environ, "PYTHONPATH": "src"})
            procs.append((job, p))
        drain(1)
        print(f"[dryrun] done in {time.perf_counter()-t_all:.0f}s; "
              f"{len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    assert args.arch and args.shape, "--arch/--shape required (or --all)"
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for mk in meshes:
        run_cell(args.arch, args.shape, mk, args.out,
                 pallas=args.use_pallas, overrides_json=args.overrides,
                 tag=args.tag, analysis=not args.no_analysis)


if __name__ == "__main__":
    main()
