"""starcoder2-15b [dense]: 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152 — GQA, RoPE [arXiv:2402.19173; hf]."""
from repro.configs.base import ArchConfig, LayerSpec, register


@register("starcoder2-15b")
def make() -> ArchConfig:
    return ArchConfig(
        name="starcoder2-15b",
        family="dense",
        d_model=6144,
        num_heads=48,
        num_kv_heads=4,
        head_dim=128,
        d_ff=24576,
        vocab_size=49152,
        block_pattern=(LayerSpec("attn", "mlp"),),
        num_superblocks=40,
        mlp_gated=False,  # starcoder2 uses a plain gelu MLP (keeps ~15B params)
        rope_theta=1e5,
        param_dtype="bfloat16",
        optimizer="adamw",
    )
