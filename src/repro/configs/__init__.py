"""Arch registry: importing this package registers all assigned architectures
plus the paper's own use-case models."""
from repro.configs import (  # noqa: F401
    gemma3_1b,
    granite_moe_1b_a400m,
    hubert_xlarge,
    kimi_k2_1t_a32b,
    llama_3_2_vision_90b,
    qwen3_0_6b,
    qwen3_4b,
    starcoder2_15b,
    xlstm_1_3b,
    zamba2_2_7b,
)
from repro.configs.base import ArchConfig, LayerSpec, ShapeSpec, SHAPES, get_config, list_archs, reduced_config
