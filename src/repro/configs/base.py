"""Architecture + shape configuration system.

Every assigned architecture is described by an :class:`ArchConfig` built out of a
*superblock pattern*: the repeated unit of layers that the model scans over
(``jax.lax.scan``), keeping HLO size ~constant in depth.  Layer kinds:

  mixers: "attn"        full (global) self attention, causal or bidirectional
          "attn_local"  sliding-window self attention
          "attn_cross"  cross attention to modality embeddings (vision)
          "attn_shared" tied-weight self attention (zamba2 shared block)
          "mamba2"      Mamba-2 / SSD block
          "mlstm"       xLSTM matrix-memory block
          "slstm"       xLSTM scalar-memory block
  ffns:   "mlp"         gated (SwiGLU) MLP
          "moe"         mixture-of-experts MLP (capacity-based dispatch)
          "mlp_shared"  tied-weight MLP (zamba2 shared block)
          "none"        no FFN (cell contains its own projections)

A model is: embed -> [superblock] * num_superblocks (scanned) -> tail layers
(unscanned leftovers, e.g. gemma3's trailing 2 local layers) -> final norm ->
logits head.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable

from repro.common.util import round_up

# ---------------------------------------------------------------------------
# Layer / block specification
# ---------------------------------------------------------------------------

MIXER_KINDS = ("attn", "attn_local", "attn_cross", "attn_shared", "mamba2", "mlstm", "slstm", "none")
FFN_KINDS = ("mlp", "moe", "mlp_shared", "none")


@dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"
    ffn: str = "mlp"

    def __post_init__(self):
        assert self.mixer in MIXER_KINDS, self.mixer
        assert self.ffn in FFN_KINDS, self.ffn


@dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# The four assigned shape cells (identical across the 10 LM archs).
SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class ArchConfig:
    # -- identity ------------------------------------------------------------
    name: str = "unnamed"
    family: str = "dense"  # dense|moe|ssm|hybrid|vlm|audio
    # -- core dims -----------------------------------------------------------
    d_model: int = 512
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 64
    d_ff: int = 2048
    vocab_size: int = 32000
    # -- depth as superblocks --------------------------------------------------
    block_pattern: tuple[LayerSpec, ...] = (LayerSpec(),)
    num_superblocks: int = 4
    head_pattern: tuple[LayerSpec, ...] = ()  # unscanned layers BEFORE the scan
    tail_pattern: tuple[LayerSpec, ...] = ()  # unscanned layers AFTER the scan
    # -- attention -----------------------------------------------------------
    causal: bool = True
    mlp_gated: bool = True  # SwiGLU vs plain (gelu) MLP
    window_size: int = 0  # sliding window for attn_local
    use_qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_theta_local: float = 10_000.0  # separate theta for local layers (gemma3)
    attn_logit_softcap: float = 0.0
    embed_scale: bool = False  # multiply embeddings by sqrt(d_model) (gemma)
    # -- MoE -----------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    first_dense_ff: int = 0  # layer 0 dense FFN width (kimi-style); 0 = pattern as-is
    # -- SSM / recurrent -------------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    mlstm_proj_factor: int = 2
    # -- modality frontend (stubbed per brief) ---------------------------------
    is_encoder_only: bool = False
    frontend: str = "none"  # none|audio_frames|vision_patches
    num_image_tokens: int = 0
    # -- execution ---------------------------------------------------------
    attn_impl: str = "auto"  # auto|naive|blockwise (naive = analysis mode)
    inner_unroll: bool = False  # unroll chunk scans (HLO cost-analysis mode)
    attn_av_dtype: str = "float32"  # probs dtype for the AV product (bf16 =
    #   half the attention HBM traffic; normalizers m/l stay fp32)
    matmul_accum_dtype: str = "float32"  # dot accumulation/psum dtype; bf16
    #   halves the TP all-reduce bytes (row-parallel contractions psum the
    #   dot output dtype)
    moe_combine_dtype: str = "float32"  # expert-output gather/combine dtype;
    #   the combine's partial-gather all-reduce over the EP axis carries this
    # -- precision / training -------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    optimizer: str = "adamw"  # adamw|adafactor|sgd
    remat: str = "full"  # none|full
    vocab_round_to: int = 128
    # -- technique (Octopus) ---------------------------------------------------
    router_policy: str = "collaborative"  # collaborative|arype_only|vpe_only
    use_pallas: bool = False  # lower hot matmuls/attention through Pallas kernels
    # -- distribution ----------------------------------------------------------
    fsdp: bool = True
    shard_kv_seq_decode: bool = False  # SP for very long decode caches
    sequence_parallel: bool = False  # Megatron-SP: shard the residual stream's
    #   seq dim over the model axis between blocks (AG/RS instead of AR psums;
    #   16x smaller remat checkpoints)
    moe_dp_attention: bool = False  # Switch/GShard layout: batch sharded over
    #   ALL mesh axes (pure-DP attention, no TP all-reduces), experts over the
    #   model axis (EP all-to-all at the dispatch boundary); params fully FSDP
    scan_layers: bool = True

    # -- derived ---------------------------------------------------------------
    @property
    def num_layers(self) -> int:
        return (len(self.block_pattern) * self.num_superblocks
                + len(self.head_pattern) + len(self.tail_pattern))

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def padded_vocab(self) -> int:
        return round_up(self.vocab_size, self.vocab_round_to)

    @property
    def gqa_groups(self) -> int:
        assert self.num_heads % max(self.num_kv_heads, 1) == 0
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def mlstm_d_inner(self) -> int:
        return self.mlstm_proj_factor * self.d_model

    @property
    def supports_decode(self) -> bool:
        return not self.is_encoder_only

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: recurrent/hybrid, or mostly-sliding-window."""
        kinds = [l.mixer for l in self.all_layers()]
        recurrent = sum(k in ("mamba2", "mlstm", "slstm") for k in kinds)
        local = sum(k == "attn_local" for k in kinds)
        return (recurrent + local) >= len(kinds) // 2 and not self.is_encoder_only

    def all_layers(self) -> tuple[LayerSpec, ...]:
        return (self.head_pattern + self.block_pattern * self.num_superblocks
                + self.tail_pattern)

    def shape_cells(self) -> list[str]:
        """Which of the four assigned shape cells apply to this arch."""
        cells = ["train_4k", "prefill_32k"]
        if self.supports_decode:
            cells.append("decode_32k")
            if self.sub_quadratic:
                cells.append("long_500k")
        return cells

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], ArchConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ArchConfig]):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str) -> ArchConfig:
    # Import the per-arch modules lazily so `import repro.configs.base` stays light.
    import repro.configs  # noqa: F401  (triggers registration)

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)


def reduced_config(cfg: ArchConfig) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    kw = dict(
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=16,
        d_ff=max(128, 0 if cfg.d_ff == 0 else 128) if cfg.d_ff else 0,
        vocab_size=256,
        num_superblocks=min(cfg.num_superblocks, 2),
        window_size=min(cfg.window_size, 16) if cfg.window_size else 0,
        num_image_tokens=16 if cfg.num_image_tokens else 0,
        param_dtype="float32",
        compute_dtype="float32",
        vocab_round_to=16,
        fsdp=False,
    )
    if cfg.num_experts:
        # capacity_factor high enough that smoke tests see no capacity drops
        # (drops are legitimate MoE semantics but break decode==train checks)
        kw.update(num_experts=4, experts_per_token=2, moe_d_ff=32,
                  num_shared_experts=min(cfg.num_shared_experts, 1),
                  first_dense_ff=64 if cfg.first_dense_ff else 0,
                  capacity_factor=8.0)
    if cfg.ssm_state:
        kw.update(ssm_state=8, ssm_head_dim=16, ssm_chunk=8)
    return cfg.replace(**kw)
