"""qwen3-0.6b [dense]: 28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936
— qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]."""
from repro.configs.base import ArchConfig, LayerSpec, register


@register("qwen3-0.6b")
def make() -> ArchConfig:
    return ArchConfig(
        name="qwen3-0.6b",
        family="dense",
        d_model=1024,
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,  # qwen3 uses explicit head_dim=128 (q_dim 2048 != d_model)
        d_ff=3072,
        vocab_size=151936,
        block_pattern=(LayerSpec("attn", "mlp"),),
        num_superblocks=28,
        use_qk_norm=True,
        rope_theta=1e6,
        param_dtype="float32",
        optimizer="adamw",
    )
