"""qwen3-4b [dense]: 36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936
— qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]."""
from repro.configs.base import ArchConfig, LayerSpec, register


@register("qwen3-4b")
def make() -> ArchConfig:
    return ArchConfig(
        name="qwen3-4b",
        family="dense",
        d_model=2560,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=9728,
        vocab_size=151936,
        block_pattern=(LayerSpec("attn", "mlp"),),
        num_superblocks=36,
        use_qk_norm=True,
        rope_theta=1e6,
        param_dtype="float32",
        optimizer="adamw",
    )
