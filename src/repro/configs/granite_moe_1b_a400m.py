"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) expert d_ff=512
vocab=49155, MoE 32 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

The 512-wide experts are the Octopus under-utilization regime at LM scale —
this arch is the strongest showcase for the paper's VPE/collaborative routing.
vocab 49155 is not shard-friendly; padded to a multiple of 128 (logits masked).
"""
from repro.configs.base import ArchConfig, LayerSpec, register


@register("granite-moe-1b-a400m")
def make() -> ArchConfig:
    return ArchConfig(
        name="granite-moe-1b-a400m",
        family="moe",
        d_model=1024,
        num_heads=16,
        num_kv_heads=8,
        head_dim=64,
        d_ff=512,
        vocab_size=49155,
        block_pattern=(LayerSpec("attn", "moe"),),
        num_superblocks=24,
        num_experts=32,
        experts_per_token=8,
        moe_d_ff=512,
        rope_theta=1e4,
        param_dtype="float32",
        optimizer="adamw",
    )
