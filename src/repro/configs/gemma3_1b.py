"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144
— 5:1 local:global sliding-window attention, 128k+ context
[hf:google/gemma-3-1b-pt; unverified].

Superblock = 5 local + 1 global; 4 superblocks + 2 trailing local layers = 26.
Local layers use window 512 and rope theta 10k; globals theta 1M.
"""
from repro.configs.base import ArchConfig, LayerSpec, register

_L = LayerSpec("attn_local", "mlp")
_G = LayerSpec("attn", "mlp")


@register("gemma3-1b")
def make() -> ArchConfig:
    return ArchConfig(
        name="gemma3-1b",
        family="dense",
        d_model=1152,
        num_heads=4,
        num_kv_heads=1,
        head_dim=256,
        d_ff=6912,
        vocab_size=262144,
        block_pattern=(_L, _L, _L, _L, _L, _G),
        num_superblocks=4,
        tail_pattern=(_L, _L),
        window_size=512,
        use_qk_norm=True,
        rope_theta=1e6,
        rope_theta_local=1e4,
        embed_scale=True,
        param_dtype="float32",
        optimizer="adamw",
    )
