"""llama-3.2-vision-90b [vlm]: 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — cross-attention image layers every 5th layer
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

The vision frontend is a STUB per the assignment: input_specs provides
precomputed patch embeddings (B, num_image_tokens, d_model); cross-attn layers
attend to them (no rope on cross kv).  Superblock = 4 self + 1 cross, x20.
"""
from repro.configs.base import ArchConfig, LayerSpec, register

_S = LayerSpec("attn", "mlp")
_X = LayerSpec("attn_cross", "mlp")


@register("llama-3.2-vision-90b")
def make() -> ArchConfig:
    return ArchConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab_size=128256,
        block_pattern=(_S, _S, _S, _S, _X),
        num_superblocks=20,
        rope_theta=5e5,
        frontend="vision_patches",
        num_image_tokens=1600,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        optimizer="adamw",
        remat="full",
    )
