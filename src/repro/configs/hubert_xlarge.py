"""hubert-xlarge [audio]: 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 —
encoder-only transformer backbone [arXiv:2106.07447; unverified].

The audio frontend (conv feature encoder) is a STUB per the assignment:
input_specs provides precomputed frame embeddings (B, S, d_model).  Training
is masked-unit prediction (per-frame CE over the 504 cluster vocabulary).
Encoder-only: no decode shape cells.
"""
from repro.configs.base import ArchConfig, LayerSpec, register


@register("hubert-xlarge")
def make() -> ArchConfig:
    return ArchConfig(
        name="hubert-xlarge",
        family="audio",
        d_model=1280,
        num_heads=16,
        num_kv_heads=16,
        head_dim=80,
        d_ff=5120,
        vocab_size=504,
        block_pattern=(LayerSpec("attn", "mlp"),),
        num_superblocks=48,
        mlp_gated=False,  # hubert uses a plain gelu MLP
        causal=False,
        is_encoder_only=True,
        frontend="audio_frames",
        rope_theta=1e4,
        vocab_round_to=8,
        param_dtype="float32",
        optimizer="adamw",
    )
