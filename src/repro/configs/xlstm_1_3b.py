"""xlstm-1.3b [ssm]: 48 blocks d_model=2048 4H d_ff=0 vocab=50304 — sLSTM +
mLSTM blocks at 7:1 [arXiv:2405.04517; unverified].

d_ff=0: blocks carry their own projections (mLSTM up-projects 2x internally).
Superblock = 7 mLSTM + 1 sLSTM, x6 = 48 blocks.  Decode state is O(1) in
sequence length, so the long_500k cell runs.
"""
from repro.configs.base import ArchConfig, LayerSpec, register

_M = LayerSpec("mlstm", "none")
_S = LayerSpec("slstm", "none")


@register("xlstm-1.3b")
def make() -> ArchConfig:
    return ArchConfig(
        name="xlstm-1.3b",
        family="ssm",
        d_model=2048,
        num_heads=4,
        num_kv_heads=4,
        head_dim=512,
        d_ff=0,
        vocab_size=50304,
        block_pattern=(_M, _M, _M, _M, _M, _M, _M, _S),
        num_superblocks=6,
        mlstm_proj_factor=2,
        ssm_chunk=256,
        param_dtype="float32",
        optimizer="adamw",
    )
