"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) expert d_ff=2048
vocab=163840, MoE 384 experts top-8 + 1 shared expert; first layer dense
(DeepSeek-V3-style) [arXiv:2501.kimi2; unverified].

At 1.04T parameters this is the framework's capacity stress test: bf16 params,
Adafactor (factored second moment), full remat, FSDP x TP x EP sharding.
"""
from repro.configs.base import ArchConfig, LayerSpec, register


@register("kimi-k2-1t-a32b")
def make() -> ArchConfig:
    return ArchConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        d_model=7168,
        num_heads=64,
        num_kv_heads=8,
        head_dim=112,
        d_ff=2048,  # per-expert width (assignment table)
        vocab_size=163840,
        head_pattern=(LayerSpec("attn", "mlp"),),  # layer 0 dense
        block_pattern=(LayerSpec("attn", "moe"),),
        num_superblocks=60,
        num_experts=384,
        experts_per_token=8,
        moe_d_ff=2048,
        num_shared_experts=1,
        first_dense_ff=16384,
        rope_theta=5e4,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        optimizer="adafactor",
        remat="full",
    )
