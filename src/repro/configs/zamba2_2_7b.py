"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000,
ssm_state=64 — Mamba2 blocks + a shared (tied-weight) attention+MLP block
[arXiv:2411.15242; hf].

Superblock = 5 mamba2 + 1 shared attention block, x9 = 54 layers.  The shared
block's weights live once in params["shared"] and are reused by every
superblock (zamba2's parameter-sharing trick); its KV cache is still
per-occurrence.  Recurrent decode state makes long_500k runnable.
"""
from repro.configs.base import ArchConfig, LayerSpec, register

_M = LayerSpec("mamba2", "none")
_A = LayerSpec("attn_shared", "mlp_shared")


@register("zamba2-2.7b")
def make() -> ArchConfig:
    return ArchConfig(
        name="zamba2-2.7b",
        family="hybrid",
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        head_dim=80,
        d_ff=10240,
        vocab_size=32000,
        block_pattern=(_M, _M, _M, _M, _M, _A),
        num_superblocks=9,
        ssm_state=64,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_chunk=256,
        rope_theta=1e4,
        param_dtype="float32",
        optimizer="adamw",
    )
