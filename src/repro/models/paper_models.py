"""The paper's own use-case models (§4.2), built on the routed compute core.

  * Use-case 1: packet-based MLP for intrusion detection [40]:
      6 -> 12 -> 6 -> 3 -> 2, ReLU; input = per-packet features.
  * Use-case 2: flow-based 1D-CNN traffic classifier [51]:
      3 conv layers {k=3, c: 1->32->32->32} with ceil max-pool stride 2
      between, flatten -> FC 128 -> linear 162; input = top-20 arrival
      intervals of a flow.
  * Use-case 3: flow-based payload transformer [49]:
      payload matrix (15 pkts x 16 bytes), WQ/WK/WV (16,64), single-head
      self-attention, MLP 64->128->64, mean-pool -> linear classifier.

All matmuls go through the Octopus router; conv layers are lowered via
img2col so the placement matches the paper's matrix-multiplication mapping
exactly ((20f,3)x(3,32), (10f,96)x(96,32), ...).

Tuning comes from the ambient :mod:`repro.runtime` config (or an explicit
``config=``).  The old per-call ``policy=`` / ``use_pallas=`` /
``fused_aggregation=`` kwargs were removed on the PR 1 deprecation schedule.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.util import ceil_div
from repro.core import router
from repro.models.spec import ParamSpec, init_params
from repro.runtime import RuntimeConfig, octopus_runtime, resolve_config


# ---------------------------------------------------------------------------
# Use-case 1: packet MLP (6 -> 12 -> 6 -> 3 -> 2)
# ---------------------------------------------------------------------------

MLP_DIMS = (6, 12, 6, 3, 2)


def mlp_specs() -> dict:
    specs = {}
    for i, (a, b) in enumerate(zip(MLP_DIMS[:-1], MLP_DIMS[1:])):
        specs[f"w{i}"] = ParamSpec((a, b), (None, None), "normal")
        specs[f"b{i}"] = ParamSpec((b,), (None,), "zeros")
    return specs


def mlp_apply(params: dict, x: jax.Array, *,
              config: Optional[RuntimeConfig] = None) -> jax.Array:
    with octopus_runtime(resolve_config(config)):
        h = x
        n = len(MLP_DIMS) - 1
        for i in range(n):
            act = "relu" if i < n - 1 else None
            h = router.matmul(h, params[f"w{i}"], name=f"w{i}") + params[f"b{i}"]
            if act == "relu":
                h = jnp.maximum(h, 0.0)
        return h


# ---------------------------------------------------------------------------
# Use-case 2: flow 1D-CNN (matmul mapping per paper §3.2.3 / §4.2)
# ---------------------------------------------------------------------------

CNN_SEQ = 20  # top-20 packet arrival intervals
CNN_CHANNELS = (1, 32, 32, 32)
CNN_KERNEL = 3
CNN_FC = 128
CNN_CLASSES = 162


def _img2col_1d(x: jax.Array, k: int) -> jax.Array:
    """x: (..., L, C) -> (..., L, k*C) with 'same' zero padding (stride 1)."""
    pad = k // 2
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(pad, pad), (0, 0)])
    cols = [xp[..., i : i + x.shape[-2], :] for i in range(k)]
    return jnp.concatenate(cols, axis=-1)


def _ceil_pool(x: jax.Array, stride: int = 2) -> jax.Array:
    """Max-pool stride 2 with ceil semantics (paper: 20->10->5->3)."""
    l = x.shape[-2]
    lp = ceil_div(l, stride) * stride
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(0, lp - l), (0, 0)],
                 constant_values=-np.inf)
    return xp.reshape(*x.shape[:-2], lp // stride, stride, x.shape[-1]).max(axis=-2)


def cnn_specs() -> dict:
    specs = {}
    for i, (ci, co) in enumerate(zip(CNN_CHANNELS[:-1], CNN_CHANNELS[1:])):
        specs[f"conv{i}"] = ParamSpec((CNN_KERNEL * ci, co), (None, None), "normal")
        specs[f"convb{i}"] = ParamSpec((co,), (None,), "zeros")
    flat = 3 * CNN_CHANNELS[-1]  # 20 -> 10 -> 5 -> 3 after three ceil-pools
    specs["fc_w"] = ParamSpec((flat, CNN_FC), (None, None), "normal")
    specs["fc_b"] = ParamSpec((CNN_FC,), (None,), "zeros")
    specs["out_w"] = ParamSpec((CNN_FC, CNN_CLASSES), (None, None), "normal")
    specs["out_b"] = ParamSpec((CNN_CLASSES,), (None,), "zeros")
    return specs


def cnn_apply(params: dict, x: jax.Array, *,
              config: Optional[RuntimeConfig] = None) -> jax.Array:
    """x: (F, 20) interval vectors -> logits (F, 162)."""
    from repro.core.collaborative import _unfused_jnp

    cfg = resolve_config(config)
    with octopus_runtime(cfg):
        h = x[..., :, None].astype(jnp.float32)  # (F, 20, 1)
        for i in range(len(CNN_CHANNELS) - 1):
            cols = _img2col_1d(h, CNN_KERNEL)  # (F, L, k*ci) == the paper's (w, ic*s)
            w = params[f"conv{i}"]
            if cfg.fused_aggregation:
                h = router.matmul(cols, w, name=f"conv{i + 1}")
            else:
                m = int(np.prod(cols.shape[:-1]))
                r = router.route_matmul(m, w.shape[0], w.shape[1], name=f"conv{i + 1}")
                h = (_unfused_jnp(cols, w, None) if r.path == "arype"
                     else router.matmul(cols, w, route=r))
            h = jnp.maximum(h + params[f"convb{i}"], 0.0)
            h = _ceil_pool(h)
        h = h.reshape(h.shape[0], -1)  # (F, 96)
        h = jnp.maximum(router.matmul(h, params["fc_w"], name="fc") + params["fc_b"], 0.0)
        return router.matmul(h, params["out_w"], name="linear") + params["out_b"]


# ---------------------------------------------------------------------------
# Use-case 3: payload transformer
# ---------------------------------------------------------------------------

TF_PKTS = 15
TF_BYTES = 16
TF_DK = 64
TF_MLP = 128
TF_CLASSES = 162


def transformer_specs() -> dict:
    return {
        "wq": ParamSpec((TF_BYTES, TF_DK), (None, None), "normal"),
        "wk": ParamSpec((TF_BYTES, TF_DK), (None, None), "normal"),
        "wv": ParamSpec((TF_BYTES, TF_DK), (None, None), "normal"),
        "mlp1": ParamSpec((TF_DK, TF_MLP), (None, None), "normal"),
        "mlp1_b": ParamSpec((TF_MLP,), (None,), "zeros"),
        "mlp2": ParamSpec((TF_MLP, TF_DK), (None, None), "normal"),
        "mlp2_b": ParamSpec((TF_DK,), (None,), "zeros"),
        "cls_w": ParamSpec((TF_DK, TF_CLASSES), (None, None), "normal"),
        "cls_b": ParamSpec((TF_CLASSES,), (None,), "zeros"),
    }


def transformer_apply(params: dict, payload: jax.Array, *,
                      config: Optional[RuntimeConfig] = None) -> jax.Array:
    """payload: (F, 15, 16) normalized byte matrix -> logits (F, 162)."""
    with octopus_runtime(resolve_config(config)):
        mm = router.matmul
        x = payload.astype(jnp.float32)
        q = mm(x, params["wq"], name="wq")  # (F,15,64)   [(15,16)x(16,64)]
        k = mm(x, params["wk"], name="wk")
        v = mm(x, params["wv"], name="wv")
        s = jnp.einsum("fqd,fkd->fqk", q, k) / np.sqrt(TF_DK)  # [(15,64)x(64,15)]
        a = jax.nn.softmax(s, axis=-1)
        h = jnp.einsum("fqk,fkd->fqd", a, v)  # [(15,15)x(15,64)]
        h = jnp.maximum(mm(h, params["mlp1"], name="mlp1") + params["mlp1_b"], 0.0)
        h = mm(h, params["mlp2"], name="mlp2") + params["mlp2_b"]
        pooled = h.mean(axis=1)
        return mm(pooled, params["cls_w"], name="cls") + params["cls_b"]


def init_paper_model(kind: str, key: jax.Array) -> dict:
    specs = {"mlp": mlp_specs, "cnn": cnn_specs, "transformer": transformer_specs}[kind]()
    return init_params(specs, key)
