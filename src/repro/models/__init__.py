from repro.models.transformer import LM
