"""Recurrent mixers: Mamba-2 (SSD, chunked), xLSTM mLSTM (chunkwise-parallel,
log-space stabilized) and sLSTM (sequential scan).

All follow the same interface as attention layers:
  *_specs(cfg)                        parameter spec tree
  *_apply(p, x, cfg, mode, cache)     -> (y, new_cache)
Caches are fixed-size recurrent states, so decode is O(1) per token — this is
what makes the long_500k cell runnable for the ssm/hybrid archs.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.common.util import ceil_div
from repro.configs.base import ArchConfig
from repro.core import router
from repro.distributed.act import shard_act
from repro.models.layers import rms_norm
from repro.runtime import RuntimeConfig
from repro.models.spec import ParamSpec


# ===========================================================================
# Mamba-2 (SSD)
# ===========================================================================

class Mamba2Cache(NamedTuple):
    ssm: jax.Array  # (B, H, N, P) state
    conv: jax.Array  # (B, W-1, conv_dim) rolling conv inputs


def mamba2_specs(cfg: ArchConfig) -> dict:
    dt = cfg.param_dtype
    d = cfg.d_model
    din = cfg.ssm_d_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads
    conv_dim = din + 2 * n
    in_dim = 2 * din + 2 * n + h  # z, x, B, C, dt
    return {
        "ln": ParamSpec((d,), ("embed",), "zeros", dtype=dt),
        "in_proj": ParamSpec((d, in_dim), ("embed", "ssm_inner"), "normal", dtype=dt),
        "conv_w": ParamSpec((cfg.ssm_conv_width, conv_dim), (None, "ssm_inner"), "small_normal", dtype=dt),
        "conv_b": ParamSpec((conv_dim,), ("ssm_inner",), "zeros", dtype=dt),
        "a_log": ParamSpec((h,), (None,), "mamba_alog", dtype="float32"),
        "d_skip": ParamSpec((h,), (None,), "ones", dtype="float32"),
        "dt_bias": ParamSpec((h,), (None,), "mamba_dt", dtype="float32"),
        "norm": ParamSpec((din,), ("ssm_inner",), "zeros", dtype=dt),
        "out_proj": ParamSpec((din, d), ("ssm_inner", "embed"), "normal", dtype=dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv along S.  x: (B,S,C); w: (W,C).  Returns (y, new_state)."""
    bsz, s, c = x.shape
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((bsz, width - 1, c), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, S+W-1, C)
    y = jnp.zeros((bsz, s, c), jnp.float32)
    for i in range(width):
        y = y + xp[:, i : i + s, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    y = jax.nn.silu(y + b.astype(jnp.float32))
    new_state = xp[:, -(width - 1):, :] if width > 1 else state
    return y.astype(x.dtype), new_state


def _ssd_chunked(xh, dt, a, b_in, c_in, chunk: int, state0: jax.Array,
                 unroll: bool = False):
    """Chunked state-space-duality scan.
    xh: (B,S,H,P); dt: (B,S,H) (post-softplus); a: (H,) negative;
    b_in/c_in: (B,S,N).  Returns (y (B,S,H,P), final_state (B,H,N,P))."""
    bsz, s, h, p = xh.shape
    n = b_in.shape[-1]
    L = min(chunk, s)
    nc = ceil_div(s, L)
    pad = nc * L - s
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
    xc = xh.reshape(bsz, nc, L, h, p)
    dtc = dt.reshape(bsz, nc, L, h)
    bc = b_in.reshape(bsz, nc, L, n)
    cc = c_in.reshape(bsz, nc, L, n)

    da = dtc * a[None, None, None, :]  # (B,nc,L,H) negative decay increments
    cum = jnp.cumsum(da, axis=2)  # inclusive cumsum within chunk
    total = cum[:, :, -1:, :]  # (B,nc,1,H)

    # within-chunk (diagonal) part: att[t,s] = exp(cum_t - cum_s) * (c_t . b_s) * dt_s,  s <= t
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # (B,nc,L,L,H)
    tri = jnp.tril(jnp.ones((L, L), bool))
    cb = jnp.einsum("bcln,bcmn->bclm", cc, bc)  # (B,nc,L,L)
    att = cb[..., None] * decay * dtc[:, :, None, :, :]
    att = jnp.where(tri[None, None, :, :, None], att, 0.0)
    y_diag = jnp.einsum("bclmh,bcmhp->bclhp", att, xc.astype(jnp.float32))

    # per-chunk outgoing state: sum_s exp(total - cum_s) * dt_s * b_s (x) x_s
    w_out = jnp.exp(total - cum) * dtc  # (B,nc,L,H)
    chunk_states = jnp.einsum("bclh,bcln,bclhp->bchnp", w_out, bc, xc.astype(jnp.float32))

    # inter-chunk recurrence over nc
    def step(carry, inp):
        st_in = carry  # (B,H,N,P)
        cs, tot = inp  # (B,H,N,P), (B,H)
        st_out = jnp.exp(tot)[:, :, None, None] * st_in + cs
        return st_out, st_in  # emit the INCOMING state for each chunk

    totals = jnp.moveaxis(total[:, :, 0, :], 1, 0)  # (nc, B, H)
    cs_seq = jnp.moveaxis(chunk_states, 1, 0)  # (nc, B, H, N, P)
    final_state, in_states = lax.scan(step, state0, (cs_seq, totals),
                                      unroll=True if unroll else 1)
    in_states = jnp.moveaxis(in_states, 0, 1)  # (B, nc, H, N, P)

    # contribution of the incoming state to each position
    y_off = jnp.einsum("bcln,bclh,bchnp->bclhp", cc, jnp.exp(cum), in_states)
    y = (y_diag + y_off).reshape(bsz, nc * L, h, p)[:, :s]
    return y, final_state


def mamba2_apply(
    p: dict, x: jax.Array, cfg: ArchConfig, *, mode: str = "train",
    cache: Optional[Mamba2Cache] = None,
) -> tuple[jax.Array, Optional[Mamba2Cache]]:
    bsz, s, d = x.shape
    din, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    pdim = cfg.ssm_head_dim
    mm = functools.partial(router.matmul, out_dtype=x.dtype,
                           config=RuntimeConfig.from_arch(cfg))
    hin = rms_norm(x, p["ln"])
    proj = mm(hin, p["in_proj"])
    z, xs, b_in, c_in, dt = jnp.split(proj, [din, 2 * din, 2 * din + n, 2 * din + 2 * n], axis=-1)

    conv_in = jnp.concatenate([xs, b_in, c_in], axis=-1)
    conv_state = cache.conv if cache is not None else None
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"], conv_state)
    xs, b_in, c_in = jnp.split(conv_out, [din, din + n], axis=-1)
    xs = shard_act(xs, "batch", None, "inner")

    a = -jnp.exp(p["a_log"])  # (H,)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    xh = shard_act(xs.reshape(bsz, s, h, pdim), "batch", None, "heads", None)

    state0 = cache.ssm if cache is not None else jnp.zeros((bsz, h, n, pdim), jnp.float32)
    state0 = shard_act(state0, "batch", "heads", None, None)
    if mode == "decode" and s == 1:
        # single-step recurrence
        da = jnp.exp(dtp[:, 0, :] * a[None, :])  # (B,H)
        dbx = jnp.einsum("bh,bn,bhp->bhnp", dtp[:, 0], b_in[:, 0].astype(jnp.float32),
                         xh[:, 0].astype(jnp.float32))
        st = da[:, :, None, None] * state0 + dbx
        y = jnp.einsum("bn,bhnp->bhp", c_in[:, 0].astype(jnp.float32), st)
        y = y[:, None]  # (B,1,H,P)
        new_state = st
    else:
        y, new_state = _ssd_chunked(xh, dtp, a, b_in.astype(jnp.float32),
                                    c_in.astype(jnp.float32), cfg.ssm_chunk, state0,
                                    unroll=cfg.inner_unroll)
    y = y + p["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, din).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), p["norm"])
    out = x + mm(y, p["out_proj"])
    new_cache = Mamba2Cache(ssm=new_state, conv=new_conv) if mode != "train" else None
    return out, new_cache


def init_mamba2_cache(cfg: ArchConfig, batch: int) -> Mamba2Cache:
    conv_dim = cfg.ssm_d_inner + 2 * cfg.ssm_state
    return Mamba2Cache(
        ssm=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), jnp.bfloat16),
    )


# ===========================================================================
# xLSTM: mLSTM (matrix memory, chunkwise-parallel)
# ===========================================================================

class MLSTMCache(NamedTuple):
    c: jax.Array  # (B, H, DK, DV) stabilized matrix memory
    n: jax.Array  # (B, H, DK) normalizer
    m: jax.Array  # (B, H) log-space stabilizer


def mlstm_specs(cfg: ArchConfig) -> dict:
    dt = cfg.param_dtype
    d = cfg.d_model
    din = cfg.mlstm_d_inner
    h = cfg.num_heads
    dk = din // h
    return {
        "ln": ParamSpec((d,), ("embed",), "zeros", dtype=dt),
        "w_up": ParamSpec((d, 2 * din), ("embed", "mlstm_inner"), "normal", dtype=dt),
        # headwise (block-diagonal) q/k projections, as in the xLSTM paper
        "wq": ParamSpec((h, dk, dk), (None, "mlstm_qk", None), "normal", dtype=dt),
        "wk": ParamSpec((h, dk, dk), (None, "mlstm_qk", None), "normal", dtype=dt),
        "w_if": ParamSpec((din, 2 * h), ("mlstm_inner", None), "small_normal", dtype="float32"),
        "if_bias": ParamSpec((2 * h,), (None,), "zeros", dtype="float32"),
        "mnorm": ParamSpec((din,), ("mlstm_inner",), "zeros", dtype=dt),
        "w_down": ParamSpec((din, d), ("mlstm_inner", "embed"), "normal", dtype=dt),
    }


def _mlstm_chunk_scan(q, k, v, ig, lf, chunk: int, cache: MLSTMCache,
                      unroll: bool = False):
    """Chunkwise-parallel stabilized mLSTM.
    q,k,v: (B,S,H,D); ig: (B,S,H) raw input-gate preact; lf: (B,S,H)
    log-sigmoid forget gate.  Returns (h (B,S,H,D), new cache)."""
    bsz, s, h, dk = q.shape
    dv = v.shape[-1]
    L = min(chunk, s)
    nc = ceil_div(s, L)
    pad = nc * L - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        ig = jnp.pad(ig, ((0, 0), (0, pad), (0, 0)), constant_values=-1e9)
        lf = jnp.pad(lf, ((0, 0), (0, pad), (0, 0)))
    shp = (bsz, nc, L)
    qc = q.reshape(*shp, h, dk).astype(jnp.float32) / np.sqrt(dk)
    kc = k.reshape(*shp, h, dk).astype(jnp.float32)
    vc = v.reshape(*shp, h, dv).astype(jnp.float32)
    igc = ig.reshape(*shp, h)
    lfc = lf.reshape(*shp, h)

    bcum = jnp.cumsum(lfc, axis=2)  # (B,nc,L,H) inclusive log-decay
    btot = bcum[:, :, -1, :]  # (B,nc,H)
    u = igc - bcum  # source term in log space
    ucmax = lax.cummax(u, axis=2)  # (B,nc,L,H)

    def chunk_step(carry, inp):
        c_in, n_in, m_in = carry  # (B,H,DK,DV), (B,H,DK), (B,H)
        qj, kj, vj, bj, uj, ujmax, btj = inp
        # per-position stabilizer: mq_t = b_t + max(m_in, cummax_s<=t u_s)
        mq = bj + jnp.maximum(m_in[:, None, :], ujmax)  # (B,L,H)
        # intra-chunk gate matrix: exp(b_t - b_s + i_s - mq_t) for s <= t
        glog = bj[:, :, None, :] + uj[:, None, :, :] - mq[:, :, None, :]
        tri = jnp.tril(jnp.ones((bj.shape[1], bj.shape[1]), bool))
        gmat = jnp.where(tri[None, :, :, None], jnp.exp(glog), 0.0)  # (B,L,L,H)
        scores = jnp.einsum("blhd,bmhd->blmh", qj, kj) * gmat
        num_intra = jnp.einsum("blmh,bmhp->blhp", scores, vj)
        den_intra = scores.sum(axis=2)  # (B,L,H): sum_s gate[t,s] * (q_t . k_s)
        # inter (incoming state) contribution, scaled exp(b_t + m_in - mq_t)
        w_in = jnp.exp(bj + m_in[:, None, :] - mq)  # (B,L,H)
        num_inter = jnp.einsum("blhd,bhdp->blhp", qj, c_in) * w_in[..., None]
        den_inter = jnp.einsum("blhd,bhd->blh", qj, n_in) * w_in
        num = num_intra + num_inter
        den = den_intra + den_inter
        hj = num / jnp.maximum(jnp.abs(den), jnp.exp(-mq))[..., None]
        # chunk-exit state
        m_out = btj + jnp.maximum(m_in, ujmax[:, -1, :])  # (B,H)
        # exp(btot - b_s + i_s - m_out) == exp(btot + u_s - m_out)
        w_state = jnp.exp(btj[:, None, :] + uj - m_out[:, None, :])
        c_out = (jnp.exp(btj + m_in - m_out)[:, :, None, None] * c_in
                 + jnp.einsum("blh,blhd,blhp->bhdp", w_state, kj, vj))
        n_out = (jnp.exp(btj + m_in - m_out)[:, :, None] * n_in
                 + jnp.einsum("blh,blhd->bhd", w_state, kj))
        return (c_out, n_out, m_out), hj

    xs = (
        jnp.moveaxis(qc, 1, 0), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
        jnp.moveaxis(bcum, 1, 0), jnp.moveaxis(u, 1, 0), jnp.moveaxis(ucmax, 1, 0),
        jnp.moveaxis(btot, 1, 0),
    )
    carry0 = (shard_act(cache.c, "batch", None, "inner", None),
              shard_act(cache.n, "batch", None, "inner"),
              cache.m)
    (c_f, n_f, m_f), hs = lax.scan(chunk_step, carry0, xs,
                                   unroll=True if unroll else 1)
    hs = jnp.moveaxis(hs, 0, 1).reshape(bsz, nc * L, h, dv)[:, :s]
    return hs, MLSTMCache(c=c_f, n=n_f, m=m_f)


def mlstm_apply(
    p: dict, x: jax.Array, cfg: ArchConfig, *, mode: str = "train",
    cache: Optional[MLSTMCache] = None,
) -> tuple[jax.Array, Optional[MLSTMCache]]:
    bsz, s, d = x.shape
    din, h = cfg.mlstm_d_inner, cfg.num_heads
    dk = din // h
    mm = functools.partial(router.matmul, out_dtype=x.dtype,
                           config=RuntimeConfig.from_arch(cfg))
    hin = rms_norm(x, p["ln"])
    up = mm(hin, p["w_up"])
    xs, z = jnp.split(up, 2, axis=-1)  # cell path, gate path
    xs = shard_act(xs, "batch", None, "inner")
    xh = xs.reshape(bsz, s, h, dk)
    # no explicit constraint on q/k: propagation from the 16-way inner dim
    # factors naturally into (heads x dk) tiles; forcing dk-only sharding
    # triggers involuntary full rematerialization in the partitioner
    q = jnp.einsum("bshd,hde->bshe", xh, p["wq"]).astype(x.dtype)
    k = jnp.einsum("bshd,hde->bshe", xh, p["wk"]).astype(x.dtype)
    v = xh
    gates = jnp.einsum("bsd,dg->bsg", xs.astype(jnp.float32), p["w_if"]) + p["if_bias"]
    ig, fg = jnp.split(gates, 2, axis=-1)  # (B,S,H) each
    lf = jax.nn.log_sigmoid(fg)

    c0 = cache if cache is not None else init_mlstm_cache(cfg, bsz)
    hs, new_cache = _mlstm_chunk_scan(q, k, v, ig, lf, cfg.ssm_chunk or 256, c0,
                                      unroll=cfg.inner_unroll)
    hs = hs.reshape(bsz, s, din).astype(x.dtype)
    hs = rms_norm(hs, p["mnorm"]) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = x + mm(hs, p["w_down"])
    return out, (new_cache if mode != "train" else None)


def init_mlstm_cache(cfg: ArchConfig, batch: int) -> MLSTMCache:
    din, h = cfg.mlstm_d_inner, cfg.num_heads
    dk = din // h
    return MLSTMCache(
        c=jnp.zeros((batch, h, dk, dk), jnp.float32),
        n=jnp.zeros((batch, h, dk), jnp.float32),
        m=jnp.full((batch, h), -1e30, jnp.float32),
    )


# ===========================================================================
# xLSTM: sLSTM (scalar memory, sequential)
# ===========================================================================

class SLSTMCache(NamedTuple):
    c: jax.Array  # (B, H, D)
    n: jax.Array  # (B, H, D)
    m: jax.Array  # (B, H, D)
    h: jax.Array  # (B, H, D) hidden (recurrent input)


def slstm_specs(cfg: ArchConfig) -> dict:
    dt = cfg.param_dtype
    d = cfg.d_model
    h = cfg.num_heads
    hd = d // h
    return {
        "ln": ParamSpec((d,), ("embed",), "zeros", dtype=dt),
        "w_gates": ParamSpec((d, 4 * d), ("embed", "slstm_gates"), "normal", dtype=dt),
        "r_gates": ParamSpec((h, hd, 4 * hd), (None, None, None), "small_normal", dtype="float32"),
        "gnorm": ParamSpec((d,), ("embed",), "zeros", dtype=dt),
        "w_down": ParamSpec((d, d), ("embed", "embed_out"), "normal", dtype=dt),
    }


def _slstm_cell(wx_t, r, st: SLSTMCache):
    """wx_t: (B, H, 4*HD) input contributions; r: (H, HD, 4HD)."""
    rec = jnp.einsum("bhd,hdg->bhg", st.h, r)  # (B,H,4HD)
    pre = wx_t.astype(jnp.float32) + rec
    i_raw, f_raw, z_raw, o_raw = jnp.split(pre, 4, axis=-1)
    m_new = jnp.maximum(jax.nn.log_sigmoid(f_raw) + st.m, i_raw)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(jax.nn.log_sigmoid(f_raw) + st.m - m_new)
    c_new = f_g * st.c + i_g * jnp.tanh(z_raw)
    n_new = f_g * st.n + i_g
    h_new = jax.nn.sigmoid(o_raw) * c_new / jnp.maximum(n_new, 1.0)
    return SLSTMCache(c=c_new, n=n_new, m=m_new, h=h_new)


def slstm_apply(
    p: dict, x: jax.Array, cfg: ArchConfig, *, mode: str = "train",
    cache: Optional[SLSTMCache] = None,
) -> tuple[jax.Array, Optional[SLSTMCache]]:
    bsz, s, d = x.shape
    h = cfg.num_heads
    hd = d // h
    mm = functools.partial(router.matmul, out_dtype=x.dtype,
                           config=RuntimeConfig.from_arch(cfg))
    hin = rms_norm(x, p["ln"])
    wx = mm(hin, p["w_gates"]).reshape(bsz, s, h, 4 * hd)
    st0 = cache if cache is not None else init_slstm_cache(cfg, bsz)

    def step(st, wx_t):
        st1 = _slstm_cell(wx_t, p["r_gates"], st)
        return st1, st1.h

    st_f, hs = lax.scan(step, st0, jnp.moveaxis(wx, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1).reshape(bsz, s, d).astype(x.dtype)
    hs = rms_norm(hs, p["gnorm"])
    out = x + mm(hs, p["w_down"])
    return out, (st_f if mode != "train" else None)


def init_slstm_cache(cfg: ArchConfig, batch: int) -> SLSTMCache:
    h = cfg.num_heads
    hd = cfg.d_model // h
    z = jnp.zeros((batch, h, hd), jnp.float32)
    return SLSTMCache(c=z, n=z, m=jnp.full_like(z, -1e30), h=z)
