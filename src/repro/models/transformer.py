"""The generic LM assembly: embed -> head layers -> scan(superblock) -> tail
layers -> final norm -> lm head, with train / prefill / decode entry points.

Every assigned architecture is an instance of this framework (see
repro/configs/*.py); heterogeneous depth patterns (gemma3's 5:1 local:global,
llama-vision's 4:1 self:cross, zamba2's 5:1 mamba:shared-attn, xlstm's 7:1
mLSTM:sLSTM) are expressed as superblock patterns so the scan body stays
uniform and HLO size is ~constant in depth.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ArchConfig, LayerSpec
from repro.core import router
from repro.distributed.act import shard_act
from repro.models import recurrent as rec
from repro.models import spec as pspec
from repro.runtime import RuntimeConfig
from repro.models.layers import (
    AttnCache,
    attn_apply,
    attn_specs,
    init_attn_cache,
    mlp_apply,
    mlp_specs,
    moe_apply,
    moe_specs,
    rms_norm,
)
from repro.models.spec import ParamSpec


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def layer_specs(cfg: ArchConfig, spec: LayerSpec, *, d_ff_override: Optional[int] = None) -> dict:
    out: dict = {}
    if spec.mixer in ("attn", "attn_local"):
        out["mixer"] = attn_specs(cfg)
    elif spec.mixer == "attn_cross":
        out["mixer"] = attn_specs(cfg, cross=True)
    elif spec.mixer == "mamba2":
        out["mixer"] = rec.mamba2_specs(cfg)
    elif spec.mixer == "mlstm":
        out["mixer"] = rec.mlstm_specs(cfg)
    elif spec.mixer == "slstm":
        out["mixer"] = rec.slstm_specs(cfg)
    elif spec.mixer in ("attn_shared", "none"):
        out["mixer"] = {}  # params live in the shared group / absent
    if spec.ffn == "mlp":
        out["ffn"] = mlp_specs(cfg, d_ff_override)
    elif spec.ffn == "moe":
        out["ffn"] = moe_specs(cfg)
    elif spec.ffn in ("mlp_shared", "none"):
        out["ffn"] = {}
    return out


def _uses_shared(cfg: ArchConfig) -> bool:
    return any(
        l.mixer == "attn_shared" or l.ffn == "mlp_shared" for l in cfg.all_layers()
    )


def superblock_specs(cfg: ArchConfig) -> dict:
    return {f"l{i}": layer_specs(cfg, s) for i, s in enumerate(cfg.block_pattern)}


def model_specs(cfg: ArchConfig) -> dict:
    dt = cfg.param_dtype
    d, v = cfg.d_model, cfg.padded_vocab
    specs: dict = {}
    if cfg.frontend != "audio_frames":
        specs["embed"] = ParamSpec((v, d), ("vocab", "embed"), "small_normal", dtype=dt)
    for i, s in enumerate(cfg.head_pattern):
        specs[f"pre{i}"] = layer_specs(cfg, s, d_ff_override=cfg.first_dense_ff or None)
    specs["blocks"] = pspec.stack_specs(superblock_specs(cfg), cfg.num_superblocks)
    for i, s in enumerate(cfg.tail_pattern):
        specs[f"tail{i}"] = layer_specs(cfg, s)
    if _uses_shared(cfg):
        shared: dict = {}
        shared["mixer"] = attn_specs(cfg)
        shared["ffn"] = mlp_specs(cfg)
        specs["shared"] = shared
    specs["final_norm"] = ParamSpec((d,), ("embed",), "zeros", dtype=dt)
    specs["lm_head"] = ParamSpec((d, v), ("embed", "vocab"), "small_normal", dtype=dt)
    return specs


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def _layer_cache(cfg: ArchConfig, spec: LayerSpec, batch: int, cache_len: int):
    m = spec.mixer
    if m == "attn":
        return init_attn_cache(cfg, batch, cache_len, kind="causal")
    if m == "attn_shared":
        return init_attn_cache(cfg, batch, cache_len, kind="causal")
    if m == "attn_local":
        return init_attn_cache(cfg, batch, cache_len, kind="local")
    if m == "attn_cross":
        t = max(cfg.num_image_tokens, 1)
        return AttnCache(
            k=jnp.zeros((batch, t, cfg.num_kv_heads, cfg.head_dim), jnp.bfloat16),
            v=jnp.zeros((batch, t, cfg.num_kv_heads, cfg.head_dim), jnp.bfloat16),
            pos=jnp.zeros((batch, t), jnp.int32),
        )
    if m == "mamba2":
        return rec.init_mamba2_cache(cfg, batch)
    if m == "mlstm":
        return rec.init_mlstm_cache(cfg, batch)
    if m == "slstm":
        return rec.init_slstm_cache(cfg, batch)
    return ()


def init_cache(cfg: ArchConfig, batch: int, cache_len: int) -> dict:
    blocks = {
        f"l{i}": jax.tree.map(
            lambda x: jnp.stack([x] * cfg.num_superblocks) if hasattr(x, "shape") else x,
            _layer_cache(cfg, s, batch, cache_len),
        )
        for i, s in enumerate(cfg.block_pattern)
    }
    cache = {
        "blocks": blocks,
        "lengths": jnp.zeros((batch,), jnp.int32),
    }
    for i, s in enumerate(cfg.head_pattern):
        cache[f"pre{i}"] = _layer_cache(cfg, s, batch, cache_len)
    for i, s in enumerate(cfg.tail_pattern):
        cache[f"tail{i}"] = _layer_cache(cfg, s, batch, cache_len)
    return cache


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

def _apply_layer(
    lp: dict,
    shared: Optional[dict],
    h: jax.Array,
    cfg: ArchConfig,
    spec: LayerSpec,
    *,
    mode: str,
    cache: Any = None,
    lengths: Optional[jax.Array] = None,
    cross_kv: Optional[jax.Array] = None,
):
    """Returns (h, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    m = spec.mixer
    new_cache = ()
    if m in ("attn", "attn_local", "attn_cross", "attn_shared"):
        kind = {
            "attn": "causal" if cfg.causal else "full",
            "attn_local": "local",
            "attn_cross": "cross",
            "attn_shared": "causal" if cfg.causal else "full",
        }[m]
        p_attn = shared["mixer"] if m == "attn_shared" else lp["mixer"]
        h, new_cache = attn_apply(
            p_attn, h, cfg, kind=kind, cross_kv=cross_kv,
            cache=(cache if cache != () else None), lengths=lengths, mode=mode,
        )
    elif m == "mamba2":
        h, new_cache = rec.mamba2_apply(lp["mixer"], h, cfg, mode=mode,
                                        cache=(cache if cache != () else None))
    elif m == "mlstm":
        h, new_cache = rec.mlstm_apply(lp["mixer"], h, cfg, mode=mode,
                                       cache=(cache if cache != () else None))
    elif m == "slstm":
        h, new_cache = rec.slstm_apply(lp["mixer"], h, cfg, mode=mode,
                                       cache=(cache if cache != () else None))

    if spec.ffn == "mlp":
        h = mlp_apply(lp["ffn"], h, cfg)
    elif spec.ffn == "mlp_shared":
        h = mlp_apply(shared["ffn"], h, cfg)
    elif spec.ffn == "moe":
        h, aux = moe_apply(lp["ffn"], h, cfg)
    if new_cache is None:
        new_cache = ()
    return h, new_cache, aux


def _apply_superblock(sbp, sbc, shared, h, cfg, *, mode, lengths, cross_kv):
    # pin the scan carry's sharding (sequence-parallel shards the seq dim over
    # the model axis: AG/RS around matmuls instead of fp32 psums, and 16x
    # smaller remat checkpoints)
    seq_axis = "seq_sp" if (cfg.sequence_parallel and mode == "train") else None
    h = shard_act(h, "batch", seq_axis, None)
    auxs = jnp.zeros((), jnp.float32)
    new_caches = {}
    for i, spec in enumerate(cfg.block_pattern):
        c = sbc[f"l{i}"] if sbc is not None else None
        h, nc, aux = _apply_layer(
            sbp[f"l{i}"], shared, h, cfg, spec, mode=mode,
            cache=c, lengths=lengths, cross_kv=cross_kv,
        )
        new_caches[f"l{i}"] = nc
        auxs = auxs + aux
    return h, new_caches, auxs


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _embed_input(params: dict, cfg: ArchConfig, batch: dict) -> jax.Array:
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.frontend == "audio_frames":
        return shard_act(batch["frames"].astype(cdt), "batch", None, None)
    h = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cdt)
    if cfg.embed_scale:
        h = h * np.sqrt(cfg.d_model).astype(np.float32)
    return shard_act(h, "batch", None, None)


def _logits(params: dict, cfg: ArchConfig, h: jax.Array) -> jax.Array:
    h = rms_norm(h, params["final_norm"])
    logits = router.matmul(h, params["lm_head"], out_dtype=jnp.float32,
                           config=RuntimeConfig.from_arch(cfg), name="lm_head")
    logits = shard_act(logits, "batch", None, "vocab")
    if cfg.padded_vocab != cfg.vocab_size:
        logits = jnp.where(
            jnp.arange(cfg.padded_vocab) < cfg.vocab_size, logits,
            jnp.float32(-1e30),
        )
    return logits


def forward_train(params: dict, cfg: ArchConfig, batch: dict):
    """-> (logits (B,S,V) fp32, aux loss scalar)."""
    h = _embed_input(params, cfg, batch)
    cross_kv = batch.get("vision")
    shared = params.get("shared")
    aux_total = jnp.zeros((), jnp.float32)

    for i, spec in enumerate(cfg.head_pattern):
        h, _, aux = _apply_layer(params[f"pre{i}"], shared, h, cfg, spec,
                                 mode="train", cross_kv=cross_kv)
        aux_total += aux

    def body(carry, sbp):
        h, aux = carry
        h2, _, aux2 = _apply_superblock(sbp, None, shared, h, cfg, mode="train",
                                        lengths=None, cross_kv=cross_kv)
        return (h2, aux + aux2), None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        (h, aux_total), _ = lax.scan(body, (h, aux_total), params["blocks"])
    else:  # unrolled (HLO cost-analysis mode: while-loop bodies count once)
        for i in range(cfg.num_superblocks):
            sbp = jax.tree.map(lambda x: x[i], params["blocks"])
            (h, aux_total), _ = body((h, aux_total), sbp)

    for i, spec in enumerate(cfg.tail_pattern):
        h, _, aux = _apply_layer(params[f"tail{i}"], shared, h, cfg, spec,
                                 mode="train", cross_kv=cross_kv)
        aux_total += aux
    return _logits(params, cfg, h), aux_total


def loss_fn(params: dict, cfg: ArchConfig, batch: dict):
    logits, aux = forward_train(params, cfg, batch)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = (lse - gold).mean()
    loss = ce + cfg.router_aux_weight * aux
    return loss, {"ce": ce, "aux": aux, "loss": loss}


def _forward_cached(params: dict, cfg: ArchConfig, batch: dict, cache: dict, mode: str):
    h = _embed_input(params, cfg, batch)
    cross_kv = batch.get("vision")
    shared = params.get("shared")
    lengths = cache["lengths"]
    new_cache: dict = {"blocks": None, "lengths": None}

    for i, spec in enumerate(cfg.head_pattern):
        h, nc, _ = _apply_layer(params[f"pre{i}"], shared, h, cfg, spec, mode=mode,
                                cache=cache[f"pre{i}"], lengths=lengths, cross_kv=cross_kv)
        new_cache[f"pre{i}"] = nc

    def body(h, xs):
        sbp, sbc = xs
        h2, ncs, _ = _apply_superblock(sbp, sbc, shared, h, cfg, mode=mode,
                                       lengths=lengths, cross_kv=cross_kv)
        return h2, ncs

    if cfg.scan_layers:
        h, new_blocks = lax.scan(body, h, (params["blocks"], cache["blocks"]))
    else:
        ncs_list = []
        for i in range(cfg.num_superblocks):
            xs_i = jax.tree.map(lambda x: x[i], (params["blocks"], cache["blocks"]))
            h, ncs = body(h, xs_i)
            ncs_list.append(ncs)
        new_blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs_list)
    new_cache["blocks"] = new_blocks

    for i, spec in enumerate(cfg.tail_pattern):
        h, nc, _ = _apply_layer(params[f"tail{i}"], shared, h, cfg, spec, mode=mode,
                                cache=cache[f"tail{i}"], lengths=lengths, cross_kv=cross_kv)
        new_cache[f"tail{i}"] = nc

    s_new = h.shape[1]
    new_cache["lengths"] = lengths + s_new
    logits = _logits(params, cfg, h[:, -1:, :])  # only the last position's logits
    return logits, new_cache


def prefill(params: dict, cfg: ArchConfig, batch: dict, cache: dict):
    """Fill the cache from a prompt batch; returns (last-token logits, cache)."""
    return _forward_cached(params, cfg, batch, cache, "prefill")


def decode_step(params: dict, cfg: ArchConfig, batch: dict, cache: dict):
    """One decode step: batch["tokens"] is (B, 1)."""
    return _forward_cached(params, cfg, batch, cache, "decode")


# ---------------------------------------------------------------------------
# Model facade
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LM:
    cfg: ArchConfig

    def specs(self) -> dict:
        return model_specs(self.cfg)

    def init(self, key: jax.Array) -> dict:
        return pspec.init_params(self.specs(), key)

    def abstract_params(self) -> dict:
        return pspec.abstract_params(self.specs())

    def logical_axes(self) -> dict:
        return pspec.logical_axes(self.specs())

    def init_cache(self, batch: int, cache_len: int) -> dict:
        return init_cache(self.cfg, batch, cache_len)

    def loss(self, params, batch):
        return loss_fn(params, self.cfg, batch)

    def forward(self, params, batch):
        return forward_train(params, self.cfg, batch)

    def prefill(self, params, batch, cache):
        return prefill(params, self.cfg, batch, cache)

    def decode_step(self, params, batch, cache):
        return decode_step(params, self.cfg, batch, cache)
