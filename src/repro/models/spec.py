"""Declarative parameter specs: one source of truth for shapes, init and
logical sharding axes.

A model's parameters are described as a nested dict of :class:`ParamSpec`.
From the same spec tree we derive:
  * ``init_params``      — materialized arrays (jax.random)
  * ``logical_axes``     — pytree of logical-axis-name tuples (for sharding)
  * ``abstract_params``  — ShapeDtypeStructs (for dry-run, no allocation)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.util import fold_in_str


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]  # logical axis name per dim (None = replicated)
    init: str = "normal"  # normal|zeros|ones|small_normal|mamba_dt|mamba_alog
    scale: float = 1.0
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _materialize(spec: ParamSpec, key: jax.Array) -> jax.Array:
    dtype = jnp.dtype(spec.dtype)
    shape = spec.shape
    if spec.init == "zeros":
        return jnp.zeros(shape, dtype)
    if spec.init == "ones":
        return jnp.ones(shape, dtype)
    if spec.init == "normal":
        # fan-in scaled normal
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        std = spec.scale / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)
    if spec.init == "small_normal":
        return (jax.random.normal(key, shape, jnp.float32) * 0.02 * spec.scale).astype(dtype)
    if spec.init == "mamba_dt":
        # dt bias init: softplus^-1 of uniform in [1e-3, 1e-1]
        u = jax.random.uniform(key, shape, jnp.float32, 1e-3, 1e-1)
        return jnp.log(jnp.expm1(u)).astype(dtype)
    if spec.init == "mamba_alog":
        # A_log init: log of uniform [1, 16]
        u = jax.random.uniform(key, shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    raise ValueError(f"unknown init {spec.init}")


def is_spec_leaf(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs: Any, key: jax.Array) -> Any:
    """Materialize a spec tree into arrays, deterministically keyed by path."""
    # jax.tree.flatten_with_path only exists in newer JAX; the pinned version
    # exposes it via jax.tree_util.
    flat, treedef = jax.tree_util.tree_flatten_with_path(specs, is_leaf=is_spec_leaf)
    leaves = []
    for path, spec in flat:
        pkey = fold_in_str(key, jax.tree_util.keystr(path))
        leaves.append(_materialize(spec, pkey))
    return jax.tree.unflatten(treedef, leaves)


def logical_axes(specs: Any) -> Any:
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec_leaf)


def abstract_params(specs: Any) -> Any:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)), specs, is_leaf=is_spec_leaf
    )


def stack_specs(specs: Any, n: int, axis_name: Optional[str] = "layers") -> Any:
    """Add a leading stacking dim (for scan-over-superblocks) to every spec."""

    def stack_one(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.init, s.scale, s.dtype)

    return jax.tree.map(stack_one, specs, is_leaf=is_spec_leaf)
