"""Transformer-family layers: norms, RoPE, attention (blockwise train path,
cached decode path, sliding-window ring caches, cross-attention), SwiGLU MLP,
and capacity-based MoE with expert parallelism.

Every matmul dispatches through the Octopus router (repro.core.router), making
the paper's heterogeneous placement a global property of the framework.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.common.util import ceil_div
from repro.configs.base import ArchConfig
from repro.core import router
from repro.distributed.act import shard_act
from repro.models.spec import ParamSpec
from repro.runtime import RuntimeConfig

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms & RoPE
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
    return out.astype(x.dtype)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) rotary over D; positions: (..., S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention: parameter specs
# ---------------------------------------------------------------------------

def attn_specs(cfg: ArchConfig, *, cross: bool = False) -> dict:
    dt = cfg.param_dtype
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    specs = {
        "ln": ParamSpec((d,), ("embed",), "zeros", dtype=dt),
        "wq": ParamSpec((d, qd), ("embed", "heads"), "normal", dtype=dt),
        "wk": ParamSpec((d, kvd), ("embed", "kv_heads"), "normal", dtype=dt),
        "wv": ParamSpec((d, kvd), ("embed", "kv_heads"), "normal", dtype=dt),
        "wo": ParamSpec((qd, d), ("heads", "embed"), "normal", dtype=dt),
    }
    if cfg.use_qk_norm:
        specs["q_norm"] = ParamSpec((cfg.head_dim,), (None,), "zeros", dtype=dt)
        specs["k_norm"] = ParamSpec((cfg.head_dim,), (None,), "zeros", dtype=dt)
    if cross:
        specs["ln_kv"] = ParamSpec((d,), ("embed",), "zeros", dtype=dt)
    return specs


# ---------------------------------------------------------------------------
# Attention: training / prefill path (blockwise, online softmax)
# ---------------------------------------------------------------------------

def _naive_attention(q, k, v, *, kind: str, window: int, q_offset=0, kv_len=None):
    """q: (B,S,Hkv,G,D); k/v: (B,Sk,Hkv,D).  Materializes scores; small S only."""
    b, s, hkv, g, dh = q.shape
    sk = k.shape[1]
    scale = 1.0 / np.sqrt(dh)
    s_ = jnp.einsum(
        "bqhgd,bkhd->bhgqk", q.astype(jnp.float32) * scale, k.astype(jnp.float32)
    )
    qpos = q_offset + jnp.arange(s)[:, None]
    kpos = jnp.arange(sk)[None, :]
    valid = jnp.ones((s, sk), bool)
    if kv_len is not None:
        valid &= kpos < kv_len
    if kind == "causal":
        valid &= qpos >= kpos
    elif kind == "local":
        valid &= (qpos >= kpos) & (qpos - kpos < window)
    s_ = jnp.where(valid[None, None, None], s_, NEG_INF)
    m = s_.max(axis=-1, keepdims=True)
    p = jnp.where(valid[None, None, None], jnp.exp(s_ - m), 0.0)
    l = p.sum(axis=-1, keepdims=True)
    l = jnp.where(l == 0, 1.0, l)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p / l, v.astype(jnp.float32))
    return out.astype(q.dtype)


def _blockwise_attention(q, k, v, *, kind: str, window: int, chunk_q: int,
                         chunk_kv: int, unroll: bool = False,
                         av_dtype=jnp.float32):
    """Flash-style blockwise attention in pure jnp: all q chunks vectorized,
    lax.scan over kv chunks carrying (m, l, acc).  Memory O(S * chunk_kv)."""
    b, s, hkv, g, dh = q.shape
    sk = k.shape[1]
    cq = min(chunk_q, s)
    ck = min(chunk_kv, sk)
    nq, nk = ceil_div(s, cq), ceil_div(sk, ck)
    sp, skp = nq * cq, nk * ck
    if sp != s:
        q = jnp.pad(q, ((0, 0), (0, sp - s), (0, 0), (0, 0), (0, 0)))
    if skp != sk:
        k = jnp.pad(k, ((0, 0), (0, skp - sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skp - sk), (0, 0), (0, 0)))
    scale = 1.0 / np.sqrt(dh)
    qc = q.reshape(b, nq, cq, hkv, g, dh).astype(jnp.float32) * scale
    kc = k.reshape(b, nk, ck, hkv, dh)
    vc = v.reshape(b, nk, ck, hkv, dh)
    qpos = (jnp.arange(nq)[:, None] * cq + jnp.arange(cq)[None, :])  # (nq, cq)

    def step(carry, kv_j):
        m_prev, l_prev, acc = carry
        kj, vj, j = kv_j
        s_ = jnp.einsum("bnqhgd,bkhd->bnhgqk", qc, kj.astype(jnp.float32))
        kpos = j * ck + jnp.arange(ck)  # (ck,)
        valid = (kpos[None, None] < sk) & jnp.ones((nq, cq, ck), bool)
        if kind == "causal":
            valid &= qpos[:, :, None] >= kpos[None, None, :]
        elif kind == "local":
            dpos = qpos[:, :, None] - kpos[None, None, :]
            valid &= (dpos >= 0) & (dpos < window)
        s_ = jnp.where(valid[None, :, None, None], s_, NEG_INF)
        m_new = jnp.maximum(m_prev, s_.max(axis=-1))
        p = jnp.where(valid[None, :, None, None], jnp.exp(s_ - m_new[..., None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bnhgqk,bkhd->bnhgqd", p.astype(av_dtype), vj.astype(av_dtype),
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc), None

    m0 = shard_act(jnp.full((b, nq, hkv, g, cq), NEG_INF, jnp.float32),
                   "batch", None, "heads", None, None)
    l0 = shard_act(jnp.zeros((b, nq, hkv, g, cq), jnp.float32),
                   "batch", None, "heads", None, None)
    a0 = shard_act(jnp.zeros((b, nq, hkv, g, cq, dh), jnp.float32),
                   "batch", None, "heads", None, None, None)
    (m, l, acc), _ = lax.scan(
        step, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.arange(nk)),
        unroll=True if unroll else 1,
    )
    l = jnp.where(l == 0, 1.0, l)
    out = (acc / l[..., None]).astype(q.dtype)  # (b, nq, hkv, g, cq, dh)
    out = jnp.moveaxis(out, (1, 4), (1, 2)).reshape(b, sp, hkv, g, dh)
    return out[:, :s]


def attention_core(
    q: jax.Array,  # (B, S, Hq, D)
    k: jax.Array,  # (B, Sk, Hkv, D)
    v: jax.Array,
    *,
    kind: str,  # causal|local|full
    cfg: Optional[ArchConfig] = None,  # pulls window/use_pallas/impl/unroll/av_dtype
    window: int = 0,
    chunk_q: int = 512,
    chunk_kv: int = 512,
    use_pallas: bool = False,
    impl: str = "auto",  # auto|naive|blockwise
    unroll: bool = False,
    av_dtype="float32",
) -> jax.Array:
    if cfg is not None:
        window, use_pallas, impl = cfg.window_size, cfg.use_pallas, cfg.attn_impl
        unroll, av_dtype = cfg.inner_unroll, cfg.attn_av_dtype
    b, s, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    if use_pallas:
        from repro.kernels.flash_attention import flash_attention

        mask = {"causal": "causal", "local": "local", "full": "full"}[kind]
        out = flash_attention(
            jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1),
            mask=mask, window=window,
        )
        return jnp.moveaxis(out, 1, 2)
    # For TP cleanliness, expand KV heads to the full head count (the repeated
    # copies shard over the model axis together with q heads).
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    k = shard_act(k, "batch", None, "heads", None)
    v = shard_act(v, "batch", None, "heads", None)
    qg = q.reshape(b, s, hq, 1, dh)
    if impl == "naive" or (impl == "auto" and s * k.shape[1] <= (1 << 20)):
        out = _naive_attention(qg, k, v, kind=kind, window=window)
    else:
        out = _blockwise_attention(qg, k, v, kind=kind, window=window,
                                   chunk_q=chunk_q, chunk_kv=chunk_kv,
                                   unroll=unroll, av_dtype=jnp.dtype(av_dtype))
    return out.reshape(b, s, hq, dh)


# ---------------------------------------------------------------------------
# Attention: cached decode path
# ---------------------------------------------------------------------------

class AttnCache(NamedTuple):
    k: jax.Array  # (B, C, Hkv, D) -- C = full length (global) or window (local ring)
    v: jax.Array
    pos: jax.Array  # (B, C) int32 absolute position stored in each slot (-1 = empty)


def init_attn_cache(cfg: ArchConfig, batch: int, cache_len: int, *, kind: str,
                    dtype=jnp.bfloat16) -> AttnCache:
    c = min(cache_len, cfg.window_size) if kind == "local" and cfg.window_size else cache_len
    return AttnCache(
        k=jnp.zeros((batch, c, cfg.num_kv_heads, cfg.head_dim), dtype),
        v=jnp.zeros((batch, c, cfg.num_kv_heads, cfg.head_dim), dtype),
        pos=jnp.full((batch, c), -1, jnp.int32),
    )


def cache_write(cache: AttnCache, k_new: jax.Array, v_new: jax.Array,
                lengths: jax.Array, *, kind: str, window: int) -> AttnCache:
    """Write S_new tokens at per-sample positions lengths..lengths+S_new-1.
    Local caches are ring buffers indexed by absolute position % window."""
    b, s_new = k_new.shape[0], k_new.shape[1]
    cap = cache.k.shape[1]
    abs_pos = lengths[:, None] + jnp.arange(s_new)[None, :]  # (B, S_new)
    idx = abs_pos % cap if kind == "local" else jnp.minimum(abs_pos, cap - 1)
    bidx = jnp.arange(b)[:, None].repeat(s_new, axis=1)
    return AttnCache(
        k=cache.k.at[bidx, idx].set(k_new.astype(cache.k.dtype)),
        v=cache.v.at[bidx, idx].set(v_new.astype(cache.v.dtype)),
        pos=cache.pos.at[bidx, idx].set(abs_pos),
    )


def attention_decode(
    q: jax.Array,  # (B, S_new, Hq, D)  (S_new typically 1)
    cache: AttnCache,
    lengths: jax.Array,  # (B,) length BEFORE this step's tokens
    *,
    kind: str,
    window: int = 0,
) -> jax.Array:
    b, sn, hq, dh = q.shape
    hkv = cache.k.shape[2]
    g = hq // hkv
    scale = 1.0 / np.sqrt(dh)
    qg = q.reshape(b, sn, hkv, g, dh).astype(jnp.float32) * scale
    s_ = jnp.einsum("bqhgd,bkhd->bhgqk", qg, cache.k.astype(jnp.float32))
    qpos = lengths[:, None] + jnp.arange(sn)[None, :]  # (B, S_new) absolute
    kpos = cache.pos  # (B, C) absolute (-1 empty)
    valid = (kpos[:, None, :] >= 0) & (kpos[:, None, :] <= qpos[:, :, None])
    if kind == "local":
        valid &= (qpos[:, :, None] - kpos[:, None, :]) < window
    s_ = jnp.where(valid[:, None, None, :, :], s_, NEG_INF)
    m = s_.max(axis=-1, keepdims=True)
    p = jnp.where(valid[:, None, None, :, :], jnp.exp(s_ - m), 0.0)
    l = p.sum(axis=-1, keepdims=True)
    l = jnp.where(l == 0, 1.0, l)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p / l, cache.v.astype(jnp.float32))
    return out.astype(q.dtype).reshape(b, sn, hq, dh)


# ---------------------------------------------------------------------------
# Attention: full layer apply
# ---------------------------------------------------------------------------

def _theta_for(cfg: ArchConfig, kind: str) -> float:
    return cfg.rope_theta_local if kind == "local" else cfg.rope_theta


def attn_apply(
    p: dict,
    x: jax.Array,  # (B, S, D)
    cfg: ArchConfig,
    *,
    kind: str,  # causal|local|full|cross
    positions: Optional[jax.Array] = None,  # (B, S)
    cross_kv: Optional[jax.Array] = None,  # (B, T, D) modality embeddings
    cache: Optional[AttnCache] = None,
    lengths: Optional[jax.Array] = None,
    mode: str = "train",  # train | prefill | decode
) -> tuple[jax.Array, Optional[AttnCache]]:
    b, s, d = x.shape
    # Projections stay on the dot path even under cfg.use_pallas: the Pallas
    # budget of this layer goes to the flash-attention kernel, not the QKV/O
    # matmuls (same split as the pre-runtime code).
    mm = functools.partial(router.matmul, out_dtype=x.dtype,
                           config=RuntimeConfig.from_arch(cfg, use_pallas=False))
    h = rms_norm(x, p["ln"])
    q = mm(h, p["wq"]).reshape(b, s, cfg.num_heads, cfg.head_dim)
    q = shard_act(q, "batch", None, "heads", None)

    if kind == "cross":
        if mode == "decode":
            assert cache is not None  # image kv precomputed at prefill
            k, v, new_cache = cache.k, cache.v, cache
        else:
            kvsrc = rms_norm(cross_kv, p["ln_kv"])
            t = kvsrc.shape[1]
            k = mm(kvsrc, p["wk"]).reshape(b, t, cfg.num_kv_heads, cfg.head_dim)
            v = mm(kvsrc, p["wv"]).reshape(b, t, cfg.num_kv_heads, cfg.head_dim)
            new_cache = AttnCache(k=k, v=v, pos=jnp.tile(jnp.arange(t)[None], (b, 1)))
        if cfg.use_qk_norm:
            q = rms_norm(q, p["q_norm"])
            k = rms_norm(k, p["k_norm"]) if mode != "decode" else k
        out = attention_core(q, k, v, kind="full", cfg=cfg)
        out = mm(out.reshape(b, s, cfg.q_dim), p["wo"])
        return x + out, (new_cache if mode != "train" else None)

    k = mm(h, p["wk"]).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = mm(h, p["wv"]).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    if cfg.use_qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if positions is None:
        base = jnp.zeros((b,), jnp.int32) if lengths is None else lengths
        positions = base[:, None] + jnp.arange(s)[None, :]
    theta = _theta_for(cfg, kind)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)

    attn_kind = {"causal": "causal", "local": "local", "full": "full"}[
        "full" if (kind == "causal" and not cfg.causal) else kind
    ]
    new_cache = None
    if mode == "train":
        out = attention_core(q, k, v, kind=attn_kind, cfg=cfg)
    elif mode == "prefill":
        assert cache is not None and lengths is not None
        new_cache = cache_write(cache, k, v, lengths, kind=attn_kind, window=cfg.window_size)
        out = attention_core(q, k, v, kind=attn_kind, cfg=cfg)
    else:  # decode
        assert cache is not None and lengths is not None
        new_cache = cache_write(cache, k, v, lengths, kind=attn_kind, window=cfg.window_size)
        out = attention_decode(q, new_cache, lengths, kind=attn_kind, window=cfg.window_size)
    out = mm(out.reshape(b, s, cfg.q_dim), p["wo"])
    return x + out, new_cache


# ---------------------------------------------------------------------------
# Dense (SwiGLU) MLP
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ArchConfig, d_ff: Optional[int] = None) -> dict:
    dt = cfg.param_dtype
    d, f = cfg.d_model, d_ff or cfg.d_ff
    specs = {
        "ln": ParamSpec((d,), ("embed",), "zeros", dtype=dt),
        "wi_up": ParamSpec((d, f), ("embed", "mlp"), "normal", dtype=dt),
        "wo": ParamSpec((f, d), ("mlp", "embed"), "normal", dtype=dt),
    }
    if cfg.mlp_gated:
        specs["wi_gate"] = ParamSpec((d, f), ("embed", "mlp"), "normal", dtype=dt)
    return specs


def mlp_apply(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    mm = functools.partial(router.matmul, out_dtype=x.dtype,
                           config=RuntimeConfig.from_arch(cfg))
    h = rms_norm(x, p["ln"])
    if cfg.mlp_gated:
        gate = shard_act(mm(h, p["wi_gate"], activation="silu"), "batch", None, "mlp")
        up = shard_act(mm(h, p["wi_up"]), "batch", None, "mlp")
        return x + mm(gate * up, p["wo"])
    up = shard_act(mm(h, p["wi_up"], activation="gelu"), "batch", None, "mlp")
    return x + mm(up, p["wo"])


# ---------------------------------------------------------------------------
# Mixture of Experts (capacity-based dispatch, EP-shardable)
# ---------------------------------------------------------------------------

def moe_specs(cfg: ArchConfig) -> dict:
    dt = cfg.param_dtype
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    specs = {
        "ln": ParamSpec((d,), ("embed",), "zeros", dtype=dt),
        "router": ParamSpec((d, e), ("embed", None), "small_normal", dtype="float32"),
        "w_gate": ParamSpec((e, d, f), ("expert", "embed", "mlp"), "normal", dtype=dt),
        "w_up": ParamSpec((e, d, f), ("expert", "embed", "mlp"), "normal", dtype=dt),
        "w_down": ParamSpec((e, f, d), ("expert", "mlp", "embed"), "normal", dtype=dt),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        specs["sh_gate"] = ParamSpec((d, fs), ("embed", "mlp"), "normal", dtype=dt)
        specs["sh_up"] = ParamSpec((d, fs), ("embed", "mlp"), "normal", dtype=dt)
        specs["sh_down"] = ParamSpec((fs, d), ("mlp", "embed"), "normal", dtype=dt)
    return specs


def moe_capacity(tokens_per_group: int, cfg: ArchConfig) -> int:
    c = int(np.ceil(tokens_per_group * cfg.experts_per_token / cfg.num_experts
                    * cfg.capacity_factor))
    return max(c, 1)


def _dispatch_indices(eidx: jax.Array, e: int, cap: int):
    """eidx: (TK,) expert id per routing entry -> (slot (TK,), keep (TK,)).
    Sort-based: position within the expert's group, capped at capacity."""
    tk = eidx.shape[0]
    order = jnp.argsort(eidx, stable=True)
    sorted_e = eidx[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(e))  # (E,)
    pos_in_e = jnp.arange(tk) - starts[sorted_e]
    keep_sorted = pos_in_e < cap
    slot_sorted = jnp.where(keep_sorted, sorted_e * cap + pos_in_e, e * cap)
    inv = jnp.argsort(order, stable=True)
    return slot_sorted[inv], keep_sorted[inv]


def moe_apply(p: dict, x: jax.Array, cfg: ArchConfig,
              num_groups: Optional[int] = None) -> tuple[jax.Array, jax.Array]:
    """Returns (output, load-balance aux loss)."""
    b, s, d = x.shape
    e, k_top = cfg.num_experts, cfg.experts_per_token
    g = num_groups if num_groups is not None else (b if s > 1 else max(1, min(b, 8)))
    assert (b * s) % g == 0, (b, s, g)
    t = (b * s) // g
    cap = moe_capacity(t, cfg)
    h = rms_norm(x, p["ln"])
    hg = h.reshape(g, t, d)
    logits = jnp.einsum("gtd,de->gte", hg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, top_idx = lax.top_k(probs, k_top)  # (G, T, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance aux (Switch-style); vmap'd scatter (see dispatch note below)
    density = jax.vmap(
        lambda idx: jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    )(top_idx) / (t * k_top)
    aux = e * jnp.mean(jnp.sum(density * probs.mean(axis=1), axis=-1))

    eidx = top_idx.reshape(g, t * k_top)
    slot, keep = jax.vmap(functools.partial(_dispatch_indices, e=e, cap=cap))(eidx)
    tok = jnp.arange(t * k_top) // k_top  # (TK,) token of each entry

    # NOTE: every gather/scatter below is vmap'd over the group axis — batched
    # (operand_batching_dims) indexing is what GSPMD can partition; explicit
    # arange-indexing makes the partitioner replicate the full dispatch buffer
    # on every device (hundreds of GiB for kimi-k2).
    def _dispatch_one(hg_g, slot_g, keep_g):
        src = hg_g[tok] * keep_g[:, None].astype(hg_g.dtype)  # (TK, D)
        buf = jnp.zeros((e * cap + 1, d), hg_g.dtype).at[slot_g].set(src, mode="drop")
        return buf[: e * cap]

    disp = jax.vmap(_dispatch_one)(hg, slot, keep).reshape(g, e, cap, d)
    # EP dispatch boundary: groups on the pure-DP axes, experts on the model
    # axis (an all-to-all-shaped reshard under the moe_dp_attention layout)
    disp = shard_act(disp, "batch_dp", "expert", None, None)

    gate = shard_act(jnp.einsum("gecd,edf->gecf", disp, p["w_gate"]),
                     "batch_dp", "expert", None, None)
    gate = gate * jax.nn.sigmoid(gate)  # silu
    up = shard_act(jnp.einsum("gecd,edf->gecf", disp, p["w_up"]),
                   "batch_dp", "expert", None, None)
    out_e = jnp.einsum("gecf,efd->gecd", (gate * up).astype(hg.dtype), p["w_down"])
    out_e = shard_act(out_e, "batch_dp", "expert", None, None)

    cdt = jnp.dtype(cfg.moe_combine_dtype)
    weights = (gate_vals.reshape(g, t * k_top) * keep.astype(jnp.float32)).astype(cdt)

    def _combine_one(out_g, slot_g, w_g):
        flat = jnp.concatenate([out_g.reshape(e * cap, d),
                                jnp.zeros((1, d), out_g.dtype)], axis=0)
        gathered = flat[slot_g].astype(cdt) * w_g[:, None]  # (TK, D)
        return jnp.zeros((t, d), cdt).at[tok].add(gathered)

    y = jax.vmap(_combine_one)(out_e, slot, weights)
    y = shard_act(y, "batch", None, None).astype(x.dtype)

    if cfg.num_shared_experts:
        mm = functools.partial(router.matmul, out_dtype=x.dtype,
                               config=RuntimeConfig.from_arch(cfg))
        sg = mm(hg, p["sh_gate"], activation="silu")
        su = mm(hg, p["sh_up"])
        y = y + mm(sg * su, p["sh_down"])

    return x + y.reshape(b, s, d), aux
