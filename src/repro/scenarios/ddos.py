"""DDoS / anomaly-scoring scenario: FlowEngine scores thresholded into deny
actions that feed back into the switch-facing rule table, with host-side
hysteresis so flapping flows don't thrash the table.

On-device, the pipeline runs an :class:`~repro.core.decisions.AnomalyHead`:
every drained flow gets a float32 anomaly score (the malicious class's
softmax probability) surfaced as ``PipelineStepOutput.flow_scores``, and
scores at or above ``deny_on`` emit an immediate deny action.

Host-side, this controller adds the state the stateless head cannot keep:

  * **hysteresis** — a flow enters the denied set at ``score >= deny_on``
    but leaves it only at ``score <= deny_off`` (``deny_off < deny_on``).
    Scores wandering inside the band cause no rule-table transitions; the
    harness property-tests ``churn <= churn_raw`` against a shadow
    bare-threshold controller run on the same emission stream.
  * **re-assertion** — the pipeline's packet-granularity rule updates
    overwrite a flow's action with the packet head's verdict every time the
    flow sends another packet, so after each dispatch (step or ``scan_len``
    chunk) the controller re-asserts ``deny`` for every denied flow.  That
    bounds the window in which a denied flow's packets are not marked deny
    in the table to one dispatch — at most ``scan_len`` microbatches, the
    same lag the chunked feedback already has (property-tested).
"""
from __future__ import annotations

import itertools
from typing import Any, Iterable, Optional

import jax
import numpy as np

from repro.core import decisions
from repro.models import paper_models
from repro.serving import OctopusPipeline, PipelineConfig, ShardedOctopusPipeline

_DENY = decisions.ACTIONS.index("deny")


class HysteresisController:
    """Host-side denied-set with a hysteresis band, plus a shadow
    bare-threshold controller run on the same emission stream.

    A flow enters ``denied`` at ``score >= deny_on`` and leaves only at
    ``score <= deny_off`` (strict ``deny_off < deny_on``); every transition
    is a rule-table write, counted in ``churn``.  The shadow flips on every
    threshold crossing and counts ``churn_raw`` — with a strict band,
    ``churn <= churn_raw`` always holds (property-tested)."""

    def __init__(self, deny_on: float, deny_off: float):
        if not 0.0 <= deny_off < deny_on <= 1.0:
            raise ValueError(f"need 0 <= deny_off < deny_on <= 1, got "
                             f"deny_off={deny_off} deny_on={deny_on}")
        self.deny_on, self.deny_off = float(deny_on), float(deny_off)
        self.denied: set[int] = set()  # hysteresis state
        self._raw_denied: set[int] = set()  # shadow bare-threshold state
        self.churn = 0  # denied-set transitions (what hits the rule table)
        self.churn_raw = 0  # shadow transitions a bare threshold would make
        self.emissions: list[tuple[int, float]] = []  # (fid, score) history

    def observe(self, fid: int, score: float) -> None:
        self.emissions.append((fid, score))
        raw = score >= self.deny_on  # shadow: flips on every crossing
        if raw != (fid in self._raw_denied):
            self.churn_raw += 1
            (self._raw_denied.add if raw else self._raw_denied.discard)(fid)
        if fid in self.denied:
            if score <= self.deny_off:  # release only below the band
                self.denied.discard(fid)
                self.churn += 1
        elif score >= self.deny_on:
            self.denied.add(fid)
            self.churn += 1


class DDoSScenario:
    """Anomaly-score pipeline + hysteresis deny controller."""

    def __init__(self, *, deny_on: float = 0.6, deny_off: float = 0.4,
                 malicious_class: int = 0, num_shards: int = 0,
                 lane_batch: Optional[int] = None, pkt_params: Any = None,
                 flow_params: Any = None, config: Any = None, **cfg_kwargs):
        if "flow_head" in cfg_kwargs:
            raise ValueError("flow_head is fixed by the scenario "
                             "(AnomalyHead; tune deny_on/malicious_class)")
        self.ctl = HysteresisController(deny_on, deny_off)
        self.cfg = PipelineConfig(flow_head=decisions.AnomalyHead(
            deny_threshold=deny_on, malicious_class=malicious_class),
            **cfg_kwargs)
        if pkt_params is None:
            pkt_params = paper_models.init_paper_model(
                "mlp", jax.random.PRNGKey(0))
        if flow_params is None:
            flow_params = paper_models.init_paper_model(
                self.cfg.flow_model, jax.random.PRNGKey(1))
        if num_shards:
            self.pipe = ShardedOctopusPipeline(
                pkt_params, flow_params, self.cfg, num_shards=num_shards,
                lane_batch=lane_batch, config=config)
        else:
            self.pipe = OctopusPipeline(pkt_params, flow_params, self.cfg,
                                        config=config)

    # ----------------------------------------------------- controller facade
    @property
    def denied(self) -> set[int]:
        return self.ctl.denied

    @property
    def churn(self) -> int:
        return self.ctl.churn

    @property
    def churn_raw(self) -> int:
        return self.ctl.churn_raw

    @property
    def emissions(self) -> list[tuple[int, float]]:
        return self.ctl.emissions

    def _absorb(self, out) -> None:
        """Fold one dispatch's emissions (single step or stacked chunk) into
        the controller, in step order."""
        mask = np.asarray(out.drained.mask)
        fids = np.asarray(out.drained.tuple_id)
        scores = np.asarray(out.flow_scores)
        if mask.ndim == 1:
            mask, fids, scores = mask[None], fids[None], scores[None]
        for j in range(mask.shape[0]):
            for fid, s in zip(fids[j][mask[j]].tolist(),
                              scores[j][mask[j]].tolist()):
                self.ctl.observe(int(fid), float(s))

    def _reassert(self) -> None:
        """Pin every denied flow's rule-table action back to deny (the
        packet-granularity feedback just overwrote it with the packet head's
        per-packet verdict)."""
        if self.denied:
            fids = np.fromiter(self.denied, np.int64, len(self.denied))
            self.pipe.rules.update(
                fids, np.full(len(fids), _DENY, np.int32))

    # ------------------------------------------------------------- host loop
    def step(self, batch):
        out = self.pipe.step(batch)
        self._absorb(out)
        self._reassert()
        return out

    def run(self, traffic: Iterable, steps: int):
        """Drive ``steps`` microbatches (chunked like ``OctopusPipeline.run``
        when ``scan_len > 1``), absorbing scores and re-asserting denies
        after every dispatch.  Returns the pipeline stats."""
        it = iter(traffic)
        L = self.cfg.scan_len
        done = 0
        while done < steps:
            chunk = list(itertools.islice(it, min(L, steps - done)))
            if not chunk:
                break
            if L > 1 and len(chunk) == L:
                out = self.pipe.step_many(chunk)
                self._absorb(out)
                self._reassert()
            else:
                if L > 1:
                    self.pipe._warm_step()
                for b in chunk:
                    self.step(b)
            done += len(chunk)
        return self.pipe.stats
