"""Use-case scenarios over the streaming pipelines (paper: the accelerator
serves many in-network DL workloads, not one).  Each scenario composes the
existing primitives — trackers, engines, rule table — through the pluggable
:class:`~repro.core.decisions.DecisionHead` layer, and each ships with a
differential or property-based harness in ``tests/test_scenarios.py``:

  * :class:`HeavyHitterScenario` — top-k per-flow byte counters, feature-only
    heads (no DL inference at all), exact against a dict-based oracle.
  * :class:`DDoSScenario` — FlowEngine anomaly scores thresholded into deny
    actions with host-side hysteresis feedback into the rule table.
  * :class:`AdversarialScenario` — flash-crowd / elephant-storm /
    hash-collision traffic (``TrafficConfig.adversarial``) driven through a
    pipeline, conservation- and bit-exactness-tested.
"""
from repro.scenarios.adversarial import AdversarialScenario, adversarial_config
from repro.scenarios.ddos import DDoSScenario, HysteresisController
from repro.scenarios.heavy_hitter import (
    HeavyHitterScenario,
    flow_counters,
    top_k_flows,
)

SCENARIOS = ("heavy_hitter", "ddos", "adversarial")

__all__ = ["AdversarialScenario", "DDoSScenario", "HeavyHitterScenario",
           "HysteresisController", "SCENARIOS", "adversarial_config",
           "flow_counters", "top_k_flows"]
