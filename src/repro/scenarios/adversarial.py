"""Adversarial-traffic scenario: drive a pipeline with the
``TrafficConfig.adversarial`` modes and measure what the attack costs.

The traffic generator owns the attack shapes (``repro.data.traffic``):

  * ``flash_crowd``      — every ``adv_period``-th batch is all fresh
                           one-packet flows (maximal establishment churn).
  * ``elephant_storm``   — every flow an elephant, every emission a maximal
                           burst (ready/drain path under line-rate pressure).
  * ``collision_attack`` — the whole population hashes into ``adv_slots``
                           tracker slots (worst-case eviction churn; the
                           segmented tracker's in-batch collision fallback
                           runs every batch), optionally pinned to shard 0
                           of ``adv_shards`` lanes so sharded exactness
                           holds while one lane absorbs the attack.

The harnesses in ``tests/test_scenarios.py`` assert the generator stays
deterministic and conservation-correct under every mode, and that
collision-attack batches remain bit-exact against the pure-Python oracle —
the attack degrades throughput, never correctness.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Union

from repro.data.traffic import (
    ADVERSARIAL_MODES,
    TrafficConfig,
    TrafficGenerator,
)

ATTACKS = tuple(m for m in ADVERSARIAL_MODES if m != "none")


def adversarial_config(mode: str, **overrides) -> TrafficConfig:
    """A :class:`TrafficConfig` with per-mode defaults that actually stress
    the mode's target path (override anything via kwargs):

      * ``collision_attack`` needs ``collision_free=False`` and a population
        larger than its slot budget;
      * ``flash_crowd`` / ``elephant_storm`` default to small tables so the
        churn is visible at test sizes."""
    if mode not in ATTACKS:
        raise ValueError(f"mode must be one of {ATTACKS}, got {mode!r}")
    base = {
        "flash_crowd": TrafficConfig(adversarial="flash_crowd",
                                     active_flows=24, table_size=256,
                                     collision_free=False),
        "elephant_storm": TrafficConfig(adversarial="elephant_storm",
                                        active_flows=16, table_size=256,
                                        burst_len=8),
        "collision_attack": TrafficConfig(adversarial="collision_attack",
                                          active_flows=12, table_size=64,
                                          adv_slots=2, collision_free=False),
    }[mode]
    return replace(base, **overrides)


class AdversarialScenario:
    """One pipeline + one adversarial generator, with a ``run`` that reports
    the sustained stats (the bench rows drive this class)."""

    def __init__(self, pipe, traffic: Union[TrafficConfig, TrafficGenerator]):
        cfg = traffic.cfg if isinstance(traffic, TrafficGenerator) else traffic
        if cfg.adversarial == "none":
            raise ValueError("AdversarialScenario needs an adversarial "
                             "TrafficConfig (adversarial != 'none')")
        self.pipe = pipe
        self.gen = (traffic if isinstance(traffic, TrafficGenerator)
                    else TrafficGenerator(traffic))

    @property
    def mode(self) -> str:
        return self.gen.cfg.adversarial

    def run(self, steps: int):
        """Drive ``steps`` microbatches through the pipeline; returns the
        pipeline's sustained :class:`~repro.serving.pipeline.PipelineStats`
        (eviction/new-flow counters show the attack's churn)."""
        return self.pipe.run(self.gen, steps=steps)
