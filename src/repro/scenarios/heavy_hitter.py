"""Heavy-hitter / top-k detection: rank live flows by accumulated bytes using
tracker state alone — the telemetry use-case family the paper serves without
ever entering the DL domain.

The pipeline runs with feature-only heads (:class:`~repro.core.decisions.PassHead`
for packets, :class:`~repro.core.decisions.TopKHead` for flows), so neither
engine dispatches any inference; the per-step cost is the tracker merge +
drain.  The top-k set is computed host-side from the *resident* flow
counters — every live flow in the hot bank(s) **and** every cold-store
resident (a heavy hitter that lost its hot slot to a collision keeps its
byte count in the cold table, so spill/promote never drops it from the
ranking).  Drained (ready) flows leave the tracker, hence the ranking —
exactly like the dict-based oracle the differential harness mirrors
(``tests/test_scenarios.py``).
"""
from __future__ import annotations

from typing import Any, Iterable, Optional

import jax
import numpy as np

from repro.core import decisions
from repro.kernels.flow_features.ops import HIST
from repro.models import paper_models
from repro.serving import OctopusPipeline, PipelineConfig, ShardedOctopusPipeline

_FLOW_SIZE = HIST["flow_size"]  # the tracker's byte-counter history lane


def _absorb(counters: dict[int, int], tuple_id, count, features) -> None:
    """Fold one table's live rows into ``counters`` (lane axes flatten —
    flows are lane-exclusive, so no key can collide across banks)."""
    tid = np.asarray(tuple_id).reshape(-1)
    cnt = np.asarray(count).reshape(-1)
    feat = np.asarray(features)
    feat = feat.reshape(-1, feat.shape[-1])
    live = cnt > 0
    for t, s in zip(tid[live].tolist(), feat[live, _FLOW_SIZE].tolist()):
        counters[int(t)] = int(s)


def flow_counters(state) -> dict[int, int]:
    """``{tuple_hash: byte count}`` for every flow resident in ``state`` —
    hot and cold levels, all lanes (works on a plain
    :class:`~repro.core.flow_tracker.TrackerState`, a
    :class:`~repro.core.cold_store.TwoLevelState`, and their sharded
    lane-stacked forms).  The scrub-live invariant guarantees a tuple is
    never live in hot and cold at once, so the dict is well-defined."""
    counters: dict[int, int] = {}
    if hasattr(state, "hot"):
        _absorb(counters, state.hot.tuple_id, state.hot.count,
                state.hot.features)
        _absorb(counters, state.cold.tuple_id, state.cold.count,
                state.cold.features)
    else:
        _absorb(counters, state.tuple_id, state.count, state.features)
    return counters


def top_k_flows(counters: dict[int, int], k: int) -> list[tuple[int, int]]:
    """The ``k`` heaviest flows as ``[(tuple_hash, bytes), ...]``, heaviest
    first.  Ties break on the smaller tuple hash — a total order, so two
    rankings over equal counters are identical lists (what the differential
    harness asserts, stronger than set equality)."""
    return sorted(counters.items(), key=lambda kv: (-kv[1], kv[0]))[:k]


class HeavyHitterScenario:
    """Drive a pipeline with feature-only heads and report per-step top-k.

    ``**cfg_kwargs`` go straight into :class:`PipelineConfig` (heads are
    fixed to :class:`~repro.core.decisions.PassHead` /
    :class:`~repro.core.decisions.TopKHead` here — that is the scenario);
    because the flow head is feature-only, ``top_n`` is free of the DL
    models' geometry — raise it so elephants stay resident longer, or keep
    the default drain threshold.  ``num_shards > 0`` runs the sharded
    pipeline (top-k then spans every lane's banks)."""

    def __init__(self, *, k: int = 8, num_shards: int = 0,
                 lane_batch: Optional[int] = None, pkt_params: Any = None,
                 flow_params: Any = None, config: Any = None, **cfg_kwargs):
        if k <= 0:
            raise ValueError(f"k must be positive, got {k}")
        for reserved in ("pkt_head", "flow_head"):
            if reserved in cfg_kwargs:
                raise ValueError(f"{reserved} is fixed by the scenario")
        self.cfg = PipelineConfig(pkt_head=decisions.PassHead(),
                                  flow_head=decisions.TopKHead(),
                                  **cfg_kwargs)
        self.k = k
        if pkt_params is None:
            pkt_params = paper_models.init_paper_model(
                "mlp", jax.random.PRNGKey(0))
        if flow_params is None:
            flow_params = paper_models.init_paper_model(
                self.cfg.flow_model, jax.random.PRNGKey(1))
        if num_shards:
            self.pipe = ShardedOctopusPipeline(
                pkt_params, flow_params, self.cfg, num_shards=num_shards,
                lane_batch=lane_batch, config=config)
        else:
            self.pipe = OctopusPipeline(pkt_params, flow_params, self.cfg,
                                        config=config)

    def step(self, batch):
        return self.pipe.step(batch)

    def counters(self) -> dict[int, int]:
        """Resident per-flow byte counters (hot + cold, all lanes)."""
        return flow_counters(self.pipe.state)

    def top_k(self) -> list[tuple[int, int]]:
        """Current top-k ``(tuple_hash, bytes)``, heaviest first."""
        return top_k_flows(self.counters(), self.k)

    def run(self, traffic: Iterable, steps: int) -> list[list[tuple[int, int]]]:
        """Drive ``steps`` microbatches and return the per-step top-k
        snapshots (pipeline stats accumulate on ``self.pipe.stats``)."""
        it = iter(traffic)
        snaps = []
        for _ in range(steps):
            self.pipe.step(next(it))
            snaps.append(self.top_k())
        return snaps
