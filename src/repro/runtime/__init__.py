"""Unified Octopus runtime: one config, one placement plan, one API.

    from repro.runtime import RuntimeConfig, octopus_runtime, RoutePlan

    with octopus_runtime(RuntimeConfig(policy="collaborative", tau=0.35)):
        y = router.matmul(x, w)                       # ambient config
    plan = RoutePlan.trace(fn, abstract_x)            # shared placement truth
    print(plan.explain())

Self-calibration (measured arype/vpe crossover, see ``repro.runtime.autotune``):

    cfg = RuntimeConfig.calibrated()                  # backend-keyed cache
    with octopus_runtime(load_calibration(path)):     # or apply an artifact
        ...
"""
from repro.runtime import platform
from repro.runtime.autotune import (
    Calibration,
    ShapeTiming,
    calibrate,
    fit_crossover,
    load_calibration,
    measure_crossover,
    save_calibration,
)
from repro.runtime.config import (
    POLICIES,
    RuntimeConfig,
    current_runtime,
    octopus_runtime,
    resolve_config,
    runtime_overrides,
)
from repro.runtime.plan import PlannedMatmul, RoutePlan
from repro.runtime.quant import QuantScales, record_scales


def __getattr__(name: str):
    # DEFAULT_RUNTIME is lazy: constructing it probes the JAX backend, which
    # must not happen as an import side effect (see repro.runtime.config).
    if name == "DEFAULT_RUNTIME":
        from repro.runtime import config

        return config.DEFAULT_RUNTIME
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
from repro.runtime.routing import (
    Route,
    RouteRecord,
    lane_scope,
    mxu_utilization,
    name_scope,
    record_routes,
    route_matmul,
    systolic_utilization,
)

__all__ = [
    "Calibration",
    "DEFAULT_RUNTIME",
    "POLICIES",
    "PlannedMatmul",
    "QuantScales",
    "Route",
    "RouteRecord",
    "RoutePlan",
    "RuntimeConfig",
    "ShapeTiming",
    "calibrate",
    "current_runtime",
    "fit_crossover",
    "lane_scope",
    "load_calibration",
    "measure_crossover",
    "mxu_utilization",
    "name_scope",
    "octopus_runtime",
    "platform",
    "record_routes",
    "record_scales",
    "resolve_config",
    "route_matmul",
    "runtime_overrides",
    "save_calibration",
    "systolic_utilization",
]
