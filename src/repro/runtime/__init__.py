"""Unified Octopus runtime: one config, one placement plan, one API.

    from repro.runtime import RuntimeConfig, octopus_runtime, RoutePlan

    with octopus_runtime(RuntimeConfig(policy="collaborative", tau=0.35)):
        y = router.matmul(x, w)                       # ambient config
    plan = RoutePlan.trace(fn, abstract_x)            # shared placement truth
    print(plan.explain())
"""
from repro.runtime.config import (
    DEFAULT_RUNTIME,
    POLICIES,
    RuntimeConfig,
    current_runtime,
    octopus_runtime,
    resolve_config,
    runtime_overrides,
)
from repro.runtime.plan import PlannedMatmul, RoutePlan
from repro.runtime.routing import (
    Route,
    RouteRecord,
    mxu_utilization,
    record_routes,
    route_matmul,
    systolic_utilization,
)

__all__ = [
    "DEFAULT_RUNTIME",
    "POLICIES",
    "PlannedMatmul",
    "Route",
    "RouteRecord",
    "RoutePlan",
    "RuntimeConfig",
    "current_runtime",
    "mxu_utilization",
    "octopus_runtime",
    "record_routes",
    "resolve_config",
    "route_matmul",
    "runtime_overrides",
    "systolic_utilization",
]
