"""Placement routing (paper §2.3, §3.2.3): the utilization model and the
per-matmul :class:`Route` decision, parameterized by :class:`RuntimeConfig`
instead of module globals.

The utilization model mirrors the paper's analysis: a (M,K)x(K,N) matmul on a
``T×T`` systolic array achieves ``util = K/⌈K⌉_T · N/⌈N⌉_T`` MAC-occupancy
(fill of the stationary tile), with an additional M-side penalty for streams
shorter than the array's fill depth.  The paper's 32x32-array example — layer 1
(10,3)x(3,32): 9.3% — is reproduced by this model (see tests).

While a :func:`record_routes` block is active every decision is appended to
the recorder — that is how :class:`repro.runtime.plan.RoutePlan` observes a
model trace without the model knowing about plans.
"""
from __future__ import annotations

from contextlib import contextmanager
from contextvars import ContextVar
from dataclasses import dataclass
from typing import Iterator, List, Optional

from repro.common.util import ceil_div
from repro.runtime.config import RuntimeConfig, current_runtime


@dataclass(frozen=True)
class Route:
    path: str  # "arype" | "vpe"
    util: float
    reason: str


@dataclass(frozen=True)
class RouteRecord:
    """One recorded placement decision (name may be auto-assigned later).

    ``quantized`` marks decisions whose execution will take the int8 engine
    path (config has ``quantize`` on and a scale entry for this name)."""

    name: Optional[str]
    m: int
    k: int
    n: int
    route: Route
    quantized: bool = False


_recorder: ContextVar[Optional[List[RouteRecord]]] = ContextVar("route_recorder", default=None)
_name_scope: ContextVar[str] = ContextVar("route_name_scope", default="")


@contextmanager
def record_routes() -> Iterator[List[RouteRecord]]:
    """Collect every :func:`route_matmul` decision made inside the block."""
    records: List[RouteRecord] = []
    token = _recorder.set(records)
    try:
        yield records
    finally:
        _recorder.reset(token)


@contextmanager
def name_scope(label: str) -> Iterator[None]:
    """Prefix recorded matmul names with ``label/`` within the block (nesting
    joins with ``/``).  Lets a composite trace — e.g. the streaming pipeline's
    packet + flow engines — keep its sub-models distinguishable inside one
    :class:`repro.runtime.plan.RoutePlan`."""
    outer = _name_scope.get()
    token = _name_scope.set(f"{outer}{label}/")
    try:
        yield
    finally:
        _name_scope.reset(token)


def current_scope() -> str:
    """The active :func:`name_scope` prefix ("" outside any scope)."""
    return _name_scope.get()


@contextmanager
def lane_scope(lane: int) -> Iterator[None]:
    """:func:`name_scope` for one serving lane (``lane<i>/``) — the sharded
    pipeline traces each lane's engines under its own scope, so
    ``RoutePlan.scoped(f"lane{i}")`` extracts any single lane's placement
    from the composite multi-lane plan."""
    with name_scope(f"lane{lane}"):
        yield


def systolic_utilization(m: int, k: int, n: int, array: int) -> float:
    """The paper's utilization definition (§3.2.3): useful MACs over
    array-slots x stream-cycles for an (m,k)x(k,n) matmul on an array x array
    systolic grid.  Reproduces the paper's 9.3% for (10,3)x(3,32) on 32x32."""
    kb, nb = ceil_div(k, array), ceil_div(n, array)
    useful = m * k * n
    slots = kb * nb * m * array * array
    return useful / slots


def mxu_utilization(m: int, k: int, n: int, tile: Optional[int] = None,
                    fill: Optional[int] = None) -> float:
    """TPU routing cost model: stationary-tile fill (K, N padding waste) plus
    the sublane granularity penalty on the streamed M dimension.

    ``tile``/``fill`` default from the *ambient* runtime (not the frozen
    class defaults, which would silently ignore an active
    ``runtime_overrides(mxu_tile=...)`` when called directly)."""
    if tile is None or fill is None:
        cfg = current_runtime()
        tile = cfg.mxu_tile if tile is None else tile
        fill = cfg.fill_depth if fill is None else fill
    fill_k = k / (ceil_div(k, tile) * tile)
    fill_n = n / (ceil_div(n, tile) * tile)
    stream = m / (ceil_div(m, fill) * fill)
    return fill_k * fill_n * stream


def route_matmul(m: int, k: int, n: int, *, config: Optional[RuntimeConfig] = None,
                 name: Optional[str] = None) -> Route:
    """Decide the engine for an (m,k)x(k,n) matmul under ``config`` (ambient
    runtime when None).  Records the decision if a plan trace is active."""
    cfg = config if config is not None else current_runtime()
    util = mxu_utilization(m, k, n, tile=cfg.mxu_tile, fill=cfg.fill_depth)
    if cfg.policy == "arype_only":
        route = Route("arype", util, "forced")
    elif cfg.policy == "vpe_only":
        route = Route("vpe", util, "forced")
    elif util < cfg.tau and m * k * n <= cfg.vpe_max_elems:
        route = Route("vpe", util, f"util {util:.3f} < {cfg.tau} and working set fits VPU path")
    else:
        route = Route("arype", util, f"util {util:.3f}")
    records = _recorder.get()
    if records is not None:
        scope = _name_scope.get()
        scoped = f"{scope}{name}" if name is not None else (scope or None)
        quantized = bool(
            cfg.quantize and cfg.quant_scales is not None
            and cfg.quant_scales.lookup(name, scope) is not None)
        records.append(RouteRecord(scoped, m, k, n, route, quantized))
    return route
