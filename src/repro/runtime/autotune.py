"""Measured arype/vpe crossover calibration (ROADMAP: self-calibrating tau).

The router's placement rule — route to VPE when MXU utilization falls below
``tau`` and the working set fits ``vpe_max_elems`` — shipped with hand-picked
constants.  This module measures the actual crossover on the running backend:

  1. :func:`measure_crossover` times both engine paths (AryPE dot vs VPE
     broadcast-multiply-reduce) over a grid of (m, k, n) shapes.
  2. :func:`fit_crossover` fits the measurements into the two routing
     thresholds: ``tau`` is the utilization decision boundary that best
     separates vpe-faster from arype-faster shapes (a 1-D decision stump over
     candidate midpoints), ``vpe_max_elems`` caps the VPE path at the largest
     working set it actually won.
  3. The result persists as a schema-versioned, backend-keyed JSON artifact
     (``~/.cache/octopus/calib-<backend>.json`` by default) that
     :func:`load_calibration` / :meth:`RuntimeConfig.calibrated` re-apply.

A :class:`Calibration` can be handed directly to ``octopus_runtime`` — it
applies itself onto the ambient config.  The artifact's platform fingerprint
travels into ``RuntimeConfig.calibration`` so plans, cycle-model reports and
benchmark JSON all record which measurement produced their thresholds.

``python -m repro.launch.calibrate`` is the CLI front end.
"""
from __future__ import annotations

import json
import os
import time
import warnings
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.runtime import platform
from repro.runtime.config import RuntimeConfig, current_runtime
from repro.runtime.quant import QuantScales
from repro.runtime.routing import mxu_utilization

SCHEMA_VERSION = 1

# Default sweep grid: spans the paper's small-network shapes (conv1-style
# skinny matmuls that belong on the VPE) through MXU-filling blocks.
_FULL_M = (8, 64, 512, 4096)
_FULL_K = (3, 16, 64, 256)
_FULL_N = (8, 32, 128, 512)
_SMOKE_M = (8, 512)
_SMOKE_K = (3, 64)
_SMOKE_N = (8, 128)


def default_grid(smoke: bool = False) -> List[Tuple[int, int, int]]:
    """The (m, k, n) sweep grid; ``smoke`` is the 8-point CI/test subset."""
    ms, ks, ns = (_SMOKE_M, _SMOKE_K, _SMOKE_N) if smoke else (_FULL_M, _FULL_K, _FULL_N)
    return [(m, k, n) for m in ms for k in ks for n in ns]


@dataclass(frozen=True)
class ShapeTiming:
    """One measured grid point: both engine paths timed for an (m,k,n) matmul."""

    m: int
    k: int
    n: int
    util: float
    us_arype: float
    us_vpe: float

    @property
    def elems(self) -> int:
        return self.m * self.k * self.n

    @property
    def vpe_wins(self) -> bool:
        return self.us_vpe < self.us_arype


@dataclass(frozen=True)
class Calibration:
    """A fitted, persistable crossover measurement for one backend.

    ``quant_scales`` optionally carries the per-layer int8 scales fitted from
    a traffic sample (``repro.launch.calibrate --quant``); older artifacts
    without the key load as None and quantized configs fall back to f32."""

    tau: float
    vpe_max_elems: int
    fingerprint: Dict[str, str]
    timings: Tuple[ShapeTiming, ...] = ()
    schema_version: int = SCHEMA_VERSION
    created_unix: float = field(default_factory=time.time)
    quant_scales: Optional[QuantScales] = None

    @property
    def backend(self) -> str:
        return self.fingerprint.get("backend", "unknown")

    @property
    def fingerprint_id(self) -> str:
        return platform.fingerprint_id(self.fingerprint)

    def apply(self, base: Optional[RuntimeConfig] = None) -> RuntimeConfig:
        """``base`` (ambient runtime when None) with the measured thresholds
        and this calibration's fingerprint stamped on."""
        cfg = base if base is not None else current_runtime()
        kw = dict(tau=self.tau, vpe_max_elems=self.vpe_max_elems,
                  calibration=self.fingerprint_id)
        if self.quant_scales is not None:
            # Scales travel with the artifact; actually *running* int8 stays
            # an explicit opt-in via RuntimeConfig.quantize.
            kw["quant_scales"] = self.quant_scales
        return cfg.replace(**kw)

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Calibration":
        timings = tuple(ShapeTiming(**t) for t in d.get("timings", ()))
        qs = d.get("quant_scales")
        return cls(tau=float(d["tau"]), vpe_max_elems=int(d["vpe_max_elems"]),
                   fingerprint=dict(d["fingerprint"]), timings=timings,
                   schema_version=int(d["schema_version"]),
                   created_unix=float(d.get("created_unix", 0.0)),
                   quant_scales=QuantScales.from_dict(qs) if qs else None)


# ---------------------------------------------------------------------------
# Measurement
# ---------------------------------------------------------------------------

def _time_call(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall seconds per call (device-blocking)."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def measure_crossover(
    shapes: Optional[Sequence[Tuple[int, int, int]]] = None,
    *,
    config: Optional[RuntimeConfig] = None,
    warmup: int = 1,
    iters: int = 5,
) -> List[ShapeTiming]:
    """Time the AryPE and VPE execution paths for every shape in the grid.

    Both paths run under ``config`` (ambient runtime when None) with the
    policy forced, so ``use_pallas``/``interpret``/``accum_dtype`` match how
    the router will actually execute on this backend.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import router

    base = config if config is not None else current_runtime()
    shapes = list(shapes) if shapes is not None else default_grid()
    timings: List[ShapeTiming] = []
    for m, k, n in shapes:
        x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
        per_path = {}
        for policy in ("arype_only", "vpe_only"):
            cfg = base.replace(policy=policy)
            fn = jax.jit(lambda a, b, cfg=cfg: router.matmul(a, b, config=cfg))
            per_path[policy] = _time_call(fn, x, w, warmup=warmup, iters=iters)
        util = mxu_utilization(m, k, n, tile=base.mxu_tile, fill=base.fill_depth)
        timings.append(ShapeTiming(m, k, n, util,
                                   us_arype=per_path["arype_only"] * 1e6,
                                   us_vpe=per_path["vpe_only"] * 1e6))
    return timings


# ---------------------------------------------------------------------------
# Fit
# ---------------------------------------------------------------------------

def _next_pow2(x: int) -> int:
    return 1 << max(x - 1, 1).bit_length()


def fit_crossover(
    timings: Sequence[ShapeTiming],
    *,
    base: Optional[RuntimeConfig] = None,
) -> Tuple[float, int]:
    """Fit measured timings into ``(tau, vpe_max_elems)``.

    ``tau`` is the utilization threshold whose rule "vpe iff util < tau"
    agrees with the most measurements (ties break toward the smaller
    threshold — prefer the throughput engine when the data is ambiguous).
    ``vpe_max_elems`` is the largest working set the VPE path actually won,
    rounded up to a power of two; with no VPE wins both fall back to the
    analytic defaults.
    """
    cfg = base if base is not None else current_runtime()
    if not timings:
        return cfg.tau, cfg.vpe_max_elems
    pts = sorted(timings, key=lambda t: t.util)
    wins = [t.vpe_wins for t in pts]
    if not any(wins):
        # VPE never pays off here: close the window below the smallest
        # observed utilization (tau must stay > 0).
        return max(pts[0].util / 2, 1e-6), cfg.vpe_max_elems
    utils = [t.util for t in pts]
    candidates = [max(utils[0] / 2, 1e-6)]
    candidates += [(a + b) / 2 for a, b in zip(utils, utils[1:]) if a < b]
    candidates.append(1.0)
    best_tau, best_score = candidates[0], -1
    for tau in candidates:
        score = sum(1 for t, w in zip(pts, wins) if (t.util < tau) == w)
        if score > best_score:
            best_tau, best_score = tau, score
    vpe_max = max(t.elems for t in pts if t.vpe_wins)
    return best_tau, _next_pow2(vpe_max)


def calibrate(
    shapes: Optional[Sequence[Tuple[int, int, int]]] = None,
    *,
    smoke: bool = False,
    config: Optional[RuntimeConfig] = None,
    warmup: int = 1,
    iters: int = 5,
) -> Calibration:
    """Measure + fit: the one-call form used by the CLI and tests."""
    base = config if config is not None else current_runtime()
    shapes = list(shapes) if shapes is not None else default_grid(smoke=smoke)
    timings = measure_crossover(shapes, config=base, warmup=warmup, iters=iters)
    tau, vpe_max_elems = fit_crossover(timings, base=base)
    return Calibration(tau=tau, vpe_max_elems=vpe_max_elems,
                       fingerprint=platform.fingerprint(), timings=tuple(timings))


# ---------------------------------------------------------------------------
# Persistence (backend-keyed, schema-versioned)
# ---------------------------------------------------------------------------

def cache_dir() -> str:
    """``$OCTOPUS_CACHE_DIR`` or ``~/.cache/octopus``."""
    return os.environ.get("OCTOPUS_CACHE_DIR",
                          os.path.join(os.path.expanduser("~"), ".cache", "octopus"))


def cache_path(backend: Optional[str] = None) -> str:
    """The backend-keyed default artifact path for this platform."""
    return os.path.join(cache_dir(), f"calib-{backend or platform.backend()}.json")


def save_calibration(calib: Calibration, path: Optional[str] = None) -> str:
    """Write the artifact (default: the backend-keyed cache path); returns it."""
    path = path or cache_path(calib.backend)
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(calib.to_dict(), f, indent=1, sort_keys=True)
    return path


def load_calibration(path: Optional[str] = None,
                     backend: Optional[str] = None) -> Optional[Calibration]:
    """Load an artifact (default: this platform's cache path).

    Returns None — always with a warning naming the reason — when the file is
    missing, unreadable, from a different schema version, or keyed to a
    different backend, so callers degrade to the analytic defaults instead of
    silently applying a stale or foreign measurement.
    """
    path = path or cache_path(backend)
    if not os.path.exists(path):
        warnings.warn(f"no calibration artifact at {path}; using analytic "
                      "routing defaults (run `python -m repro.launch.calibrate`)",
                      stacklevel=2)
        return None
    try:
        with open(path) as f:
            raw = json.load(f)
    except (OSError, ValueError) as e:
        warnings.warn(f"unreadable calibration artifact {path} ({e}); using "
                      "analytic routing defaults", stacklevel=2)
        return None
    version = raw.get("schema_version")
    if version != SCHEMA_VERSION:
        warnings.warn(f"calibration artifact {path} has schema_version="
                      f"{version!r}, expected {SCHEMA_VERSION}; re-run "
                      "`python -m repro.launch.calibrate` (using analytic "
                      "routing defaults)", stacklevel=2)
        return None
    want = backend or platform.backend()
    try:
        calib = Calibration.from_dict(raw)
    except (KeyError, TypeError, ValueError) as e:
        warnings.warn(f"malformed calibration artifact {path} ({e}); using "
                      "analytic routing defaults", stacklevel=2)
        return None
    if calib.backend != want:
        warnings.warn(f"calibration artifact {path} was measured on backend="
                      f"{calib.backend!r} but this process runs {want!r}; "
                      "using analytic routing defaults", stacklevel=2)
        return None
    return calib
