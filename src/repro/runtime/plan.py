"""RoutePlan — the single source of truth for matmul placement.

A :class:`RoutePlan` records, per matmul of a layer stack, the shape and the
router's :class:`Route` decision under one :class:`RuntimeConfig`.  The same
plan drives

  (a) the JAX execution path (``collaborative_forward`` executes a plan's
      recorded routes instead of re-deriving them),
  (b) the analytical FPGA cycle model (``OctopusCycleModel.stack_report``
      consumes a plan, so the model can never silently diverge from the
      execution placement), and
  (c) the human-readable placement report, :meth:`RoutePlan.explain`.

Plans are built either from explicit layer shapes::

    plan = RoutePlan.from_layers(usecase2_layers(1000))

or by tracing any JAX callable abstractly (no FLOPs are executed; every
``router.matmul`` along the way reports its decision)::

    plan = RoutePlan.trace(lambda x: cnn_apply(params, x),
                           jax.ShapeDtypeStruct((1000, 20), jnp.float32))
    print(plan.explain())
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.runtime import routing
from repro.runtime.config import RuntimeConfig, current_runtime, octopus_runtime


@dataclass(frozen=True)
class PlannedMatmul:
    name: str
    m: int
    k: int
    n: int
    route: routing.Route
    quantized: bool = False

    @property
    def shape(self) -> Tuple[int, int, int]:
        return (self.m, self.k, self.n)

    @property
    def engine(self) -> str:
        return self.route.path

    @property
    def macs(self) -> int:
        return self.m * self.k * self.n


@dataclass(frozen=True)
class RoutePlan:
    """An ordered, immutable placement plan for a stack of matmuls."""

    config: RuntimeConfig
    steps: Tuple[PlannedMatmul, ...]

    # ------------------------------------------------------------- builders
    @classmethod
    def from_layers(cls, layers: Sequence[Tuple[str, int, int, int]],
                    *, config: Optional[RuntimeConfig] = None) -> "RoutePlan":
        """Build a plan from explicit ``(name, M, K, N)`` layer shapes."""
        cfg = config if config is not None else current_runtime()
        steps = tuple(
            PlannedMatmul(name, m, k, n, routing.route_matmul(m, k, n, config=cfg),
                          bool(cfg.quantize and cfg.quant_scales is not None
                               and cfg.quant_scales.lookup(name) is not None))
            for name, m, k, n in layers
        )
        return cls(cfg, steps)

    @classmethod
    def trace(cls, fn: Callable, *args: Any, config: Optional[RuntimeConfig] = None,
              **kwargs: Any) -> "RoutePlan":
        """Abstractly evaluate ``fn(*args)`` (``jax.ShapeDtypeStruct`` args are
        fine) under ``config`` and record every routed matmul it performs."""
        import jax

        cfg = config if config is not None else current_runtime()
        with octopus_runtime(cfg), routing.record_routes() as records:
            jax.eval_shape(fn, *args, **kwargs)
        steps = tuple(
            PlannedMatmul(r.name or f"mm{i}", r.m, r.k, r.n, r.route, r.quantized)
            for i, r in enumerate(records)
        )
        return cls(cfg, steps)

    # -------------------------------------------------------------- queries
    def __len__(self) -> int:
        return len(self.steps)

    def __iter__(self):
        return iter(self.steps)

    def layers(self) -> List[Tuple[str, int, int, int]]:
        return [(s.name, s.m, s.k, s.n) for s in self.steps]

    def engines(self) -> Dict[str, str]:
        """``{step name: engine}`` placement map."""
        return {s.name: s.engine for s in self.steps}

    def scoped(self, prefix: str, *, strip: bool = False) -> "RoutePlan":
        """The sub-plan of steps recorded under ``name_scope(prefix)`` (see
        :func:`repro.runtime.routing.name_scope`) — same config, so a
        composite trace stays queryable per sub-model.  With ``strip`` the
        scope prefix is removed from the step names, so the sub-plan reads
        like the sub-model was traced on its own."""
        p = prefix.rstrip("/") + "/"
        steps = tuple(s for s in self.steps if s.name.startswith(p))
        if strip:
            steps = tuple(replace(s, name=s.name[len(p) :]) for s in steps)
        return RoutePlan(self.config, steps)

    def macs(self, engine: Optional[str] = None) -> int:
        return sum(s.macs for s in self.steps if engine is None or s.engine == engine)

    # -------------------------------------------------------------- report
    def explain(self) -> str:
        """Human-readable placement report."""
        cfg = self.config
        head = (f"RoutePlan: {len(self.steps)} matmuls | policy={cfg.policy} "
                f"tau={cfg.tau} mxu_tile={cfg.mxu_tile} fill_depth={cfg.fill_depth}")
        if cfg.calibration:
            head += f" [calibrated: {cfg.calibration}]"
        if cfg.quantize and cfg.quant_scales is not None:
            head += f" [quantize: {cfg.quant_scales.fingerprint}]"
        if not self.steps:
            return head + "\n  (empty)"
        name_w = max(len(s.name) for s in self.steps)
        shape_w = max(len(f"({s.m},{s.k},{s.n})") for s in self.steps)
        lines = [head]
        for s in self.steps:
            shape = f"({s.m},{s.k},{s.n})"
            dtype = "int8" if s.quantized else "f32"
            lines.append(f"  {s.name:<{name_w}}  {shape:<{shape_w}}  "
                         f"{s.engine:<5}  {dtype:<4}  util={s.route.util:6.3f}  "
                         f"{s.route.reason}")
        total = self.macs() or 1
        ary, vpe = self.macs("arype"), self.macs("vpe")
        n_ary = sum(1 for s in self.steps if s.engine == "arype")
        n_q = sum(1 for s in self.steps if s.quantized)
        lines.append(f"  -- arype: {n_ary} matmuls ({100 * ary / total:.1f}% of MACs) | "
                     f"vpe: {len(self.steps) - n_ary} matmuls ({100 * vpe / total:.1f}% of MACs)")
        if n_q:
            lines.append(f"  -- int8: {n_q}/{len(self.steps)} matmuls quantized")
        return "\n".join(lines)
