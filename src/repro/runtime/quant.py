"""Int8 symmetric quantization for the engine datapath (the paper's fixed
point).

The FPGA Octopus computes its engine matmuls in fixed point; this module
carries the pieces that make the same numerics portable across our backends:

  * :class:`QuantScales` — the per-layer symmetric scale table.  One entry
    per routed matmul name (``w0``..``w3``, ``conv1``..``linear``, ...),
    holding the activation and weight scales picked by calibration.  It is
    a frozen, hashable value so it can live on the (frozen, hashable)
    :class:`repro.runtime.RuntimeConfig`; the artifact only ever shows the
    short ``fingerprint`` in reports.
  * :func:`quantize_i8` / :func:`quantize_f32int` — the two encodings of the
    same integer grid.  ``i8`` is the native operand dtype for backends with
    int8 MACs (TPU MXU, the Pallas kernels); ``f32int`` keeps the clipped,
    rounded integers in f32 lanes.  For every engine shape in this repo the
    contraction depth K is far below :data:`EMULATE_MAX_K`, so an f32 dot of
    ``f32int`` operands is **bit-exact** to the int32 accumulation — products
    are ≤ 127², and K of them sum below 2^24, inside f32's exact-integer
    range.  That is how CPU backends (where XLA emulates int8 dots slowly)
    get the paper's fixed-point *numerics* without paying an emulation tax.
  * :func:`record_scales` — an eager-only recorder that ``router.matmul``
    feeds max-abs statistics into; the calibration pass in
    :mod:`repro.launch.calibrate` drives a traffic sample through the
    engines under this context and turns the recorder into a
    :class:`QuantScales`.
"""
from __future__ import annotations

import hashlib
import json
from contextlib import contextmanager
from contextvars import ContextVar
from dataclasses import dataclass
from typing import Dict, Iterator, Mapping, Optional, Tuple

Q_MAX = 127  # symmetric int8 grid: codes in [-127, 127] (no -128, keeps |q| symmetric)

# Largest contraction depth for which sum_K (127 * 127) stays below 2^24,
# f32's exact-integer range: an f32 dot of integer-valued operands is then
# bit-exact to int32 accumulation.  Every engine K in this repo is <= 256.
EMULATE_MAX_K = (1 << 24) // (Q_MAX * Q_MAX)  # 1040

_EPS = 1e-8


def pick_scale(max_abs: float) -> float:
    """Symmetric per-tensor scale from a max-abs statistic (zero-guarded)."""
    return max(float(max_abs), _EPS) / Q_MAX


#: A weight scale is either per-tensor (one float) or per-output-channel
#: (one float per N column — the standard int8 scheme; channel scales fold
#: into the post-accumulation dequant exactly, so the integer contraction is
#: untouched).
WeightScale = Tuple[float, ...]


@dataclass(frozen=True)
class QuantScales:
    """Per-layer symmetric int8 scales: ``(name, scale_x, scale_w)`` entries.

    ``scale_x`` quantizes the activation operand (per-tensor); ``scale_w``
    the weight — a single float, or a tuple with one scale per output
    channel (N column).  The dequantized output is
    ``int32_accum * scale_x * scale_w[n]``.  Lookup tries the
    routing-scope-qualified name first (``pkt/w0``) then the bare layer name
    (``w0``), so one table serves both a composite pipeline trace and a bare
    model call.
    """

    entries: Tuple[Tuple[str, float, object], ...]

    def __post_init__(self):
        seen = set()
        for name, sx, sw in self.entries:
            if not name or not isinstance(name, str):
                raise ValueError(f"quant scale entry needs a layer name, got {name!r}")
            if name in seen:
                raise ValueError(f"duplicate quant scale entry for {name!r}")
            seen.add(name)
            sws = sw if isinstance(sw, tuple) else (sw,)
            if not (sx > 0.0 and sws and all(s > 0.0 for s in sws)):
                raise ValueError(
                    f"quant scales must be positive, got {name!r}: ({sx}, {sw})")
        object.__setattr__(self, "_map", {e[0]: (e[1], e[2]) for e in self.entries})

    # ------------------------------------------------------------- queries
    def lookup(self, name: Optional[str], scope: str = "") -> Optional[Tuple[float, float]]:
        """``(scale_x, scale_w)`` for a routed matmul, or None (→ stay f32)."""
        if not name:
            return None
        table: Dict[str, Tuple[float, float]] = self._map  # type: ignore[attr-defined]
        if scope:
            hit = table.get(f"{scope}{name}")
            if hit is not None:
                return hit
        # A scoped execution name like "pkt/w0" falls back to its bare tail.
        hit = table.get(name)
        if hit is None and "/" in name:
            hit = table.get(name.rsplit("/", 1)[-1])
        return hit

    def names(self) -> Tuple[str, ...]:
        return tuple(e[0] for e in self.entries)

    @property
    def fingerprint(self) -> str:
        """Short stable id for reports/artifacts (``int8/<10 hex>``)."""
        blob = json.dumps(self.entries, sort_keys=True).encode()
        return "int8/" + hashlib.sha256(blob).hexdigest()[:10]

    def subset(self, names) -> "QuantScales":
        """The table restricted to ``names`` (layers outside it stay f32) —
        how the sensitivity pass in calibration prunes flip-prone layers."""
        keep = set(names)
        return QuantScales(tuple(e for e in self.entries if e[0] in keep))

    # ---------------------------------------------------------- construction
    @classmethod
    def from_max_abs(cls, stats: Mapping[str, Tuple[float, object]]) -> "QuantScales":
        """Build from ``{name: (max_abs_x, max_abs_w)}`` statistics; the
        weight stat may be a scalar (per-tensor) or a per-output-channel
        sequence."""
        entries = []
        for name, (mx, mw) in sorted(stats.items()):
            sw = (tuple(pick_scale(v) for v in mw)
                  if isinstance(mw, (tuple, list)) else pick_scale(mw))
            entries.append((name, pick_scale(mx), sw))
        return cls(tuple(entries))

    # ------------------------------------------------------------ artifacts
    def to_dict(self) -> dict:
        return {"entries": [[n, sx, list(sw) if isinstance(sw, tuple) else sw]
                            for n, sx, sw in self.entries]}

    @classmethod
    def from_dict(cls, d: Mapping) -> "QuantScales":
        entries = []
        for name, sx, sw in d["entries"]:
            sw = tuple(float(v) for v in sw) if isinstance(sw, (tuple, list)) else float(sw)
            entries.append((str(name), float(sx), sw))
        return cls(tuple(entries))


# --------------------------------------------------------------------------
# Quantization primitives (jnp — imported lazily so config import stays light)


def _scale_arr(scale):
    """Scale as a jnp value: scalar, or an (N,) row for per-channel tuples
    (divides the last axis — the output-channel dim of a (K, N) weight)."""
    import jax.numpy as jnp

    if isinstance(scale, tuple):
        return jnp.asarray(scale, jnp.float32)
    return jnp.float32(scale)


def quantize_i8(v, scale):
    """Clip-round to the symmetric int8 grid (native operand encoding)."""
    import jax.numpy as jnp

    return jnp.clip(jnp.round(v.astype(jnp.float32) / _scale_arr(scale)),
                    -Q_MAX, Q_MAX).astype(jnp.int8)


def quantize_f32int(v, scale):
    """Same integer grid, kept in f32 lanes (exact-emulation encoding)."""
    import jax.numpy as jnp

    return jnp.clip(jnp.round(v.astype(jnp.float32) / _scale_arr(scale)),
                    float(-Q_MAX), float(Q_MAX))


def dequant_row(scale_x, scale_w, n: int):
    """The (n,) f32 dequant vector ``scale_x * scale_w`` (broadcast scalars)."""
    import numpy as np

    return np.broadcast_to(
        np.float32(scale_x) * np.asarray(scale_w, np.float32), (n,)).copy()


# --------------------------------------------------------------------------
# Calibration-time scale recording


class ScaleRecorder:
    """Accumulates per-layer max-abs stats from eager ``router.matmul`` calls:
    a per-tensor activation max plus a per-output-channel weight max."""

    def __init__(self) -> None:
        self.stats: Dict[str, Tuple[float, Tuple[float, ...]]] = {}

    def update(self, name: str, max_x: float, max_w) -> None:
        mw_new = tuple(max_w) if isinstance(max_w, (tuple, list)) else (float(max_w),)
        mx, mw = self.stats.get(name, (0.0, (0.0,) * len(mw_new)))
        if len(mw) != len(mw_new):
            raise ValueError(f"inconsistent weight width for {name!r}: "
                             f"{len(mw)} vs {len(mw_new)}")
        self.stats[name] = (max(mx, max_x),
                            tuple(max(a, b) for a, b in zip(mw, mw_new)))

    def scales(self) -> QuantScales:
        return QuantScales.from_max_abs(self.stats)


_scale_recorder: ContextVar[Optional[ScaleRecorder]] = ContextVar(
    "quant_scale_recorder", default=None)


@contextmanager
def record_scales() -> Iterator[ScaleRecorder]:
    """Collect max-abs stats from every *eager* routed matmul in the block.

    Traced (jit/eval_shape) calls are skipped — tracers have no values — so a
    calibration pass can freely mix jitted pipeline steps (ignored) with
    eager engine applications (recorded).
    """
    rec = ScaleRecorder()
    token = _scale_recorder.set(rec)
    try:
        yield rec
    finally:
        _scale_recorder.reset(token)


def maybe_record(name: Optional[str], x, w) -> None:
    """Feed one matmul's operands to the active recorder, if any (eager only)."""
    rec = _scale_recorder.get()
    if rec is None or not name:
        return
    import jax.numpy as jnp
    from jax import core

    if isinstance(x, core.Tracer) or isinstance(w, core.Tracer):
        return
    w_cols = jnp.max(jnp.abs(w), axis=tuple(range(w.ndim - 1)))  # per N column
    rec.update(name, float(jnp.max(jnp.abs(x))),
               tuple(float(v) for v in w_cols))
