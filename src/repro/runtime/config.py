"""The Octopus runtime configuration (paper §2.3, §3.2.3).

One frozen :class:`RuntimeConfig` holds every knob that used to be threaded
through the call stack as ad-hoc kwargs (``policy=``, ``use_pallas=``,
``interpret=``, ``fused_aggregation=``) or frozen as module globals (``TAU``,
``MXU``, ``FILL_DEPTH``, ``VPE_MAX_ELEMS``).  The active config is ambient:

    from repro.runtime import RuntimeConfig, octopus_runtime

    with octopus_runtime(RuntimeConfig(policy="arype_only")):
        y = router.matmul(x, w)          # no tuning kwargs anywhere

Precedence, highest first:
  1. deprecated explicit kwargs on ``router.matmul`` etc. (one release only)
  2. an explicit ``config=`` argument
  3. the innermost ``octopus_runtime`` / ``runtime_overrides`` context
  4. :data:`DEFAULT_RUNTIME`

The context is a :class:`contextvars.ContextVar`, so nesting, threads and
async all behave.  Configs only influence *trace-time* routing decisions;
note that ``jax.jit`` caches by argument shapes, not by ambient context, so
a jitted callable must be traced under the config it should keep (the
serving paths capture their config at construction time for exactly this
reason).
"""
from __future__ import annotations

import dataclasses
import warnings
from contextlib import contextmanager
from contextvars import ContextVar
from dataclasses import dataclass
from typing import Any, Iterator, Optional

POLICIES = ("collaborative", "arype_only", "vpe_only")


@dataclass(frozen=True)
class RuntimeConfig:
    """Placement + execution knobs for the routed compute core.

    Routing (paper's placement policy):
      * ``policy`` — "collaborative" (router decides), "arype_only", "vpe_only".
      * ``tau`` — MXU-utilization threshold below which work routes to VPE.
      * ``mxu_tile`` — systolic array edge of the target hardware.
      * ``fill_depth`` — minimum stream length to hide systolic fill latency.
      * ``vpe_max_elems`` — VPE-path working-set cap (M*K*N fp32 elements).

    Execution:
      * ``use_pallas`` — lower through the Pallas engine kernels.
      * ``interpret`` — Pallas interpret mode (True for CPU validation).
      * ``accum_dtype`` — accumulation dtype name for both engine paths.
      * ``fused_aggregation`` — fuse K-block partial aggregation (False
        reproduces the paper's "wo/ collaborating" ablation).
    """

    policy: str = "collaborative"
    tau: float = 0.35
    mxu_tile: int = 128
    fill_depth: int = 8
    vpe_max_elems: int = 1 << 21
    use_pallas: bool = False
    interpret: bool = True
    accum_dtype: str = "float32"
    fused_aggregation: bool = True

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, got {self.policy!r}")
        if not 0.0 < self.tau <= 1.0:
            raise ValueError(f"tau must be in (0, 1], got {self.tau}")
        if self.mxu_tile <= 0 or self.fill_depth <= 0 or self.vpe_max_elems <= 0:
            raise ValueError("mxu_tile, fill_depth and vpe_max_elems must be positive")

    def replace(self, **overrides: Any) -> "RuntimeConfig":
        return dataclasses.replace(self, **overrides) if overrides else self

    @classmethod
    def from_arch(cls, arch: Any, **overrides: Any) -> "RuntimeConfig":
        """Derive a runtime config from a model ArchConfig (duck-typed so the
        runtime package never imports ``repro.configs``).

        ``interpret`` is inherited from the ambient runtime (default True,
        which is what host/CPU emulation — including the dryrun's forced host
        platform — needs).  A real-TPU launch must run inside
        ``runtime_overrides(interpret=False)`` until platform-derived defaults
        land (see ROADMAP)."""
        base = current_runtime()
        kw = {
            "policy": getattr(arch, "router_policy", base.policy),
            "accum_dtype": getattr(arch, "matmul_accum_dtype", base.accum_dtype),
            "use_pallas": getattr(arch, "use_pallas", base.use_pallas),
        }
        kw.update(overrides)
        return base.replace(**kw)


DEFAULT_RUNTIME = RuntimeConfig()

_active: ContextVar[RuntimeConfig] = ContextVar("octopus_runtime", default=DEFAULT_RUNTIME)


def current_runtime() -> RuntimeConfig:
    """The innermost active config (or :data:`DEFAULT_RUNTIME`)."""
    return _active.get()


@contextmanager
def octopus_runtime(config: RuntimeConfig) -> Iterator[RuntimeConfig]:
    """Make ``config`` the ambient runtime within the block."""
    token = _active.set(config)
    try:
        yield config
    finally:
        _active.reset(token)


@contextmanager
def runtime_overrides(**overrides: Any) -> Iterator[RuntimeConfig]:
    """Like :func:`octopus_runtime` but patches only the given fields of the
    currently active config (nesting composes)."""
    with octopus_runtime(current_runtime().replace(**overrides)) as cfg:
        yield cfg


def resolve_config(config: Optional[RuntimeConfig] = None, **deprecated: Any) -> RuntimeConfig:
    """Resolve ``config`` (or the ambient runtime) plus deprecated explicit
    kwarg overrides; warns once per call for any non-None deprecated kwarg.

    ``accum_dtype`` values are normalized to dtype names so callers may keep
    passing ``jnp.float32`` etc.
    """
    cfg = config if config is not None else current_runtime()
    live = {k: v for k, v in deprecated.items() if v is not None}
    if live:
        if "accum_dtype" in live:
            import numpy as np

            live["accum_dtype"] = np.dtype(live["accum_dtype"]).name
        warnings.warn(
            f"explicit {sorted(live)} kwargs are deprecated; pass a RuntimeConfig "
            "via config= or enter `with octopus_runtime(cfg):` instead",
            DeprecationWarning,
            stacklevel=3,
        )
        cfg = cfg.replace(**live)
    return cfg
