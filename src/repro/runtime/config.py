"""The Octopus runtime configuration (paper §2.3, §3.2.3).

One frozen :class:`RuntimeConfig` holds every knob that used to be threaded
through the call stack as ad-hoc kwargs (``policy=``, ``use_pallas=``,
``interpret=``, ``fused_aggregation=``) or frozen as module globals (``TAU``,
``MXU``, ``FILL_DEPTH``, ``VPE_MAX_ELEMS``).  The active config is ambient:

    from repro.runtime import RuntimeConfig, octopus_runtime

    with octopus_runtime(RuntimeConfig(policy="arype_only")):
        y = router.matmul(x, w)          # no tuning kwargs anywhere

Precedence, highest first:
  1. an explicit ``config=`` argument
  2. the innermost ``octopus_runtime`` / ``runtime_overrides`` context
  3. :data:`DEFAULT_RUNTIME`

Two fields are not hand-picked constants:

  * ``interpret`` defaults from the execution platform
    (:mod:`repro.runtime.platform`): True on CPU hosts where Pallas kernels
    only run in interpret mode, False on real TPU/GPU backends.
  * ``tau`` / ``vpe_max_elems`` ship with the paper's analytic values but can
    be replaced by measured crossover points: :meth:`RuntimeConfig.calibrated`
    loads a :mod:`repro.runtime.autotune` artifact, and ``octopus_runtime``
    accepts a ``Calibration`` directly.  A config whose thresholds came from a
    measurement carries the artifact's platform fingerprint in
    ``calibration`` (None for analytic defaults).

The context is a :class:`contextvars.ContextVar`, so nesting, threads and
async all behave.  Configs only influence *trace-time* routing decisions;
note that ``jax.jit`` caches by argument shapes, not by ambient context, so
a jitted callable must be traced under the config it should keep (the
serving paths capture their config at construction time for exactly this
reason).
"""
from __future__ import annotations

import dataclasses
from contextlib import contextmanager
from contextvars import ContextVar
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Any, Iterator, Optional

from repro.runtime import platform
from repro.runtime.quant import QuantScales

POLICIES = ("collaborative", "arype_only", "vpe_only")
QUANT_IMPLS = ("auto", "native", "emulate")


@dataclass(frozen=True)
class RuntimeConfig:
    """Placement + execution knobs for the routed compute core.

    Routing (paper's placement policy):
      * ``policy`` — "collaborative" (router decides), "arype_only", "vpe_only".
      * ``tau`` — MXU-utilization threshold below which work routes to VPE.
      * ``mxu_tile`` — systolic array edge of the target hardware.
      * ``fill_depth`` — minimum stream length to hide systolic fill latency.
      * ``vpe_max_elems`` — VPE-path working-set cap (M*K*N fp32 elements).
      * ``calibration`` — platform fingerprint of the measured-crossover
        artifact that produced ``tau``/``vpe_max_elems`` (None: analytic).

    Execution:
      * ``use_pallas`` — lower through the Pallas engine kernels.
      * ``interpret`` — Pallas interpret mode (platform-derived: True on CPU
        hosts, False on real TPU/GPU backends).
      * ``accum_dtype`` — accumulation dtype name for both engine paths.
      * ``fused_aggregation`` — fuse K-block partial aggregation (False
        reproduces the paper's "wo/ collaborating" ablation).

    Quantization (the paper's fixed-point datapath):
      * ``quantize`` — run engine matmuls in int8 operands / int32 accum,
        dequantized to f32 on the way out.  A matmul quantizes only when its
        layer name has an entry in ``quant_scales``; unnamed or uncalibrated
        matmuls stay f32 (never silently mis-scaled).
      * ``quant_scales`` — the per-layer :class:`repro.runtime.quant.QuantScales`
        table from calibration (reports show its ``fingerprint``).
      * ``quant_impl`` — "native" (int8 dot, int32 preferred type), "emulate"
        (integer grid in f32 lanes — bit-exact to int32 accum for engine K
        depths, fast where XLA lacks int8 MACs), or "auto" (emulate on CPU
        hosts, native elsewhere).
    """

    policy: str = "collaborative"
    tau: float = 0.35
    mxu_tile: int = 128
    fill_depth: int = 8
    vpe_max_elems: int = 1 << 21
    use_pallas: bool = False
    interpret: bool = field(default_factory=platform.interpret_default)
    accum_dtype: str = "float32"
    fused_aggregation: bool = True
    calibration: Optional[str] = None
    quantize: bool = False
    quant_scales: Optional[QuantScales] = None
    quant_impl: str = "auto"

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, got {self.policy!r}")
        if not 0.0 < self.tau <= 1.0:
            raise ValueError(f"tau must be in (0, 1], got {self.tau}")
        if self.mxu_tile <= 0 or self.fill_depth <= 0 or self.vpe_max_elems <= 0:
            raise ValueError("mxu_tile, fill_depth and vpe_max_elems must be positive")
        if self.quant_impl not in QUANT_IMPLS:
            raise ValueError(
                f"quant_impl must be one of {QUANT_IMPLS}, got {self.quant_impl!r}")

    def replace(self, **overrides: Any) -> "RuntimeConfig":
        return dataclasses.replace(self, **overrides) if overrides else self

    @classmethod
    def calibrated(cls, path: Optional[str] = None, **overrides: Any) -> "RuntimeConfig":
        """A config whose ``tau``/``vpe_max_elems`` come from the measured
        crossover artifact at ``path`` (default: this platform's cache path,
        see :func:`repro.runtime.autotune.load_calibration`).  Falls back to
        the analytic defaults — with the loader's warning — when no usable
        artifact exists; ``calibration`` is None in that case.

        ``quantize=True`` additionally requires per-layer scales in the
        artifact: when they are absent (old artifact, or a corrupt/missing
        one that already fell back) the config warns and stays f32 rather
        than running mis-scaled int8."""
        import warnings

        from repro.runtime import autotune

        calib = autotune.load_calibration(path)
        base = calib.apply(cls()) if calib is not None else cls()
        cfg = base.replace(**overrides)
        if cfg.quantize and cfg.quant_scales is None:
            warnings.warn(
                "quantize=True requested but the calibration artifact carries "
                "no quant_scales; falling back to the f32 datapath "
                "(re-run repro.launch.calibrate to fit int8 scales)",
                UserWarning, stacklevel=2)
            cfg = cfg.replace(quantize=False)
        return cfg

    @classmethod
    def from_arch(cls, arch: Any, **overrides: Any) -> "RuntimeConfig":
        """Derive a runtime config from a model ArchConfig (duck-typed so the
        runtime package never imports ``repro.configs``).

        ``interpret`` is inherited from the ambient runtime, whose default is
        platform-derived (True under host/CPU emulation — including the
        dryrun's forced host platform — False on real TPU/GPU backends)."""
        base = current_runtime()
        kw = {
            "policy": getattr(arch, "router_policy", base.policy),
            "accum_dtype": getattr(arch, "matmul_accum_dtype", base.accum_dtype),
            "use_pallas": getattr(arch, "use_pallas", base.use_pallas),
        }
        kw.update(overrides)
        return base.replace(**kw)


# DEFAULT_RUNTIME is constructed lazily (module __getattr__ below): building a
# RuntimeConfig probes the JAX backend for the interpret default, and an
# import-time probe would lock XLA_FLAGS/device discovery for consumers (the
# dryrun/train launchers) that must set flags before anything touches jax.
@lru_cache(maxsize=None)
def _default_runtime() -> RuntimeConfig:
    return RuntimeConfig()


def __getattr__(name: str) -> Any:
    if name == "DEFAULT_RUNTIME":
        return _default_runtime()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


_active: ContextVar[Optional[RuntimeConfig]] = ContextVar("octopus_runtime", default=None)


def current_runtime() -> RuntimeConfig:
    """The innermost active config (or :data:`DEFAULT_RUNTIME`)."""
    cfg = _active.get()
    return cfg if cfg is not None else _default_runtime()


@contextmanager
def octopus_runtime(config: Any) -> Iterator[RuntimeConfig]:
    """Make ``config`` the ambient runtime within the block.

    Besides a :class:`RuntimeConfig`, anything with an ``apply(base)`` method
    is accepted — in particular a :class:`repro.runtime.autotune.Calibration`,
    so ``with octopus_runtime(load_calibration(...)):`` applies measured
    thresholds onto the currently active config."""
    if not isinstance(config, RuntimeConfig):
        if hasattr(config, "apply"):
            config = config.apply(current_runtime())
        else:
            raise TypeError(
                f"octopus_runtime expects a RuntimeConfig or an object with "
                f".apply(base), got {type(config).__name__}")
    token = _active.set(config)
    try:
        yield config
    finally:
        _active.reset(token)


@contextmanager
def runtime_overrides(**overrides: Any) -> Iterator[RuntimeConfig]:
    """Like :func:`octopus_runtime` but patches only the given fields of the
    currently active config (nesting composes)."""
    with octopus_runtime(current_runtime().replace(**overrides)) as cfg:
        yield cfg


def resolve_config(config: Optional[RuntimeConfig] = None) -> RuntimeConfig:
    """``config`` when given, else the ambient runtime.

    (The deprecated per-call kwarg overrides this function used to absorb —
    ``policy=``/``use_pallas=``/``interpret=``/... — were removed on the PR 1
    schedule; pass a RuntimeConfig or enter ``octopus_runtime``.)"""
    return config if config is not None else current_runtime()
