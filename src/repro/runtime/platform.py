"""Execution-platform probing (ROADMAP: platform-derived runtime defaults).

``RuntimeConfig`` used to hard-code ``interpret=True`` — right for CPU hosts
(Pallas kernels only run there in interpret mode) and silently wrong on a real
TPU/GPU, where every ``--use-pallas`` launch needed a manual
``runtime_overrides(interpret=False)``.  This module asks JAX what it is
actually running on, once, and the answers become the config defaults.

Probes are cached (the backend cannot change within a process) and never
raise: an unimportable or uninitializable JAX degrades to conservative CPU
answers, so this module is safe to use at config-construction time.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Dict

# Backends where the Pallas kernels compile for real hardware; anything else
# (cpu, interpreters, mocks) needs interpret mode.
_ACCELERATOR_BACKENDS = frozenset({"tpu", "gpu", "cuda", "rocm"})


@lru_cache(maxsize=None)
def backend() -> str:
    """The active JAX backend name ("cpu", "gpu", "tpu"); "cpu" on failure."""
    try:
        import jax

        return jax.default_backend()
    except Exception:
        return "cpu"


@lru_cache(maxsize=None)
def device_kind() -> str:
    """Hardware kind of device 0 (e.g. "cpu", "TPU v4"); "unknown" on failure."""
    try:
        import jax

        return jax.devices()[0].device_kind
    except Exception:
        return "unknown"


@lru_cache(maxsize=None)
def pallas_available() -> bool:
    """Whether the Pallas engine kernels can be imported at all."""
    try:
        import jax.experimental.pallas  # noqa: F401

        return True
    except Exception:
        return False


@lru_cache(maxsize=None)
def device_count() -> int:
    """Number of addressable local devices; 1 on failure.  Forced host
    platforms (``--xla_force_host_platform_device_count``) count — that is
    exactly how the lane tests/benchmarks exercise ``shard_map`` on CPU."""
    try:
        import jax

        return jax.local_device_count()
    except Exception:
        return 1


def lanes_backend(num_lanes: int) -> str:
    """How the sharded pipeline should run its parallel lanes on this host:
    ``"shard_map"`` when one device per lane exists (each lane's tracker bank
    lives on its own device, the paper's multi-bank memory fabric),
    ``"vmap"`` otherwise (single-device hosts batch the lanes — for the scan
    tracker this still cuts the serial depth to the per-lane capacity)."""
    return "shard_map" if 1 < num_lanes <= device_count() else "vmap"


def is_accelerator() -> bool:
    """True when running on a real TPU/GPU backend (not host emulation)."""
    return backend() in _ACCELERATOR_BACKENDS


def interpret_default() -> bool:
    """Platform-correct ``RuntimeConfig.interpret``: Pallas interpret mode is
    required on CPU hosts and wrong (slow, and unsupported ops) on real
    accelerators."""
    return not is_accelerator()


def fingerprint() -> Dict[str, str]:
    """Identity of the execution platform, embedded in calibration artifacts
    so a cache written on one target is never silently applied to another."""
    try:
        import jax

        jax_version = jax.__version__
    except Exception:
        jax_version = "unknown"
    return {
        "backend": backend(),
        "device_kind": device_kind(),
        "jax": jax_version,
    }


def fingerprint_id(fp: Dict[str, str] | None = None) -> str:
    """Short one-line form of :func:`fingerprint` ("cpu/cpu/jax-0.4.37")."""
    fp = fp if fp is not None else fingerprint()
    return f"{fp['backend']}/{fp['device_kind']}/jax-{fp['jax']}"
