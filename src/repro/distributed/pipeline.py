"""GPipe-style pipeline parallelism over the ``pod`` axis (shard_map +
collective_permute).

At 1000+ node scale, cross-pod ICI/DCN links are much slower than intra-pod
links, so the pod axis prefers pipeline transfers (point-to-point, one
activation tensor per microbatch) over data-parallel all-reduces of full
gradients.  This module implements the schedule:

  * the layer stack is split into ``num_stages`` contiguous groups,
  * microbatches stream through stages with ``collective_permute`` handoffs,
  * the standard GPipe bubble: (stages-1) warmup + (stages-1) drain slots of
    the (microbatches + stages - 1)-slot schedule.

The implementation is deliberately stage-generic: ``stage_fn(stage_params,
x, stage_index)`` is user code (usually a superblock scan slice).  A CPU
integration test validates numerical equality with the unpipelined model on
an 8-device host mesh.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_forward(
    stage_fn: Callable[[Any, jax.Array, jax.Array], jax.Array],
    stage_params: Any,  # pytree with leading [num_stages] dim, sharded over axis
    x_microbatches: jax.Array,  # (num_micro, mb, ...) input activations
    *,
    mesh: Mesh,
    axis: str = "pod",
) -> jax.Array:
    """Runs the GPipe forward schedule inside shard_map over ``axis``.

    Every device along ``axis`` holds one stage's params (leading dim sharded).
    Microbatch i enters stage 0 at slot i; stage s processes microbatch
    (slot - s); outputs stream off the last stage.  Returns (num_micro, mb, ...)
    activations after all stages.
    """
    num_stages = mesh.shape[axis]
    num_micro = x_microbatches.shape[0]
    total_slots = num_micro + num_stages - 1

    def body(params_local, xs_local):
        # params_local: stage params with leading dim 1 (this device's stage)
        # xs_local: full microbatch stream (replicated along `axis`)
        stage_idx = lax.axis_index(axis)
        my_params = jax.tree.map(lambda p: p[0], params_local)

        def slot_step(carry, t):
            state, outputs = carry  # state: (mb, ...) current activation
            # stage 0 ingests microbatch t; others take the permuted input
            incoming = jnp.where(
                t < num_micro,
                xs_local[jnp.minimum(t, num_micro - 1)],
                jnp.zeros_like(xs_local[0]),
            )
            inp = jnp.where(stage_idx == 0, incoming, state)
            out = stage_fn(my_params, inp, stage_idx)
            # hand off to the next stage (ring permute; last->first is ignored)
            perm = [(i, (i + 1) % num_stages) for i in range(num_stages)]
            state_next = lax.ppermute(out, axis, perm)
            # the LAST stage emits microbatch (t - (num_stages - 1)) at slot t
            emit_idx = t - (num_stages - 1)
            is_emit = (stage_idx == num_stages - 1) & (emit_idx >= 0)
            outputs = lax.cond(
                is_emit,
                lambda o: o.at[jnp.maximum(emit_idx, 0)].set(out),
                lambda o: o,
                outputs,
            )
            return (state_next, outputs), None

        out0 = jnp.zeros_like(xs_local)
        state0 = jnp.zeros_like(xs_local[0])
        (_, outputs), _ = lax.scan(slot_step, (state0, out0), jnp.arange(total_slots))
        # only the last stage holds real outputs; broadcast them along the axis
        outputs = lax.psum(
            jnp.where(stage_idx == num_stages - 1, outputs, jnp.zeros_like(outputs)),
            axis,
        )
        return outputs

    in_specs = (
        jax.tree.map(lambda _: P(axis), stage_params),
        P(),  # microbatch stream replicated along the pipeline axis
    )
    fn = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=P(),
                   check_rep=False)
    return fn(stage_params, x_microbatches)


def split_stages(stacked_params: Any, num_stages: int) -> Any:
    """Reshape a [num_layers, ...] stacked param tree into
    [num_stages, layers_per_stage, ...]."""

    def one(p):
        n = p.shape[0]
        assert n % num_stages == 0, (n, num_stages)
        return p.reshape(num_stages, n // num_stages, *p.shape[1:])

    return jax.tree.map(one, stacked_params)
