"""Logical-axis -> mesh-axis sharding rules (DP / FSDP / TP / EP / SP).

Every parameter spec carries logical axis names; this module maps them onto
the production mesh axes (pod, data, model):

  batch        -> (pod, data)        data parallel (pod = outer DP axis)
  vocab        -> model              TP on embedding / lm head
  heads/kv     -> model              TP on attention projections (if divisible)
  mlp          -> model              TP on FFN
  expert       -> model              EP on MoE expert banks
  ssm_inner    -> model              TP on Mamba/mLSTM inner projections
  embed        -> fsdp axes          ZeRO-3 parameter sharding (if cfg.fsdp)
  kv_seq       -> model              SP on very long decode caches (optional)

Rules degrade gracefully: any dimension not divisible by its mesh axes falls
back to replication (recorded, so the roofline report can flag the padding /
replication waste — e.g. gemma3's 4 q-heads on a 16-way model axis).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig


def logical_rules(cfg: ArchConfig, mesh: Mesh) -> dict[str, Any]:
    if getattr(cfg, "moe_dp_attention", False):
        # Switch/GShard layout: no TP — dense params fully FSDP over every
        # axis, experts over model (EP), batch over everything.
        all_axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
        return {
            "batch": all_axes,
            "vocab": "model",
            "heads": None, "kv_heads": None, "mlp": None,
            "expert": "model",
            "ssm_inner": None, "mlstm_inner": None, "mlstm_qk": None,
            "slstm_gates": None, "embed_out": None,
            "embed": tuple(a for a in ("pod", "data") if a in mesh.axis_names),
            "layers": None, "kv_seq": None, "seq": None,
        }
    fsdp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    rules: dict[str, Any] = {
        "batch": tuple(a for a in ("pod", "data") if a in mesh.axis_names) or None,
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "expert": "model",
        "ssm_inner": "model",
        "mlstm_inner": "model",
        "mlstm_qk": None,
        "slstm_gates": "model",
        "embed_out": None,
        "embed": fsdp_axes if cfg.fsdp else None,
        "layers": None,
        "kv_seq": "model" if cfg.shard_kv_seq_decode else None,
        "seq": None,
    }
    return rules


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def spec_for_shape(
    shape: tuple[int, ...],
    logical: tuple[Optional[str], ...],
    rules: dict[str, Any],
    mesh: Mesh,
    report: Optional[list] = None,
) -> P:
    """Build a PartitionSpec, replicating any dim whose size is not divisible
    by its assigned mesh axes, and never assigning one mesh axis twice."""
    parts = []
    used: set[str] = set()
    for dim, name in zip(shape, logical):
        axes = rules.get(name) if name else None
        if axes is None:
            parts.append(None)
            continue
        axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
        axes_t = tuple(a for a in axes_t if a not in used)
        size = _axis_size(mesh, axes_t)
        if not axes_t or size <= 1:
            parts.append(None)
            continue
        if dim % size != 0:
            if report is not None:
                report.append((name, dim, axes_t, "replicated: not divisible"))
            parts.append(None)
            continue
        used.update(axes_t)
        parts.append(axes_t[0] if len(axes_t) == 1 else axes_t)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def shardings_for(
    tree_logical: Any,
    tree_abstract: Any,
    cfg: ArchConfig,
    mesh: Mesh,
    report: Optional[list] = None,
) -> Any:
    """Map a tree of logical-axis tuples + abstract shapes to NamedShardings."""
    rules = logical_rules(cfg, mesh)

    def one(axes, aval):
        return NamedSharding(mesh, spec_for_shape(aval.shape, axes, rules, mesh, report))

    return jax.tree.map(one, tree_logical, tree_abstract,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            a is None or isinstance(a, str) for a in x))


# ---------------------------------------------------------------------------
# Serving-lane shardings (the pipeline's `lanes` mesh axis)
# ---------------------------------------------------------------------------

LANES_AXIS = "lanes"


def lanes_spec(extra_dims: int = 0) -> P:
    """PartitionSpec for a lane-stacked array: dim0 over ``lanes``, the rest
    replicated.  Every per-shard pipeline tensor (TrackerState banks, packet
    lanes, keep masks) is stacked on dim0, so one spec shape fits all."""
    return P(LANES_AXIS, *([None] * extra_dims))


def lanes_shardings(mesh: Mesh, tree_abstract: Any) -> Any:
    """NamedShardings placing every leaf's dim0 on the ``lanes`` axis — used
    to pre-place the per-shard tracker banks so the shard_map'd step never
    reshards its carried state."""
    def one(aval):
        return NamedSharding(mesh, lanes_spec(len(aval.shape) - 1))

    return jax.tree.map(one, tree_abstract)


# ---------------------------------------------------------------------------
# Activation / batch shardings
# ---------------------------------------------------------------------------

def batch_spec(mesh: Mesh, batch_size: int, extra_dims: int = 1,
               all_axes: bool = False) -> P:
    """Shard the leading batch dim over (pod, data) — or every axis for the
    pure-DP (moe_dp_attention) layout — when divisible."""
    names = ("pod", "data", "model") if all_axes else ("pod", "data")
    axes = tuple(a for a in names if a in mesh.axis_names)
    size = _axis_size(mesh, axes)
    if axes and batch_size % size == 0:
        return P(axes if len(axes) > 1 else axes[0], *([None] * extra_dims))
    return P(*([None] * (extra_dims + 1)))


def input_shardings(mesh: Mesh, batch_abstract: dict,
                    cfg: Optional[ArchConfig] = None) -> dict:
    """Shardings for a model-inputs dict: batch-sharded on the leading dim."""
    all_axes = bool(cfg and getattr(cfg, "moe_dp_attention", False))
    out = {}
    for k, v in batch_abstract.items():
        out[k] = NamedSharding(mesh, batch_spec(mesh, v.shape[0], v.ndim - 1,
                                                all_axes=all_axes))
    return out


def opt_shardings(param_sh: Any, params_abstract: Any, opt_abstract: Any) -> Any:
    """Optimizer-state shardings mirror the parameter shardings; factored
    (Adafactor) leaves drop the corresponding PartitionSpec dims."""
    flat_ps, _ = jax.tree.flatten(param_sh)
    flat_pa, _ = jax.tree.flatten(params_abstract)
    by_shape: dict[tuple, NamedSharding] = {}
    for sh, aval in zip(flat_ps, flat_pa):
        by_shape.setdefault(tuple(aval.shape), sh)

    def _norm_spec(sh: NamedSharding, ndim: int) -> list:
        parts = list(sh.spec)
        parts += [None] * (ndim - len(parts))
        return parts

    def _fill_free_axes(spec: list, shape: tuple, mesh: Mesh) -> list:
        """Assign mesh axes freed by the dropped (factored) dim to the largest
        still-unsharded divisible dims (keeps Adafactor col-stats sharded)."""
        used = set()
        for s in spec:
            for a in ((s,) if isinstance(s, str) else (s or ())):
                used.add(a)
        free = [a for a in mesh.axis_names if a not in used and mesh.shape[a] > 1]
        order = sorted(range(len(shape)), key=lambda i: -shape[i])
        for a in free:
            for i in order:
                if spec[i] is None and shape[i] % mesh.shape[a] == 0 and shape[i] >= mesh.shape[a]:
                    spec[i] = a
                    break
        return spec

    def one(aval):
        shape = tuple(aval.shape)
        if shape in by_shape:
            return by_shape[shape]
        # factored leaf: find a param whose shape prefix/suffix matches
        for pshape, sh in by_shape.items():
            parts = _norm_spec(sh, len(pshape))
            if len(pshape) >= 2 and shape == pshape[:-1]:  # row stats
                spec = _fill_free_axes(parts[:-1], shape, sh.mesh)
                return NamedSharding(sh.mesh, P(*spec))
            if len(pshape) >= 2 and shape == pshape[:-2] + pshape[-1:]:  # col stats
                spec = _fill_free_axes(parts[:-2] + parts[-1:], shape, sh.mesh)
                return NamedSharding(sh.mesh, P(*spec))
        # scalars / unmatched: replicate
        mesh0 = next(iter(by_shape.values())).mesh
        return NamedSharding(mesh0, P())

    return jax.tree.map(one, opt_abstract)


def cache_shardings(cache_abstract: Any, cfg: ArchConfig, mesh: Mesh) -> Any:
    """Shard decode caches: batch dim over (pod,data); kv-head dim over model
    for attention caches when divisible; recurrent states similarly.

    Stacked (scanned) caches have a leading num_superblocks dim -> replicated.
    Heuristic by rank & position: every cache leaf's *batch* axis is either
    dim0 (unstacked) or dim1 (stacked); we detect via matching cfg sizes."""
    axes_dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = _axis_size(mesh, axes_dp)
    tp = mesh.shape.get("model", 1)

    def one(aval):
        shape = aval.shape
        parts: list = [None] * len(shape)
        # find batch dim: first dim (or second if leading == num_superblocks)
        bdim = 0
        if len(shape) >= 2 and shape[0] == cfg.num_superblocks and cfg.num_superblocks > 1:
            bdim = 1
        if bdim < len(shape) and shape[bdim] % dp == 0 and dp > 1:
            parts[bdim] = axes_dp if len(axes_dp) > 1 else axes_dp[0]
        # shard the largest remaining dim over model if divisible
        rest = [(d, i) for i, d in enumerate(shape) if i != bdim and parts[i] is None]
        if rest and tp > 1:
            d, i = max(rest)
            if d % tp == 0 and d >= tp:
                parts[i] = "model"
        while parts and parts[-1] is None:
            parts.pop()
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(one, cache_abstract)
