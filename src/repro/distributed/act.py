"""Activation sharding constraints (thread-local mesh context).

GSPMD propagates parameter shardings through straight-line code well, but
propagation through nested while loops (superblock scan + attention chunk
scan) + remat can fall back to replication — which shows up as huge
all-gathers and 100+ GiB temp buffers.  Models therefore pin their key
activations (residual stream, per-head tensors, scan carries, MoE dispatch
buffers) with ``shard_act(x, "batch", None, "heads", None)``.

Outside a mesh context (unit tests, single-device runs) shard_act is a no-op,
so model code never needs to know whether it is distributed.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

_CTX = threading.local()

# logical activation-axis -> mesh axes
_ACT_RULES = {
    "batch": ("pod", "data"),
    "batch_dp": ("pod", "data"),  # always the pure-DP axes (MoE group dim)
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "expert": ("model",),
    "embed": (),  # residual stream stays replicated on the model axis
    "vocab": ("model",),
    "inner": ("model",),
    "kv_seq": ("model",),
    "seq_sp": ("model",),  # sequence-parallel residual stream
}


def rules_for(cfg=None) -> dict:
    """Activation rules, layout-aware (see ArchConfig.moe_dp_attention)."""
    rules = dict(_ACT_RULES)
    if cfg is not None and getattr(cfg, "moe_dp_attention", False):
        rules.update(
            batch=("pod", "data", "model"),  # pure-DP attention
            heads=(), kv_heads=(), mlp=(), inner=(),
        )
    return rules


@contextmanager
def use_act_sharding(mesh: Optional[Mesh], cfg=None):
    prev = getattr(_CTX, "env", None)
    _CTX.env = (mesh, rules_for(cfg)) if mesh is not None else None
    try:
        yield
    finally:
        _CTX.env = prev


def current_mesh() -> Optional[Mesh]:
    env = getattr(_CTX, "env", None)
    return env[0] if env else None


def shard_act(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Constrain activation x's dims to the mesh axes given by logical names
    (None = replicated dim).  Silently skips non-divisible dims and inactive
    contexts."""
    env = getattr(_CTX, "env", None)
    if env is None:
        return x
    mesh, rules = env
    if mesh is None or mesh.size == 1:
        return x
    assert len(names) == x.ndim, (names, x.shape)
    parts = []
    used: set[str] = set()
    for dim, name in zip(x.shape, names):
        if name is None:
            parts.append(None)
            continue
        axes = tuple(a for a in rules.get(name, ()) if a in mesh.axis_names
                     and a not in used)
        size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
        if not axes or size <= 1 or dim % size != 0:
            parts.append(None)
            continue
        used.update(axes)
        parts.append(axes[0] if len(axes) == 1 else axes)
    while parts and parts[-1] is None:
        parts.pop()
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*parts)))
