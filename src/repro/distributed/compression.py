"""Int8 gradient compression with error feedback (distributed-optimization
trick for bandwidth-bound data-parallel training).

Usage inside a shard_map'd gradient exchange: quantize local grads to int8
with a per-tensor scale, all-reduce (psum) the int8-represented values in
fp16/fp32 accumulators, dequantize, and fold the quantization residual into
the next step (error feedback keeps the method unbiased over time).

Under pjit/GSPMD the all-reduce is implicit; the compression transform is
exposed as a pair (encode, decode) applied around the optimizer step, plus a
shard_map collective helper for the explicit-collective path.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressedGrad(NamedTuple):
    q: jax.Array  # int8 payload
    scale: jax.Array  # () fp32


def encode_int8(g: jax.Array) -> CompressedGrad:
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return CompressedGrad(q=q, scale=scale)


def decode_int8(c: CompressedGrad) -> jax.Array:
    return c.q.astype(jnp.float32) * c.scale


def compress_tree(grads: Any) -> Any:
    return jax.tree.map(encode_int8, grads)


def decompress_tree(comp: Any) -> Any:
    return jax.tree.map(decode_int8, comp, is_leaf=lambda x: isinstance(x, CompressedGrad))


def compressed_psum_with_feedback(
    grads: Any, errors: Any, axis_name: str
) -> tuple[Any, Any]:
    """shard_map path: per-leaf int8 quantization with error feedback, then
    psum of the dequantized payloads over ``axis_name``.

    Returns (reduced grads (mean), new error residuals)."""
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        c = encode_int8(gf)
        deq = decode_int8(c)
        new_e = gf - deq  # local residual carried to next step
        red = jax.lax.psum(deq, axis_name) / n
        return red, new_e

    out = jax.tree.map(one, grads, errors)
    red = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return red, err


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
