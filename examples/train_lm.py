"""End-to-end training driver: train an LM on the synthetic Markov stream for
a few hundred steps with checkpointing and (optional) crash/restart.

  PYTHONPATH=src python examples/train_lm.py                 # ~6M params, 200 steps
  PYTHONPATH=src python examples/train_lm.py --size 100m     # ~100M params
  PYTHONPATH=src python examples/train_lm.py --crash-at 100  # then re-run to resume

The loss must decrease measurably (the stream has ~2 bits of conditional
entropy vs 8 bits marginal).
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.data.tokens import TokenPipelineConfig
from repro.train.loop import Trainer, TrainLoopConfig


def size_cfg(size: str):
    base = get_config("qwen3-0.6b")
    if size == "small":  # ~6M params
        return base.replace(d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
                            d_ff=512, vocab_size=256, num_superblocks=4,
                            vocab_round_to=16, fsdp=False,
                            param_dtype="float32", compute_dtype="float32")
    if size == "20m":
        return base.replace(d_model=256, num_heads=8, num_kv_heads=4, head_dim=32,
                            d_ff=1024, vocab_size=512, num_superblocks=8,
                            vocab_round_to=16, fsdp=False)
    if size == "100m":
        return base.replace(d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
                            d_ff=2048, vocab_size=4096, num_superblocks=16,
                            vocab_round_to=64, fsdp=False)
    raise ValueError(size)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="small", choices=["small", "20m", "100m"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="/tmp/train_lm_ckpt")
    ap.add_argument("--crash-at", type=int, default=None)
    args = ap.parse_args()

    cfg = size_cfg(args.size)
    loop = TrainLoopConfig(
        total_steps=args.steps, checkpoint_every=max(args.steps // 4, 10),
        checkpoint_dir=args.ckpt, lr=args.lr, warmup_steps=20, log_every=20,
        fail_at_step=args.crash_at,
    )
    data = TokenPipelineConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                               global_batch=args.batch, branching=4)
    trainer = Trainer(cfg, loop, data)
    out = trainer.run()
    h = out["history"]
    print(f"[train_lm] loss {h[0]:.3f} -> {h[-1]:.3f} over {len(h)} steps "
          f"(median {out['median_step_time_s']*1e3:.0f} ms/step)")
    assert h[-1] < h[0] - 0.5, "loss did not decrease enough"


if __name__ == "__main__":
    main()
