"""Quickstart: the public API in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py

Builds a reduced qwen3-family model, routes its matmuls through the Octopus
router, trains a handful of steps, checkpoints, restores, and greedy-decodes.
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced_config
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.models import LM
from repro.optim import adamw
from repro.runtime import RoutePlan
from repro.train.steps import make_train_step


def main():
    cfg = reduced_config(get_config("qwen3-0.6b"))
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # --- data + optimizer + one jit'd train step -----------------------------
    pipe = TokenPipeline(TokenPipelineConfig(vocab_size=cfg.vocab_size,
                                             seq_len=64, global_batch=8))
    opt = adamw(3e-3)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))

    for step in range(20):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state,
                                             jnp.asarray(step), batch)
        if step % 5 == 0:
            print(f"step {step:3d}  loss {float(metrics['loss']):.4f}")

    # --- checkpoint round trip ------------------------------------------------
    mgr = CheckpointManager("/tmp/quickstart_ckpt", async_writes=False)
    mgr.save({"params": params}, step=20, extra={"next_step": 20})
    restored, extra, at = mgr.restore({"params": params})
    print(f"checkpoint restored from step {at}")

    # --- greedy decode ---------------------------------------------------------
    prompt = jnp.asarray([[5, 9, 2, 7]], jnp.int32)
    cache = model.init_cache(batch=1, cache_len=32)
    # Octopus placement report for the prefill (traced abstractly, no FLOPs):
    plan = RoutePlan.trace(
        lambda p: model.prefill(p, {"tokens": prompt}, cache), restored["params"])
    print(plan.explain())
    logits, cache = jax.jit(model.prefill)(restored["params"],
                                           {"tokens": prompt}, cache)
    toks = [int(jnp.argmax(logits[0, -1, : cfg.vocab_size]))]
    for _ in range(8):
        lg, cache = jax.jit(model.decode_step)(
            restored["params"], {"tokens": jnp.asarray([[toks[-1]]])}, cache)
        toks.append(int(jnp.argmax(lg[0, 0, : cfg.vocab_size])))
    print("decoded:", toks)


if __name__ == "__main__":
    main()
