"""Continuous-batching LM serving demo (slot-based engine, per-slot lengths).

  PYTHONPATH=src python examples/serve_lm.py --arch gemma3-1b --requests 6
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import LM
from repro.serving import Request, ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    if not cfg.supports_decode:
        raise SystemExit(f"{args.arch} is encoder-only")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, ServeConfig(batch_slots=args.slots, cache_len=128))

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 6 + i % 5),
                           max_new=args.max_new))
    t0 = time.perf_counter()
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"[serve_lm] {len(done)} requests, {toks} tokens, {toks/dt:.1f} tok/s")
    for r in done:
        print(f"  rid={r.rid} out={r.out_tokens}")


if __name__ == "__main__":
    main()
