"""Drive the three pluggable-head scenarios end to end.

  PYTHONPATH=src python examples/scenarios.py [--steps N]

1. heavy-hitter: feature-only heads (no DL inference), top-k byte ranking
   over hot + cold residents;
2. DDoS: anomaly scores -> hysteresis deny controller -> rule table;
3. adversarial: a collision attack against the tracker path, with the
   eviction churn it costs.
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax

from repro.core import decisions
from repro.data.traffic import TrafficConfig, TrafficGenerator
from repro.models import paper_models
from repro.scenarios import (
    AdversarialScenario,
    DDoSScenario,
    HeavyHitterScenario,
    adversarial_config,
)
from repro.serving import OctopusPipeline, PipelineConfig


def heavy_hitter(steps: int) -> None:
    sc = HeavyHitterScenario(k=5, batch_size=64, max_ready=8, table_size=256,
                             cold_size=512, top_n=8, top_k=4, pay_bytes=4)
    gen = TrafficGenerator(TrafficConfig(
        batch_size=64, active_flows=384, table_size=256, collision_free=False,
        elephant_fraction=0.3, pay_bytes=4, seed=7))
    sc.run(gen, steps)
    s = sc.pipe.stats
    print(f"[heavy-hitter] {steps} steps  pkt/s={s.pkt_per_s:.0f}  "
          f"spilled={s.spilled} promoted={s.promoted}")
    for rank, (fid, size) in enumerate(sc.top_k(), start=1):
        print(f"  #{rank}  flow {fid & 0xFFFFFFFF:#010x}  {size} bytes")


def ddos(steps: int) -> None:
    import numpy as np

    def traffic():
        return TrafficGenerator(TrafficConfig(
            batch_size=64, active_flows=16, table_size=1024,
            elephant_fraction=1.0, elephant_pkts=(30, 60), seed=3))

    # calibrate the hysteresis band from observed score quantiles (scores are
    # controller-independent, so the probe stream is the real stream)
    probe = DDoSScenario(deny_on=0.99, deny_off=0.0, batch_size=64,
                         table_size=1024)
    probe.run(traffic(), steps)
    scores = np.array([s for _, s in probe.emissions])
    on, off = (float(q) for q in np.quantile(scores, [0.6, 0.4]))
    sc = DDoSScenario(deny_on=on, deny_off=off, batch_size=64,
                      table_size=1024)
    sc.run(traffic(), steps)
    print(f"[ddos] {steps} steps  emissions={len(sc.emissions)}  "
          f"denied={len(sc.denied)}  churn={sc.churn} (raw {sc.churn_raw})")
    for fid in sorted(sc.denied)[:5]:
        rule = sc.pipe.rules.lookup(fid)
        print(f"  flow {fid & 0xFFFFFFFF:#010x}  action={rule['action']}  "
              f"generation={rule['generation']}")


def adversarial(steps: int) -> None:
    cfg = PipelineConfig(batch_size=64, max_ready=8, table_size=256,
                         top_n=8, top_k=1, pay_bytes=4,
                         pkt_head=decisions.PassHead(),
                         flow_head=decisions.TopKHead())
    pipe = OctopusPipeline(
        paper_models.init_paper_model("mlp", jax.random.PRNGKey(0)),
        paper_models.init_paper_model("cnn", jax.random.PRNGKey(1)), cfg)
    sc = AdversarialScenario(pipe, adversarial_config(
        "collision_attack", batch_size=64, table_size=256, adv_slots=4,
        active_flows=32, pay_bytes=4, seed=0))
    stats = sc.run(steps)
    print(f"[adversarial:{sc.mode}] {steps} steps  "
          f"pkt/s={stats.pkt_per_s:.0f}  evicted={stats.evicted}  "
          f"new_flows={stats.new_flows}  (population confined to 4 slots)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="scenario family demo")
    ap.add_argument("--steps", type=int, default=16)
    args = ap.parse_args(argv)
    heavy_hitter(args.steps)
    ddos(args.steps)
    adversarial(args.steps)
    return 0


if __name__ == "__main__":
    sys.exit(main())
