"""The paper's full working procedure, end to end (all three use-cases):

  packets -> feature extractor (meta set + series + payload memories)
          -> packet path   (use-case 1: MLP intrusion detection, latency)
          -> flow paths    (use-case 2: 1D-CNN classify; use-case 3: payload
                            transformer classify; throughput)
          -> decisions     (RV-core analogue: rule-table updates)

Also demonstrates heterogeneous collaborative computing: the CNN runs once
with Octopus routing (layer 1 -> VPE path, deep layers -> AryPE path, fused
aggregation) and once as a 'straightforwardly inserted accelerator'
(everything on the systolic path, partial blocks through memory), reporting
the throughput ratio against the paper's 1.69x.

Finally the same procedure runs as one *continuous* loop: the streaming
OctopusPipeline ingests live mice/elephant traffic microbatches, carries the
flow table across steps (donated, no retrace), classifies emitted ready flows
and feeds every decision back into one rule table — the paper's steps 1 -> 6
fused into a single jit'd step.  The tracker inside the step is the
vectorized segmented update (bit-exact to the scan oracle), and with
--scan-len N the loop dispatches N microbatches per jit call (lax.scan over
the step), amortizing host round-trips — both runs are shown side by side.

With --overlap the streaming runs use the deferred-sync runtime: run()
double-buffers (chunk k+1 is staged while chunk k executes on device) and
the traffic generator is staged by the depth-2 prefetcher — bit-identical
decisions, and the report splits each dispatch into host vs exposed-device
time.

  PYTHONPATH=src python examples/innetwork_pipeline.py [--flows 400]
      [--steps 40] [--scan-len 8] [--overlap]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--flows", type=int, default=400)
    ap.add_argument("--steps", type=int, default=40,
                    help="streaming pipeline microbatches")
    ap.add_argument("--scan-len", type=int, default=8,
                    help="microbatches fused per dispatch (lax.scan chunk)")
    ap.add_argument("--num-shards", type=int, default=2,
                    help="hash-partitioned tracker lanes (1 disables the "
                         "sharded weak-scaling demo)")
    ap.add_argument("--overlap", action="store_true",
                    help="deferred-sync dispatch + prefetched traffic: "
                         "overlap host staging with device execution "
                         "(bit-identical decisions)")
    args = ap.parse_args()

    from repro.core.feature_extractor import ExtractorConfig, FeatureExtractor
    from repro.data.packets import PacketTraceConfig, synth_packet_trace
    from repro.models import paper_models
    from repro.runtime import RuntimeConfig
    from repro.serving.packet_path import FlowPath, PacketPath

    # ---------------------------------------------------------------- traffic
    trace_cfg = PacketTraceConfig(num_flows=args.flows, pkts_per_flow=20,
                                  seed=0, table_size=8192)
    packets, classes, hashes, labels = synth_packet_trace(trace_cfg)
    n_pkts = int(packets.ts.shape[0])
    print(f"[trace] {args.flows} flows, {n_pkts} packets")

    # ------------------------------------------------------- feature extract
    ex = FeatureExtractor(ExtractorConfig(table_size=8192, top_n=20, top_k=15))
    extract = jax.jit(ex.extract_segmented)
    jax.block_until_ready(extract(packets))  # compile outside the timing
    t0 = time.perf_counter()
    feats, series, sizes, payload, counts = jax.block_until_ready(extract(packets))
    dt = time.perf_counter() - t0
    print(f"[extract] segmented path: {n_pkts/dt/1e6:.2f} Mpkt/s "
          f"(paper FPGA: 31 Mpkt/s @125MHz)")

    # --------------------------------------------- use-case 1: packet MLP IDS
    mlp_params = paper_models.init_paper_model("mlp", jax.random.PRNGKey(0))
    ppath = PacketPath(mlp_params)
    ppath.warmup(batch=n_pkts)
    actions = ppath.process(packets)
    print(f"[usecase1] {n_pkts} pkts -> {int(actions.sum())} flagged; "
          f"batch latency {ppath.stats.latency_us:.1f} us "
          f"({ppath.stats.latency_us/n_pkts*1000:.1f} ns/pkt; paper: 207 ns)")

    # ------------------------------------------- use-case 2: flow CNN classify
    ready = np.asarray(counts) >= 20
    x_cnn = jnp.log1p(series[ready].astype(jnp.float32))
    cnn_params = paper_models.init_paper_model("cnn", jax.random.PRNGKey(1))
    fpath = FlowPath(cnn_params, model="cnn")
    print(fpath.route_plan(int(ready.sum())).explain())  # shared placement truth
    fpath.warmup(int(ready.sum()))
    fpath.process(x_cnn, np.flatnonzero(ready))
    kflow = fpath.stats.throughput / 1e3
    print(f"[usecase2] {int(ready.sum())} flows classified "
          f"({kflow:.1f} kflow/s; paper w/ collaborating: 90 kflow/s)")

    # collaborative ablation — the fusion half transfers to the CPU host
    # (block partials through memory vs fused accumulation); the routing half
    # only shows on the TPU target / cycle model (CPUs prefer dots over the
    # VPU-style mul+reduce), see benchmarks/bench_collaborative.py.
    fpath_fused = FlowPath(cnn_params, model="cnn",
                           config=RuntimeConfig(policy="arype_only"))
    fpath_off = FlowPath(cnn_params, model="cnn",
                         config=RuntimeConfig(policy="arype_only",
                                              fused_aggregation=False))
    for p_ in (fpath_fused, fpath_off):
        p_.warmup(int(ready.sum()))
        p_.process(x_cnn, np.flatnonzero(ready))
    ratio = fpath_off.stats.latency_us / fpath_fused.stats.latency_us
    print(f"[usecase2] fused-aggregation speedup {ratio:.2f}x "
          f"(paper's collaborative win: 1.69x; routing half: see cycle model)")

    # ------------------------------- use-case 3: payload transformer classify
    ready_k = np.asarray(counts) >= 15
    x_tf = payload[ready_k].astype(jnp.float32) / 255.0
    tf_params = paper_models.init_paper_model("transformer", jax.random.PRNGKey(2))
    tpath = FlowPath(tf_params, model="transformer")
    tpath.warmup(int(ready_k.sum()))
    tpath.process(x_tf, np.flatnonzero(ready_k))
    print(f"[usecase3] {int(ready_k.sum())} flows "
          f"({tpath.stats.throughput/1e3:.1f} kflow/s; paper: 35.7 kflow/s)")

    # -------------------------------------------------------------- decisions
    print(f"[decisions] rule tables: usecase1 gen={ppath.rules.generation} "
          f"({len(ppath.rules.rules)} rules), usecase2 gen={fpath.rules.generation}, "
          f"usecase3 gen={tpath.rules.generation}")

    # ------------------------------------------- streaming pipeline (steps 1-6)
    from repro.data.traffic import TrafficConfig, TrafficGenerator
    from repro.serving import OctopusPipeline, PipelineConfig

    def streaming(tracker: str, scan_len: int):
        from repro.data.traffic import prefetch

        pipe = OctopusPipeline(
            mlp_params, cnn_params,
            PipelineConfig(batch_size=64, max_ready=8, flow_model="cnn",
                           table_size=1024, tracker=tracker,
                           scan_len=scan_len, overlap=args.overlap))
        traffic = TrafficGenerator(TrafficConfig(
            batch_size=64, active_flows=32, elephant_fraction=0.3,
            table_size=1024, seed=0))
        pipe.warmup()
        # full chunks only, at least one (--steps below --scan-len must not
        # silently run nothing)
        steps = max(scan_len, args.steps - args.steps % scan_len)
        src = (prefetch(traffic.batches(steps), depth=2) if args.overlap
               else traffic)
        return pipe, pipe.run(src, steps=steps)

    # PR 3 baseline (order-exact scan tracker, one microbatch per dispatch)
    # vs the vectorized segmented tracker with chunked lax.scan dispatch —
    # identical decisions (differentially tested), different throughput
    pipe0, s0 = streaming("scan", 1)
    pipe, stats = streaming("segmented", max(1, args.scan_len))
    print(pipe.explain())  # both engines, one RoutePlan
    print(f"[pipeline] scan/x1 baseline: {s0.pkt_per_s/1e6:.3f} Mpkt/s, "
          f"{s0.flow_per_s/1e3:.2f} kflow/s over {s0.steps} microbatches")
    print(f"[pipeline] segmented/x{pipe.cfg.scan_len}: {stats.steps} microbatches "
          f"in {stats.dispatches} dispatches: {stats.packets} pkts "
          f"({stats.pkt_per_s/1e6:.3f} Mpkt/s; paper extraction: 31 Mpkt/s), "
          f"{stats.flows} ready flows classified "
          f"({stats.flow_per_s/1e3:.2f} kflow/s; paper: 90 kflow/s), "
          f"{stats.new_flows} established / {stats.evicted} evicted, "
          f"speedup {stats.pkt_per_s/max(s0.pkt_per_s, 1e-9):.2f}x")
    print(f"[pipeline] rule table: {len(pipe.rules.rules)} rules, "
          f"gen={pipe.rules.generation}, step latency {stats.step_us:.0f} us, "
          f"traces={pipe.trace_count} (no retrace after warmup)")
    if args.overlap:
        print(f"[pipeline] overlapped dispatch: host {stats.host_us:.0f} us "
              f"+ exposed device {stats.device_us:.0f} us per dispatch")

    # ------------------------------------- sharded lanes (weak scaling, §2.2)
    if args.num_shards > 1:
        from repro.serving import ShardedOctopusPipeline

        S, per_lane = args.num_shards, 64
        sharded = ShardedOctopusPipeline(
            mlp_params, cnn_params,
            PipelineConfig(batch_size=per_lane * S, max_ready=max(8, 4 * S),
                           flow_model="cnn", table_size=1024),
            num_shards=S, lane_batch=int(1.5 * per_lane))
        traffic = TrafficGenerator(TrafficConfig(
            batch_size=per_lane * S, active_flows=32 * S,
            elephant_fraction=0.3, table_size=1024, seed=0))
        sharded.warmup()
        st = sharded.run(traffic, steps=max(4, args.steps // 2))
        print(f"[sharded] {S} lanes ({sharded.backend}), per-lane load "
              f"{per_lane} pkts: {st.pkt_per_s/1e6:.3f} Mpkt/s aggregate "
              f"({st.packets} pkts, {st.padded} padded lane rows, "
              f"{st.dispatches} dispatches), {st.flows} flows classified")


if __name__ == "__main__":
    main()
