"""Multi-client serving demo: four concurrent seeded traffic clients drive
one OctopusService over the streaming pipeline.

Each client is an independent closed-loop arrival process (its own seed,
microbatch size, and mice/elephant mix — think four switch ports with very
different traffic), submitting packet microbatches and awaiting verdicts.
The service coalesces whatever is queued, pads to the nearest pre-warmed
bucket (masked rows — bit-exact to unpadded serving), dispatches one
fixed-shape step, and slices the verdicts back per client.

The run prints the coalescing/padding economics and a per-client p50/p99
latency table, and asserts the acceptance property: ``trace_count`` stays
flat across the whole ragged multi-client run — startup pre-warming covered
every shape the service will ever dispatch.

  PYTHONPATH=src python examples/serve_traffic.py [--requests 16]
      [--buckets 32,64,128] [--admission shed|block] [--num-shards 0]
"""
import argparse
import asyncio
import sys

sys.path.insert(0, "src")

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16,
                    help="closed-loop microbatches per client")
    ap.add_argument("--buckets", default="32,64,128",
                    help="pre-warmed batch buckets, comma-separated")
    ap.add_argument("--admission", default="shed", choices=("shed", "block"))
    ap.add_argument("--depth-budget", type=int, default=1024,
                    help="max queued packets before admission control")
    ap.add_argument("--num-shards", type=int, default=0,
                    help="hash-partitioned tracker lanes (0 = single lane)")
    args = ap.parse_args()

    from repro.data.traffic import TrafficConfig, TrafficGenerator
    from repro.models import paper_models
    from repro.serving import (
        OctopusPipeline,
        OctopusService,
        PipelineConfig,
        Rejected,
        ServiceConfig,
        ShardedOctopusPipeline,
        serve_stream,
    )

    buckets = tuple(int(b) for b in args.buckets.split(","))

    # Four ports, four very different arrival processes: staggered microbatch
    # sizes and mixes so the coalescer earns its keep.
    client_cfgs = [
        TrafficConfig(batch_size=12, elephant_fraction=0.05,  # mice port
                      active_flows=16, table_size=512, seed=101, client_id=0),
        TrafficConfig(batch_size=24, elephant_fraction=0.5,  # elephant port
                      active_flows=16, table_size=512, seed=202, client_id=1),
        TrafficConfig(batch_size=7, elephant_fraction=0.125,  # trickle port
                      active_flows=16, table_size=512, seed=303, client_id=2),
        TrafficConfig(batch_size=40, elephant_fraction=0.3,  # bursty port
                      active_flows=16, table_size=512, seed=404, client_id=3),
    ]
    gens = [TrafficGenerator(c) for c in client_cfgs]

    pipe_cfg = PipelineConfig(batch_size=buckets[-1], max_ready=8,
                              flow_model="cnn", table_size=512,
                              tracker="segmented")
    pkt_params = paper_models.init_paper_model("mlp", jax.random.PRNGKey(0))
    flow_params = paper_models.init_paper_model("cnn", jax.random.PRNGKey(1))
    if args.num_shards > 1:
        pipe = ShardedOctopusPipeline(pkt_params, flow_params, pipe_cfg,
                                      num_shards=args.num_shards)
    else:
        pipe = OctopusPipeline(pkt_params, flow_params, pipe_cfg)

    svc_cfg = ServiceConfig(buckets=buckets, admission=args.admission,
                            depth_budget=args.depth_budget)

    async def drive():
        async with OctopusService(pipe, svc_cfg) as svc:
            warm = svc.trace_count
            print(f"[warmup] {len(buckets)} buckets {buckets} pre-compiled, "
                  f"trace_count={warm}")
            outs = await asyncio.gather(*(
                serve_stream(svc, g, requests=args.requests) for g in gens))
            return svc, warm, outs

    svc, warm, outs = asyncio.run(drive())
    s = svc.stats

    shed = sum(1 for per in outs for o in per if isinstance(o, Rejected))
    print(f"[service] {s.served_requests} requests served"
          + (f", {s.shed_requests} shed" if shed else "")
          + f": {s.served} pkts in {s.dispatches} dispatches "
          f"({s.coalesced} requests coalesced, {s.padded} pad rows, "
          f"{s.pkt_per_s:.0f} pkt/s)")
    print(f"[service] queue depth high-water {s.depth_hwm} pkts "
          f"(budget {svc.cfg.depth_budget}), buffer pool "
          f"{s.pool_hits} hits / {s.pool_misses} misses")

    print(f"{'client':>6} {'batch':>5} {'reqs':>5} {'pkts':>6} "
          f"{'wait p50':>9} {'wait p99':>9} {'e2e p50':>9} {'e2e p99':>9}")
    for cfg in client_cfgs:
        c = s.clients[cfg.client_id]
        print(f"{cfg.client_id:>6} {cfg.batch_size:>5} {c.requests:>5} "
              f"{c.served:>6} {c.wait.p50:>7.0f}us {c.wait.p99:>7.0f}us "
              f"{c.e2e.p50:>7.0f}us {c.e2e.p99:>7.0f}us")
    print(f"{'all':>6} {'':>5} {s.requests:>5} {s.served:>6} "
          f"{s.wait.p50:>7.0f}us {s.wait.p99:>7.0f}us "
          f"{s.e2e.p50:>7.0f}us {s.e2e.p99:>7.0f}us")

    retraces = svc.trace_count - warm
    print(f"[service] retraces after warmup: {retraces}")
    assert retraces == 0, "ragged multi-client serving must never retrace"


if __name__ == "__main__":
    main()
