"""Streaming pipeline: differential test against a pure-Python oracle
tracker (both trackers, forced collisions included), chunked-dispatch
equivalence across scan_len, interpret-vs-compiled parity, jit cache
stability (no per-step retrace), and the combined placement report."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import assert_states_equal

from repro.core import flow_tracker as ft
from repro.data.traffic import TrafficConfig, TrafficGenerator
from repro.models import paper_models
from repro.serving import OctopusPipeline, PipelineConfig

INT_MAX = np.iinfo(np.int32).max


# ---------------------------------------------------------------------------
# Pure-Python oracle tracker (independent reimplementation of the paper's
# establish/update/evict/emit semantics — dicts and ints, no JAX)
# ---------------------------------------------------------------------------

class OracleTracker:
    def __init__(self, table_size: int, top_n: int, top_k: int, pay_bytes: int):
        self.table_size = table_size
        self.top_n = top_n
        self.top_k = top_k
        self.pay_bytes = pay_bytes
        self.slots: dict[int, dict] = {}

    def slot_of(self, tuple_hash: int) -> int:
        h = ((tuple_hash & 0xFFFFFFFF) * 0x9E3779B1) & 0xFFFFFFFF
        h ^= h >> 16
        return h % self.table_size

    def _fresh(self, tuple_hash: int) -> dict:
        return {
            "tuple_id": tuple_hash, "count": 0, "last_ts": 0,
            "flow_dur": 0, "flow_size": 0, "max_size": 0, "min_size": INT_MAX,
            "max_intv": 0, "min_intv": INT_MAX, "size_fwd": 0, "size_bwd": 0,
            "flags_acc": 0, "last_size": 0, "payload_bytes": 0, "proto": 0,
            "series": [0] * self.top_n, "sizes": [0] * self.top_n,
            "payload": [[0] * self.pay_bytes for _ in range(self.top_k)],
        }

    def process(self, pkt: dict) -> None:
        slot = self.slot_of(pkt["tuple_hash"])
        e = self.slots.get(slot)
        if e is None or e["count"] == 0 or e["tuple_id"] != pkt["tuple_hash"]:
            e = self._fresh(pkt["tuple_hash"])  # establish (evicts any stale flow)
            self.slots[slot] = e
        intv = pkt["ts"] - e["last_ts"] if e["count"] > 0 else 0
        size = pkt["size"]
        c0 = e["count"]
        e["flow_dur"] += intv
        e["flow_size"] += size
        e["max_size"] = max(e["max_size"], size)
        e["min_size"] = min(e["min_size"], size)
        e["max_intv"] = max(e["max_intv"], intv)
        e["min_intv"] = min(e["min_intv"], intv)
        e["last_ts"] = pkt["ts"]
        e["size_fwd"] += size if pkt["dir"] == 0 else 0
        e["size_bwd"] += size if pkt["dir"] == 1 else 0
        e["flags_acc"] += pkt["flags"]
        e["last_size"] = size
        e["payload_bytes"] += min(size, self.pay_bytes)
        e["proto"] = pkt["proto"]
        if c0 < self.top_n:
            e["series"][c0] = intv
            e["sizes"][c0] = size
        if c0 < self.top_k:
            e["payload"][c0] = list(pkt["payload"])
        e["count"] = c0 + 1

    def feature_word(self, e: dict) -> list:
        return [e["flow_dur"], e["count"], e["flow_size"], e["max_size"],
                e["min_size"], e["max_intv"], e["min_intv"], e["last_ts"],
                e["size_fwd"], e["size_bwd"], e["flags_acc"], e["last_size"],
                e["payload_bytes"], e["proto"], 0, 0]

    def drain_ready(self, max_ready: int) -> list:
        ready = sorted(s for s, e in self.slots.items()
                       if e["count"] >= self.top_n)[:max_ready]
        emitted = []
        for s in ready:
            e = self.slots.pop(s)
            emitted.append({"slot": s, "tuple_id": e["tuple_id"],
                            "count": e["count"],
                            "features": self.feature_word(e),
                            "series": e["series"], "sizes": e["sizes"],
                            "payload": e["payload"]})
        return emitted


def batch_as_dicts(batch: ft.PacketBatch) -> list:
    ts, size, dirs, flags, proto, thash, pay = (np.asarray(a) for a in batch)
    return [{"ts": int(ts[i]), "size": int(size[i]), "dir": int(dirs[i]),
             "flags": int(flags[i]), "proto": int(proto[i]),
             "tuple_hash": int(thash[i]), "payload": pay[i].tolist()}
            for i in range(ts.shape[0])]


@pytest.fixture(scope="module")
def params():
    return {
        "mlp": paper_models.init_paper_model("mlp", jax.random.PRNGKey(0)),
        "cnn": paper_models.init_paper_model("cnn", jax.random.PRNGKey(1)),
        "transformer": paper_models.init_paper_model("transformer",
                                                     jax.random.PRNGKey(2)),
    }


@pytest.mark.parametrize("tracker", ["segmented", "scan"])
def test_pipeline_matches_python_oracle(params, tracker):
    """Differential: every drained flow over seeded mice/elephant traffic must
    equal the pure-Python oracle exactly (int32 features, series, payload) —
    for the vectorized segmented tracker and the lax.scan oracle alike."""
    cfg = PipelineConfig(batch_size=24, max_ready=4, flow_model="transformer",
                         table_size=64, top_n=6, top_k=15, pay_bytes=16,
                         tracker=tracker)
    pipe = OctopusPipeline(params["mlp"], params["transformer"], cfg)
    gen = TrafficGenerator(TrafficConfig(
        batch_size=24, active_flows=16, elephant_fraction=0.5,
        table_size=64, seed=11, burst_prob=0.3))
    oracle = OracleTracker(64, top_n=6, top_k=15, pay_bytes=16)

    total_emitted = 0
    for _ in range(25):
        batch = gen.next_batch()
        for pkt in batch_as_dicts(batch):
            oracle.process(pkt)
        expect = oracle.drain_ready(cfg.max_ready)
        out = pipe.step(batch)
        d = out.drained
        mask = np.asarray(d.mask)
        assert int(mask.sum()) == len(expect)
        for r, want in enumerate(expect):
            assert int(d.slots[r]) == want["slot"]
            assert int(d.tuple_id[r]) == want["tuple_id"]
            assert int(d.count[r]) == want["count"]
            np.testing.assert_array_equal(
                np.asarray(d.features[r]), np.asarray(want["features"], np.int32))
            np.testing.assert_array_equal(
                np.asarray(d.series[r]), np.asarray(want["series"], np.int32))
            np.testing.assert_array_equal(
                np.asarray(d.sizes[r]), np.asarray(want["sizes"], np.int32))
            np.testing.assert_array_equal(
                np.asarray(d.payload[r]), np.asarray(want["payload"], np.int32))
        total_emitted += len(expect)
    assert total_emitted > 5  # the trace actually exercised the emission path

    # residual table state agrees too (live flows, exact int32)
    live = np.asarray(pipe.state.count) > 0
    for slot in np.flatnonzero(live):
        e = oracle.slots[int(slot)]
        assert int(pipe.state.tuple_id[slot]) == e["tuple_id"]
        np.testing.assert_array_equal(
            np.asarray(pipe.state.features[slot]),
            np.asarray(oracle.feature_word(e), np.int32))
    assert {int(s) for s in np.flatnonzero(live)} == set(oracle.slots)


def test_segmented_pipeline_matches_oracle_under_forced_collisions(params):
    """Same differential, but with random (non-collision-avoiding) traffic on
    a tiny table: in-batch slot collisions must route through the segmented
    tracker's scan fallback and still match the oracle bit-for-bit."""
    cfg = PipelineConfig(batch_size=24, max_ready=4, flow_model="transformer",
                         table_size=16, top_n=4, top_k=15, pay_bytes=16,
                         tracker="segmented")
    pipe = OctopusPipeline(params["mlp"], params["transformer"], cfg)
    gen = TrafficGenerator(TrafficConfig(
        batch_size=24, active_flows=12, elephant_fraction=0.5, table_size=16,
        seed=13, burst_prob=0.4, collision_free=False))
    oracle = OracleTracker(16, top_n=4, top_k=15, pay_bytes=16)

    saw_mixed_segment = False
    for _ in range(20):
        batch = gen.next_batch()
        dicts = batch_as_dicts(batch)
        by_slot: dict[int, set] = {}
        for pkt in dicts:
            by_slot.setdefault(oracle.slot_of(pkt["tuple_hash"]), set()).add(
                pkt["tuple_hash"])
        saw_mixed_segment |= any(len(v) > 1 for v in by_slot.values())
        for pkt in dicts:
            oracle.process(pkt)
        expect = oracle.drain_ready(cfg.max_ready)
        out = pipe.step(batch)
        d = out.drained
        assert int(np.asarray(d.mask).sum()) == len(expect)
        for r, want in enumerate(expect):
            assert int(d.slots[r]) == want["slot"]
            assert int(d.tuple_id[r]) == want["tuple_id"]
            np.testing.assert_array_equal(
                np.asarray(d.features[r]), np.asarray(want["features"], np.int32))
            np.testing.assert_array_equal(
                np.asarray(d.series[r]), np.asarray(want["series"], np.int32))
    assert saw_mixed_segment  # the stream actually exercised the fallback
    assert pipe.stats.evicted > 0  # collision churn reached the tracker

    # residual table agrees (live flows, exact int32)
    live = np.asarray(pipe.state.count) > 0
    for slot in np.flatnonzero(live):
        e = oracle.slots[int(slot)]
        assert int(pipe.state.tuple_id[slot]) == e["tuple_id"]
        np.testing.assert_array_equal(
            np.asarray(pipe.state.features[slot]),
            np.asarray(oracle.feature_word(e), np.int32))
    assert {int(s) for s in np.flatnonzero(live)} == set(oracle.slots)


def test_chunked_dispatch_matches_per_step(params):
    """scan_len > 1 must change only the dispatch granularity: final state,
    rule table and event counters all equal the per-step run, with one trace
    and steps/scan_len device round-trips."""
    def traffic():
        return TrafficGenerator(TrafficConfig(
            batch_size=16, active_flows=12, elephant_fraction=0.5,
            table_size=128, seed=3))

    ref = OctopusPipeline(params["mlp"], params["cnn"], PipelineConfig(
        batch_size=16, max_ready=4, flow_model="cnn", table_size=128))
    ref.run(traffic(), steps=12)

    chunked = OctopusPipeline(params["mlp"], params["cnn"], PipelineConfig(
        batch_size=16, max_ready=4, flow_model="cnn", table_size=128,
        scan_len=4))
    chunked.warmup()
    chunked.run(traffic(), steps=12)

    assert_states_equal(ref.state, chunked.state)
    assert chunked.rules.rules == ref.rules.rules
    assert (chunked.stats.flows, chunked.stats.new_flows, chunked.stats.evicted) \
        == (ref.stats.flows, ref.stats.new_flows, ref.stats.evicted)
    assert chunked.stats.steps == 12 and chunked.stats.dispatches == 3
    assert ref.stats.dispatches == 12
    assert chunked.trace_count == 1  # one trace across the multi-chunk run


def test_flow_straddling_chunk_boundary_drains_identically(params):
    """A flow whose packets split across two scanned chunks must carry its
    state through the scan and drain exactly once, in the right step slot."""
    cfg = PipelineConfig(batch_size=4, max_ready=2, flow_model="transformer",
                         table_size=16, top_n=8, top_k=15, pay_bytes=16,
                         scan_len=2)
    pipe = OctopusPipeline(params["mlp"], params["transformer"], cfg)
    pipe.warmup()
    assert pipe.trace_count == 1

    h = 77  # one flow; its 8 packets arrive over two 2-step chunks

    def batch(ts0):
        return ft.PacketBatch(
            ts=jnp.asarray([ts0 + 10 * i for i in range(4)], jnp.int32),
            size=jnp.full((4,), 100, jnp.int32),
            dir=jnp.zeros((4,), jnp.int32), flags=jnp.zeros((4,), jnp.int32),
            proto=jnp.zeros((4,), jnp.int32),
            tuple_hash=jnp.full((4,), h, jnp.int32),
            payload=jnp.zeros((4, 16), jnp.int32))

    # quiet filler: one-packet mice flows that never reach top_n and never
    # hash onto flow h's slot (they must not evict it mid-test)
    h_slot = ft.hash_slot_scalar(h, cfg.table_size)
    fillers = [t for t in range(1000, 1400)
               if ft.hash_slot_scalar(t, cfg.table_size) != h_slot]

    def quiet(ts0, salt):
        return ft.PacketBatch(
            ts=jnp.full((4,), ts0, jnp.int32),
            size=jnp.full((4,), 60, jnp.int32),
            dir=jnp.zeros((4,), jnp.int32), flags=jnp.zeros((4,), jnp.int32),
            proto=jnp.zeros((4,), jnp.int32),
            tuple_hash=jnp.asarray(fillers[4 * salt : 4 * salt + 4], jnp.int32),
            payload=jnp.zeros((4, 16), jnp.int32))

    out1 = pipe.step_many([batch(100), quiet(135, 0)])  # 4 of 8 packets
    assert int(np.asarray(out1.drained.mask).sum()) == 0
    out2 = pipe.step_many([quiet(138, 1), batch(140)])  # remaining 4 cross top_n
    masks = np.asarray(out2.drained.mask)  # (scan_len, max_ready)
    assert masks[0].sum() == 0 and masks[1].sum() == 1  # drains in step 2
    drained_row = int(np.flatnonzero(masks[1])[0])
    assert int(out2.drained.tuple_id[1, drained_row]) == h
    assert int(out2.drained.count[1, drained_row]) == 8
    # interval series crosses both chunk boundaries seamlessly
    assert np.asarray(
        out2.drained.series[1, drained_row])[:8].tolist() == [0] + [10] * 7
    assert pipe.trace_count == 1
    assert pipe.stats.steps == 4 and pipe.stats.dispatches == 2


def test_step_many_rejects_wrong_chunk_length(params):
    cfg = PipelineConfig(batch_size=4, max_ready=2, flow_model="cnn",
                         table_size=16, scan_len=3)
    pipe = OctopusPipeline(params["mlp"], params["cnn"], cfg)
    with pytest.raises(ValueError, match="scan_len"):
        pipe.step_many([pipe._zero_batch()] * 2)


def test_interpret_vs_compiled_step_parity(params):
    """One pipeline step must produce identical state + outputs whether it is
    compiled (jit) or evaluated eagerly (jax.disable_jit)."""
    cfg = PipelineConfig(batch_size=16, max_ready=4, flow_model="transformer",
                         table_size=32, top_n=4, top_k=15, pay_bytes=16)
    pipe = OctopusPipeline(params["mlp"], params["transformer"], cfg)
    batch = TrafficGenerator(TrafficConfig(
        batch_size=16, active_flows=8, elephant_fraction=0.5, table_size=32,
        seed=5)).next_batch()
    state = ft.init_state(cfg.table_size, cfg.top_n, cfg.top_k, cfg.pay_bytes)

    with jax.disable_jit():
        s_eager, o_eager = pipe._step(state, batch)
    s_jit, o_jit = jax.jit(pipe._step)(state, batch)  # fresh jit, no donation

    for a, b in zip(jax.tree.leaves((s_eager, o_eager)),
                    jax.tree.leaves((s_jit, o_jit))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_no_retrace_after_warmup_and_state_sustained(params):
    """The jit cache must hold across microbatches (one trace total) while
    TrackerState accumulates — a flow spread over several batches still
    reaches the ready threshold."""
    cfg = PipelineConfig(batch_size=4, max_ready=2, flow_model="transformer",
                         table_size=16, top_n=8, top_k=15, pay_bytes=16)
    pipe = OctopusPipeline(params["mlp"], params["transformer"], cfg)
    pipe.warmup()
    assert pipe.trace_count == 1

    h = 77  # one flow, its 8 packets split across two microbatches
    def batch(ts0):
        return ft.PacketBatch(
            ts=jnp.asarray([ts0 + 10 * i for i in range(4)], jnp.int32),
            size=jnp.full((4,), 100, jnp.int32),
            dir=jnp.zeros((4,), jnp.int32), flags=jnp.zeros((4,), jnp.int32),
            proto=jnp.zeros((4,), jnp.int32),
            tuple_hash=jnp.full((4,), h, jnp.int32),
            payload=jnp.zeros((4, 16), jnp.int32))

    out1 = pipe.step(batch(100))
    assert int(np.asarray(out1.drained.mask).sum()) == 0  # 4 < top_n
    out2 = pipe.step(batch(140))
    mask = np.asarray(out2.drained.mask)
    assert int(mask.sum()) == 1  # state carried: 4 + 4 == top_n
    assert int(out2.drained.tuple_id[0]) == h
    assert int(out2.drained.count[0]) == 8
    # interval series crosses the batch boundary seamlessly
    assert np.asarray(out2.drained.series[0])[:8].tolist() == [0] + [10] * 7
    assert pipe.trace_count == 1  # cache hits only: no per-step retrace
    assert pipe.stats.steps == 2 and pipe.stats.packets == 8 and pipe.stats.flows == 1


def test_no_retrace_extends_to_sharded_step(params):
    """The jit-cache-stability contract covers the sharded dispatch too: the
    multi-lane step shares `_lane_core` with the single-lane `_step_core`,
    compiles once at warmup, and every later step (including the donated
    per-shard TrackerState carry) is a cache hit."""
    from repro.serving import ShardedOctopusPipeline

    cfg = PipelineConfig(batch_size=4, max_ready=2, flow_model="transformer",
                         table_size=16, top_n=8, top_k=15, pay_bytes=16)
    sh = ShardedOctopusPipeline(params["mlp"], params["transformer"], cfg,
                                num_shards=2)
    sh.warmup()
    assert sh.trace_count == 1

    h = 77
    def batch(ts0):
        return ft.PacketBatch(
            ts=jnp.asarray([ts0 + 10 * i for i in range(4)], jnp.int32),
            size=jnp.full((4,), 100, jnp.int32),
            dir=jnp.zeros((4,), jnp.int32), flags=jnp.zeros((4,), jnp.int32),
            proto=jnp.zeros((4,), jnp.int32),
            tuple_hash=jnp.full((4,), h, jnp.int32),
            payload=jnp.zeros((4, 16), jnp.int32))

    out1 = sh.step(batch(100))
    assert int(np.asarray(out1.drained.mask).sum()) == 0
    out2 = sh.step(batch(140))  # per-shard state carried across dispatches
    assert int(np.asarray(out2.drained.mask).sum()) == 1
    assert sh.trace_count == 1  # no per-step retrace on the sharded path
    assert sh.stats.steps == 2 and sh.stats.dispatches == 2


def test_explain_reports_both_engines_from_one_plan(params):
    cfg = PipelineConfig(batch_size=32, max_ready=8, flow_model="cnn",
                         table_size=128)
    pipe = OctopusPipeline(params["mlp"], params["cnn"], cfg)
    plan = pipe.plan()
    names = [s.name for s in plan.steps]
    assert names[:4] == ["pkt/w0", "pkt/w1", "pkt/w2", "pkt/w3"]
    assert "flow/conv1" in names and "flow/linear" in names
    assert len(plan.scoped("pkt")) == 4 and len(plan.scoped("flow")) == 5
    # a sub-plan keeps the shared config (single placement truth)
    assert plan.scoped("flow").config is plan.config
    text = pipe.explain()
    assert "packet-engine (4 matmuls)" in text
    assert "flow-engine (5 matmuls)" in text
    assert "RoutePlan: 9 matmuls" in text  # one plan covers both


def test_pipeline_run_and_reset(params):
    cfg = PipelineConfig(batch_size=16, max_ready=4, flow_model="cnn",
                         table_size=128)
    pipe = OctopusPipeline(params["mlp"], params["cnn"], cfg)
    gen = TrafficGenerator(TrafficConfig(batch_size=16, active_flows=12,
                                         elephant_fraction=0.5, table_size=128,
                                         seed=3))
    stats = pipe.run(gen, steps=12)
    assert stats.steps == 12 and stats.packets == 12 * 16
    assert stats.flows > 0 and stats.flow_per_s > 0 and stats.pkt_per_s > 0
    assert pipe.rules.generation > 0 and len(pipe.rules.rules) > 0
    # rule table carries flow-class verdicts for emitted flows
    assert any(r["class"] >= 0 for r in pipe.rules.rules.values())

    pipe.reset()
    assert pipe.stats.steps == 0 and len(pipe.rules.rules) == 0
    assert int(np.asarray(pipe.state.count).sum()) == 0
    assert pipe.trace_count == 1  # reset keeps the compiled step


def test_pipeline_config_validation():
    with pytest.raises(ValueError):
        PipelineConfig(flow_model="rnn")
    with pytest.raises(ValueError):
        PipelineConfig(flow_model="cnn", top_n=7)  # cnn needs CNN_SEQ
    with pytest.raises(ValueError):
        PipelineConfig(flow_model="transformer", top_k=3)
    with pytest.raises(ValueError):
        PipelineConfig(max_ready=0)
    with pytest.raises(ValueError):
        PipelineConfig(tracker="bogus")
    with pytest.raises(ValueError):
        PipelineConfig(scan_len=0)
    # transformer frees top_n from the CNN's sequence length
    assert PipelineConfig(flow_model="transformer", top_n=4).top_n == 4


def test_step_rejects_wrong_batch_size(params):
    cfg = PipelineConfig(batch_size=8, max_ready=2, flow_model="cnn",
                         table_size=64)
    pipe = OctopusPipeline(params["mlp"], params["cnn"], cfg)
    small = TrafficGenerator(TrafficConfig(batch_size=4, table_size=64,
                                           active_flows=4)).next_batch()
    with pytest.raises(ValueError, match="batch_size"):
        pipe.step(small)
