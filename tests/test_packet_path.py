"""Serving-path units: the engine cores the pipeline composes, the thin
standalone wrappers, and the PathStats empty-batch regression."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import paper_models
from repro.runtime import RuntimeConfig
from repro.serving.packet_path import (
    FlowEngine,
    FlowPath,
    PacketEngine,
    PacketPath,
    PathStats,
)


@pytest.fixture(scope="module")
def mlp_params():
    return paper_models.init_paper_model("mlp", jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def cnn_params():
    return paper_models.init_paper_model("cnn", jax.random.PRNGKey(1))


def make_packets(n: int):
    from repro.core.flow_tracker import PacketBatch

    return PacketBatch(
        ts=jnp.arange(n, dtype=jnp.int32), size=jnp.full((n,), 100, jnp.int32),
        dir=jnp.zeros((n,), jnp.int32), flags=jnp.zeros((n,), jnp.int32),
        proto=jnp.zeros((n,), jnp.int32),
        tuple_hash=jnp.arange(1, n + 1, dtype=jnp.int32),
        payload=jnp.zeros((n, 16), jnp.int32))


# ------------------------------------------------------------------ PathStats

def test_pathstats_empty_is_explicit_nan_and_zero():
    s = PathStats()
    assert math.isnan(s.latency_us)  # not a fake 0.0us latency
    assert s.throughput == 0.0


def test_pathstats_record_drops_empty_calls():
    s = PathStats()
    s.record(1e-3, 10)
    lat = s.latency_us
    s.record(5.0, 0)  # a stray empty submit must not skew the mean
    assert s.latency_us == lat
    assert s.calls == 1 and s.items == 10


def test_empty_batch_submit_does_not_skew_stats(mlp_params, cnn_params):
    p = PacketPath(mlp_params)
    out = p.process(make_packets(0))
    assert out.shape == (0,)
    assert p.stats.calls == 0 and math.isnan(p.stats.latency_us)
    assert p.rules.generation == 0  # no rule churn either

    f = FlowPath(cnn_params, model="cnn")
    cls = f.process(jnp.zeros((0, paper_models.CNN_SEQ), jnp.float32),
                    np.zeros((0,), np.int32))
    assert cls.shape == (0,)
    assert f.stats.calls == 0 and math.isnan(f.stats.latency_us)

    # a real batch afterwards produces untainted per-call latency
    p.process(make_packets(4))
    assert p.stats.calls == 1 and p.stats.items == 4
    assert p.stats.latency_us > 0 and p.stats.throughput > 0


# -------------------------------------------------------------- PipelineStats

def test_pipeline_stats_counts_packets_per_actual_dispatch():
    """A fused chunk advances several steps in ONE dispatch; a sharded step
    can issue several dispatches for ONE step.  The counters must keep those
    axes apart so pkt_per_s / dispatch_us stay honest."""
    from repro.serving import PipelineStats

    s = PipelineStats()
    s.record_dispatch(0.5, packets=4 * 32, steps=4)  # one scan_len=4 chunk
    assert (s.steps, s.dispatches, s.packets) == (4, 1, 128)
    s.record_dispatch(0.5, packets=32, dispatches=3)  # one 3-round sharded step
    assert (s.steps, s.dispatches, s.packets) == (5, 4, 160)
    assert s.pkt_per_s == 160 / 1.0
    assert s.step_us == 1.0 / 5 * 1e6
    assert s.dispatch_us == 1.0 / 4 * 1e6


def test_pipeline_stats_padding_is_not_throughput():
    """Sharded lanes move padded rows; those must never inflate pkt_per_s
    (the wire only carried the real packets)."""
    from repro.serving import PipelineStats

    s = PipelineStats()
    s.record_dispatch(1.0, packets=32, padded=96)  # 4 lanes x 32 capacity
    assert s.packets == 32 and s.padded == 96
    assert s.pkt_per_s == 32.0


def test_pipeline_stats_empty_is_nan_and_zero():
    from repro.serving import PipelineStats

    s = PipelineStats()
    assert s.pkt_per_s == 0.0 and s.flow_per_s == 0.0
    assert math.isnan(s.step_us) and math.isnan(s.dispatch_us)
    # idle percentiles are nan too (the latency_us convention) — never a
    # fake 0us tail
    assert math.isnan(s.p50_us) and math.isnan(s.p99_us)


def test_pipeline_stats_percentiles_from_dispatch_samples():
    from repro.serving import PipelineStats

    s = PipelineStats()
    for dt_ms in (1.0, 2.0, 3.0, 100.0):  # one slow outlier
        s.record_dispatch(dt_ms * 1e-3, packets=32)
    assert s.p50_us == pytest.approx(2500.0)  # median of 1/2/3/100 ms
    assert s.p99_us > 90_000.0  # the tail sees the outlier
    assert s.dispatch_us == pytest.approx(26_500.0)  # the mean hides neither


def test_latency_reservoir_is_bounded_ring():
    from repro.serving import LatencyReservoir

    r = LatencyReservoir(capacity=8)
    assert math.isnan(r.p50) and math.isnan(r.percentile(99.0)) and len(r) == 0
    for v in range(100):
        r.add(float(v))
    # bounded memory: only the last `capacity` samples are retained
    assert len(r) == 8 and r.total_added == 100
    assert r.p50 == pytest.approx(95.5)  # median of 92..99
    assert r.percentile(0.0) == 92.0 and r.percentile(100.0) == 99.0
    with pytest.raises(ValueError, match="capacity"):
        LatencyReservoir(capacity=0)


# -------------------------------------------------------------------- engines

def test_engines_are_pure_cores(mlp_params, cnn_params):
    pe = PacketEngine(mlp_params, config=RuntimeConfig(policy="vpe_only"))
    x = jnp.ones((3, pe.feature_dim), jnp.float32)
    logits = pe.fn(mlp_params, x)
    assert logits.shape == (3, 2)
    # jit-composable (this is exactly what the pipeline does)
    np.testing.assert_allclose(np.asarray(jax.jit(pe.fn)(mlp_params, x)),
                               np.asarray(logits), rtol=1e-6)

    fe = FlowEngine(cnn_params, "cnn")
    series = jnp.ones((2, paper_models.CNN_SEQ), jnp.int32)
    payload = jnp.ones((2, paper_models.TF_PKTS, paper_models.TF_BYTES), jnp.int32)
    assert fe.prep(series, payload).shape == (2, paper_models.CNN_SEQ)
    assert fe.fn(cnn_params, fe.prep(series, payload)).shape == (2, paper_models.CNN_CLASSES)


def test_flow_engine_rejects_unknown_model(cnn_params):
    with pytest.raises(ValueError, match="model"):
        FlowEngine(cnn_params, "rnn")


def test_wrappers_share_engine_state(mlp_params, cnn_params):
    cfg = RuntimeConfig(policy="arype_only")
    p = PacketPath(mlp_params, config=cfg)
    assert p.runtime is p.engine.runtime and p.runtime.policy == "arype_only"
    assert p.params is mlp_params
    plan = p.route_plan(batch=8)
    assert all(s.engine == "arype" for s in plan.steps)

    f = FlowPath(cnn_params, model="cnn", config=cfg)
    assert f.model == "cnn" and f.runtime.policy == "arype_only"
    assert len(f.route_plan(flows=10)) == 5


# --------------------------------------------------- host/device time split

def test_path_stats_host_device_split_accumulates():
    s = PathStats()
    assert math.isnan(s.host_us) and math.isnan(s.device_us)
    s.record(1.0, 10, host_s=0.25, device_s=0.75)
    s.record(1.0, 10, host_s=0.5, device_s=0.5)
    assert s.host_s == pytest.approx(0.75) and s.device_s == pytest.approx(1.25)
    assert s.host_us == pytest.approx(0.375e6)
    assert s.device_us == pytest.approx(0.625e6)
    # callers that don't measure the split leave it 0 — totals still correct
    s2 = PathStats()
    s2.record(2.0, 4)
    assert s2.latency_us == pytest.approx(2e6)
    assert s2.host_s == 0.0 and s2.device_s == 0.0


def test_path_process_records_split(mlp_params):
    p = PacketPath(mlp_params)
    p.warmup(batch=8)
    p.process(make_packets(8))
    s = p.stats
    assert s.calls == 1
    assert s.total_s == pytest.approx(s.host_s + s.device_s)
    assert math.isfinite(s.host_us) and math.isfinite(s.device_us)


def test_pipeline_stats_host_device_split():
    from repro.serving import PipelineStats

    s = PipelineStats()
    assert math.isnan(s.host_us) and math.isnan(s.device_us)
    s.record_dispatch(1.0, packets=32, host_s=0.6, device_s=0.4)
    s.record_dispatch(1.0, packets=32, host_s=0.2, device_s=0.8)
    assert s.host_s == pytest.approx(0.8) and s.device_s == pytest.approx(1.2)
    assert s.host_us == pytest.approx(0.4e6)
    assert s.device_us == pytest.approx(0.6e6)
    assert s.total_s == pytest.approx(s.host_s + s.device_s)
