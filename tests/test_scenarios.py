"""Per-scenario harnesses (PR 9): a dict-based differential oracle for the
heavy-hitter scenario (exact top-k equality, including across hot->cold
spill/promote), seeded + property tests for the DDoS feedback loop (denied
flows are marked deny in the rule table within one dispatch; hysteresis churn
never exceeds a bare threshold's), and adversarial-traffic harnesses
(determinism, conservation, and collision-attack bit-exactness against the
pure-Python tracker oracle while every batch forces the worst-case in-batch
collision path)."""
import jax
import numpy as np
import pytest
from conftest import assert_states_equal
from hypothesis_compat import given, settings, st
from test_cold_store import TwoLevelOracle, assert_drained_equal
from test_pipeline import OracleTracker, batch_as_dicts

from repro.core import decisions, flow_tracker as ft
from repro.data.traffic import TrafficConfig, TrafficGenerator, shard_of
from repro.models import paper_models
from repro.scenarios import (
    AdversarialScenario,
    DDoSScenario,
    HeavyHitterScenario,
    HysteresisController,
    adversarial_config,
    top_k_flows,
)
from repro.serving import OctopusPipeline, PipelineConfig


@pytest.fixture(scope="module")
def params():
    return {
        "mlp": paper_models.init_paper_model("mlp", jax.random.PRNGKey(0)),
        "cnn": paper_models.init_paper_model("cnn", jax.random.PRNGKey(1)),
    }


def oracle_counters(o: OracleTracker) -> dict[int, int]:
    """{tuple_hash: byte count} over the oracle's resident flows — hot slots
    plus (for the two-level oracle) the cold dict."""
    c = {e["tuple_id"]: e["flow_size"] for e in o.slots.values()
         if e["count"] > 0}
    for e in getattr(o, "cold", {}).values():
        c[e["tuple_id"]] = e["flow_size"]
    return c


# ---------------------------------------------------------------------------
# Heavy hitter / top-k: exact differential against the dict oracle
# ---------------------------------------------------------------------------

def test_top_k_flows_total_order():
    counters = {7: 100, 3: 100, 9: 50, 1: 200}
    assert top_k_flows(counters, 3) == [(1, 200), (3, 100), (7, 100)]
    assert top_k_flows(counters, 99) == [(1, 200), (3, 100), (7, 100), (9, 50)]
    assert top_k_flows({}, 4) == []


@pytest.mark.parametrize("tracker", ["segmented", "scan"])
def test_heavy_hitter_matches_oracle_with_cold(tracker):
    """Per-step top-k equality vs the two-level dict oracle, with a cold
    store small enough that spill AND promote both fire (a heavy hitter that
    loses its hot slot keeps its byte count in the ranking)."""
    sc = HeavyHitterScenario(
        k=6, batch_size=32, max_ready=4, table_size=32, cold_size=64,
        top_n=8, top_k=4, pay_bytes=4, tracker=tracker)
    oracle = TwoLevelOracle(32, 64, 8, 4, 4)
    gen = TrafficGenerator(TrafficConfig(
        batch_size=32, active_flows=48, table_size=32, collision_free=False,
        pay_bytes=4, seed=3))
    for batch in gen.batches(14):
        sc.step(batch)
        oracle.step_batch(batch_as_dicts(batch), 4)
        assert sc.counters() == oracle_counters(oracle)
        assert sc.top_k() == top_k_flows(oracle_counters(oracle), 6)
    assert sc.pipe.stats.spilled > 0, "harness must exercise spill"
    assert sc.pipe.stats.promoted > 0, "harness must exercise promote"


@pytest.mark.parametrize("tracker", ["segmented", "scan"])
@pytest.mark.parametrize("num_shards", [1, 2, 4])
def test_heavy_hitter_sharded_matches_oracle(num_shards, tracker):
    """Sharded top-k vs the single-table oracle under collision-attack
    traffic pinned to shard 0 (the exactness precondition: same-hot-slot
    flows share a lane, and adv_slots <= lane_ready so no lane backlogs)."""
    sc = HeavyHitterScenario(
        k=4, num_shards=num_shards, batch_size=16, max_ready=8,
        table_size=64, cold_size=128, top_n=6, top_k=4, pay_bytes=4,
        tracker=tracker)
    oracle = TwoLevelOracle(64, 128, 6, 4, 4)
    gen = TrafficGenerator(adversarial_config(
        "collision_attack", batch_size=16, table_size=64, active_flows=10,
        adv_slots=2, adv_shards=num_shards, pay_bytes=4, seed=5))
    for batch in gen.batches(10):
        sc.step(batch)
        oracle.step_batch(batch_as_dicts(batch), 8)
        assert sc.top_k() == top_k_flows(oracle_counters(oracle), 4)
    assert sc.pipe.stats.packets == 10 * 16


def test_heavy_hitter_run_snapshots():
    sc = HeavyHitterScenario(k=3, batch_size=16, max_ready=4, table_size=32,
                             top_n=8, top_k=4, pay_bytes=4)
    gen = TrafficGenerator(TrafficConfig(batch_size=16, active_flows=8,
                                         table_size=32, pay_bytes=4, seed=1))
    snaps = sc.run(gen, 5)
    assert len(snaps) == 5
    assert all(len(s) <= 3 for s in snaps)
    assert snaps[-1] == sc.top_k()


def test_heavy_hitter_rejects_bad_args():
    with pytest.raises(ValueError, match="k must be positive"):
        HeavyHitterScenario(k=0)
    with pytest.raises(ValueError, match="fixed by the scenario"):
        HeavyHitterScenario(k=2, flow_head=None)


# ---------------------------------------------------------------------------
# DDoS: deny feedback + hysteresis properties
# ---------------------------------------------------------------------------

def _ddos_traffic(seed=7):
    return TrafficGenerator(TrafficConfig(
        batch_size=32, active_flows=8, table_size=256, elephant_fraction=1.0,
        elephant_pkts=(30, 60), seed=seed))


def _calibrated_thresholds(steps=20, seed=7):
    """Run a probe scenario (thresholds parked at the extremes) and pick the
    deny band from the observed score quantiles, so the real run denies some
    flows and releases others regardless of the random-init model's score
    range."""
    probe = DDoSScenario(deny_on=0.99, deny_off=0.0)
    probe.run(_ddos_traffic(seed), steps)
    scores = np.array([s for _, s in probe.emissions])
    assert scores.size >= 8, "probe traffic must produce emissions"
    on, off = np.quantile(scores, [0.6, 0.4])
    assert off < on, "score distribution must have spread for the harness"
    return float(on), float(off), probe.emissions


def test_ddos_denies_feed_back_into_rule_table():
    on, off, probe_emissions = _calibrated_thresholds()
    sc = DDoSScenario(deny_on=on, deny_off=off)
    sc.run(_ddos_traffic(), 20)
    # scores are controller-independent: same traffic -> same emissions
    assert sc.emissions == probe_emissions
    # the band was calibrated to split the population
    assert len(sc.denied) >= 1
    assert len({f for f, _ in sc.emissions}) > len(sc.denied)
    # every currently-denied flow is marked deny in the switch-facing table
    for fid in sc.denied:
        assert sc.pipe.rules.lookup(fid)["action"] == "deny"
    # hysteresis writes no more often than a bare threshold would
    assert sc.churn <= sc.churn_raw
    # replaying the emission history through a fresh controller reproduces
    # the scenario's controller state exactly (absorb order is step order)
    replay = HysteresisController(on, off)
    for fid, s in sc.emissions:
        replay.observe(fid, s)
    assert replay.denied == sc.denied
    assert (replay.churn, replay.churn_raw) == (sc.churn, sc.churn_raw)


def test_ddos_deny_visible_within_scan_len():
    """With scan_len > 1 the controller only sees scores once per chunk —
    after every dispatch, each denied flow must already be pinned to deny in
    the rule table (the re-assertion bounds the lag to one dispatch)."""
    on, off, _ = _calibrated_thresholds()
    sc = DDoSScenario(deny_on=on, deny_off=off, scan_len=4)
    gen = _ddos_traffic()
    for _ in range(5):
        sc.run(gen, 4)  # one scan_len chunk per call
        for fid in sc.denied:
            assert sc.pipe.rules.lookup(fid)["action"] == "deny"
    assert sc.pipe.stats.packets == 5 * 4 * 32
    assert len(sc.denied) >= 1


def test_ddos_sharded_controller_sees_all_lanes():
    on, off, _ = _calibrated_thresholds(steps=12)
    sc = DDoSScenario(deny_on=on, deny_off=off, num_shards=2)
    sc.run(_ddos_traffic(), 12)
    assert len(sc.emissions) >= 1
    for fid in sc.denied:
        assert sc.pipe.rules.lookup(fid)["action"] == "deny"
    assert sc.churn <= sc.churn_raw


def test_ddos_rejects_bad_band():
    with pytest.raises(ValueError, match="deny_off"):
        DDoSScenario(deny_on=0.5, deny_off=0.5)
    with pytest.raises(ValueError, match="deny_off"):
        HysteresisController(0.4, 0.6)
    with pytest.raises(ValueError, match="fixed by the scenario"):
        DDoSScenario(flow_head=None)


@settings(max_examples=60, deadline=None)
@given(events=st.lists(st.tuples(st.integers(0, 5), st.floats(0.0, 1.0)),
                       max_size=80),
       t0=st.floats(0.0, 1.0), t1=st.floats(0.0, 1.0))
def test_hysteresis_churn_never_exceeds_raw(events, t0, t1):
    off, on = sorted((t0, t1))
    if not off < on:
        return  # degenerate draw: the controller requires a strict band
    ctl = HysteresisController(on, off)
    for fid, s in events:
        ctl.observe(fid, s)
    assert ctl.churn <= ctl.churn_raw
    # a denied flow has crossed deny_on at least once, so the shadow has
    # seen it too; flows parked inside the band never entered either set
    assert ctl.denied <= {f for f, s in events if s >= on}


@settings(max_examples=40, deadline=None)
@given(scores=st.lists(st.floats(0.0, 1.0), max_size=60))
def test_hysteresis_single_flow_writes_bounded(scores):
    """One flow flapping across the band: the denied set flips at most once
    per genuine on->off traversal, never once per sample."""
    ctl = HysteresisController(0.7, 0.3)
    for s in scores:
        ctl.observe(0, s)
    assert ctl.churn <= ctl.churn_raw
    assert ctl.churn <= len(scores)
    assert (0 in ctl.denied) == (ctl.churn % 2 == 1)


# ---------------------------------------------------------------------------
# Adversarial traffic: determinism, conservation, collision bit-exactness
# ---------------------------------------------------------------------------

def _batches_equal(a, b):
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("mode",
                         ["flash_crowd", "elephant_storm", "collision_attack"])
def test_adversarial_modes_deterministic(mode):
    cfg = adversarial_config(mode, batch_size=16, seed=9)
    g1, g2 = TrafficGenerator(cfg), TrafficGenerator(cfg)
    for _ in range(6):
        _batches_equal(g1.next_batch(), g2.next_batch())


def test_flash_crowd_periodic_fresh_flows():
    cfg = adversarial_config("flash_crowd", batch_size=16, adv_period=3,
                             seed=2)
    gen = TrafficGenerator(cfg)
    for i, batch in enumerate(gen.batches(9), start=1):
        hashes = np.asarray(batch.tuple_hash)
        if i % 3 == 0:  # crowd batch: all fresh one-packet flows
            assert len(set(hashes.tolist())) == 16
            assert np.all(np.asarray(batch.flags) == 2)
        else:  # steady-state batches revisit the live population
            assert len(set(hashes.tolist())) < 16


def test_elephant_storm_every_emission_is_a_burst():
    cfg = adversarial_config("elephant_storm", batch_size=32, burst_len=8,
                             seed=4)
    gen = TrafficGenerator(cfg)
    batch = gen.next_batch()
    hashes = np.asarray(batch.tuple_hash)
    # maximal bursts: runs of burst_len consecutive same-flow packets
    # (the last run of the batch and flow exhaustion may truncate)
    runs, n = [], 1
    for a, b in zip(hashes[:-1], hashes[1:]):
        if a == b:
            n += 1
        else:
            runs.append(n)
            n = 1
    runs.append(n)
    assert max(runs) == 8
    assert np.mean(runs) > 2.0


def test_collision_attack_confines_slots_and_collides_every_batch():
    cfg = adversarial_config("collision_attack", batch_size=16,
                             table_size=64, adv_slots=2, seed=6)
    gen = TrafficGenerator(cfg)
    for batch in gen.batches(6):
        slots = [ft.hash_slot_scalar(int(h), 64)
                 for h in np.asarray(batch.tuple_hash)]
        assert max(slots) < 2  # whole population in the targeted slots
        # worst case for the segmented tracker: in-batch slot collisions
        assert len(set(slots)) < len(slots)


def test_collision_attack_shard_pinning():
    cfg = adversarial_config("collision_attack", batch_size=16,
                             table_size=64, adv_slots=2, adv_shards=4, seed=6)
    gen = TrafficGenerator(cfg)
    for batch in gen.batches(4):
        for h in np.asarray(batch.tuple_hash).tolist():
            assert shard_of(h, 4) == 0


@pytest.mark.parametrize("tracker", ["segmented", "scan"])
def test_collision_attack_bit_exact_vs_oracle(tracker, params):
    """Collision-attack batches force the segmented tracker's worst-case
    in-batch collision fallback every step — the states and drained rows
    must stay bit-exact against the per-packet pure-Python oracle."""
    cfg = PipelineConfig(batch_size=16, max_ready=4, table_size=16,
                         top_n=6, top_k=4, pay_bytes=4, tracker=tracker,
                         flow_head=decisions.TopKHead())
    pipe = OctopusPipeline(params["mlp"], params["cnn"], cfg)
    oracle = OracleTracker(16, 6, 4, 4)
    gen = TrafficGenerator(adversarial_config(
        "collision_attack", batch_size=16, table_size=16, adv_slots=2,
        active_flows=8, pay_bytes=4, seed=11))
    for batch in gen.batches(8):
        out = pipe.step(batch)
        for pkt in batch_as_dicts(batch):
            oracle.process(pkt)
        assert_drained_equal(out, oracle.drain_ready(4), oracle)
    assert pipe.stats.evicted > 0, "attack must cause eviction churn"


def test_collision_attack_trackers_agree(params):
    cfgs = {t: PipelineConfig(batch_size=16, max_ready=4, table_size=16,
                              top_n=6, top_k=4, pay_bytes=4, tracker=t,
                              flow_head=decisions.TopKHead())
            for t in ("segmented", "scan")}
    pipes = {t: OctopusPipeline(params["mlp"], params["cnn"], c)
             for t, c in cfgs.items()}
    gen = TrafficGenerator(adversarial_config(
        "collision_attack", batch_size=16, table_size=16, adv_slots=2,
        active_flows=8, pay_bytes=4, seed=11))
    for batch in gen.batches(8):
        outs = {t: p.step(batch) for t, p in pipes.items()}
        assert_states_equal(pipes["segmented"].state, pipes["scan"].state)
        np.testing.assert_array_equal(
            np.asarray(outs["segmented"].drained.tuple_id),
            np.asarray(outs["scan"].drained.tuple_id))
        np.testing.assert_array_equal(
            np.asarray(outs["segmented"].pkt_actions),
            np.asarray(outs["scan"].pkt_actions))


@pytest.mark.parametrize("mode",
                         ["flash_crowd", "elephant_storm", "collision_attack"])
def test_adversarial_scenario_conservation(mode, params):
    """Every adversarial mode keeps packet conservation through a pipeline:
    each generated packet is ingested exactly once."""
    cfg = PipelineConfig(batch_size=16, max_ready=4, table_size=64,
                         top_n=6, top_k=4, pay_bytes=4,
                         flow_head=decisions.TopKHead())
    pipe = OctopusPipeline(params["mlp"], params["cnn"], cfg)
    sc = AdversarialScenario(pipe, adversarial_config(
        mode, batch_size=16, table_size=64, pay_bytes=4, seed=8))
    assert sc.mode == mode
    stats = sc.run(8)
    assert stats.packets == 8 * 16
    assert stats.new_flows > 0


def test_adversarial_scenario_rejects_plain_traffic(params):
    cfg = PipelineConfig(batch_size=16, max_ready=4, table_size=64,
                         top_n=6, top_k=4, pay_bytes=4,
                         flow_head=decisions.TopKHead())
    pipe = OctopusPipeline(params["mlp"], params["cnn"], cfg)
    with pytest.raises(ValueError, match="adversarial"):
        AdversarialScenario(pipe, TrafficConfig(batch_size=16))
    with pytest.raises(ValueError, match="mode must be one of"):
        adversarial_config("none")
