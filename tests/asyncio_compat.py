"""Optional pytest-asyncio shim (see requirements-dev.txt).

The serving-frontend tests are coroutines.  With ``pytest-asyncio``
installed (the CI lane; ``asyncio_mode = "auto"`` in pyproject.toml) they
run natively.  Without it the suite must still pass — decorate with
``@async_test`` and the coroutine is wrapped in ``asyncio.run`` instead of
being silently skipped-as-uncollected.  With pytest-asyncio present the
decorator is a pass-through (auto mode collects the bare coroutine).
"""
import asyncio
import functools

try:
    import pytest_asyncio  # noqa: F401

    HAVE_PYTEST_ASYNCIO = True
except ImportError:
    HAVE_PYTEST_ASYNCIO = False


def async_test(fn):
    if HAVE_PYTEST_ASYNCIO:
        return fn

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        return asyncio.run(fn(*args, **kwargs))

    return wrapper
