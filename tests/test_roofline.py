"""Roofline machinery: HLO collective parsing, term math, report rendering."""
import json


from repro.launch.roofline import Roofline, parse_collectives

HLO = """
HloModule jit_step, entry_computation_layout={...}

%fused (p: f32[16,128]) -> f32[16,128] {
  ROOT %x = f32[16,128]{1,0} parameter(0)
}

ENTRY %main {
  %p0 = f32[16,128]{1,0} parameter(0)
  %all-gather = f32[256,128]{1,0} all-gather(%p0), replica_groups=[16,16]<=[256], dimensions={0}
  %all-reduce = f32[16,128]{1,0} all-reduce(%p0), replica_groups=[1,256]<=[256], to_apply=%add
  %ars = f32[16,128]{1,0} all-reduce-start(%p0), replica_groups=[16,16]<=[256]
  %ard = f32[16,128]{1,0} all-reduce-done(%ars)
  %rs = bf16[1,128]{1,0} reduce-scatter(%p0), replica_groups=[16,16]<=[256], dimensions={0}
  %cp = f32[16,128]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
  %a2a = (f32[4,128], f32[4,128]) all-to-all(%p0, %p0), replica_groups=[64,4]<=[256]
  ROOT %t = f32[16,128]{1,0} add(%p0, %cp)
}
"""


def test_parse_collectives_kinds_and_groups():
    st = parse_collectives(HLO, 256)
    assert st.counts["all-gather"] == 1
    assert st.counts["all-reduce"] == 2  # plain + -start (done not counted)
    assert st.counts["reduce-scatter"] == 1
    assert st.counts["collective-permute"] == 1
    assert st.counts["all-to-all"] == 1
    # all-gather result = 256*128*4 bytes, group 16
    ag = 256 * 128 * 4
    assert st.bytes_by_kind["all-gather"] == ag
    # all-to-all result: tuple of two f32[4,128]
    assert st.bytes_by_kind["all-to-all"] == 2 * 4 * 128 * 4
    # effective bytes positive and >= permute bytes
    assert st.effective_bytes > 16 * 128 * 4


def test_roofline_terms_and_bottleneck():
    rf = Roofline(
        label="x/train", mesh="single", chips=256,
        flops_per_device=1.97e14,  # exactly 1 s of compute
        bytes_per_device=819e9 * 2,  # 2 s of memory
        collective_bytes_eff=50e9 * 0.5,  # 0.5 s of collectives
        collective_counts={}, model_flops_total=1.97e14 * 256 * 0.5,
        memory={"peak_bytes_est": 1},
    )
    assert abs(rf.compute_term_s - 1.0) < 1e-9
    assert abs(rf.memory_term_s - 2.0) < 1e-9
    assert abs(rf.collective_term_s - 0.5) < 1e-9
    assert rf.bottleneck == "memory"
    assert abs(rf.useful_flops_fraction - 0.5) < 1e-9
    # roofline fraction: achieved useful flops over peak at the 2 s bound
    assert abs(rf.roofline_fraction - 0.25) < 1e-9
    d = rf.to_dict()
    assert d["bottleneck"] == "memory"
    json.dumps(d)  # serializable


def test_report_renders(tmp_path):
    from repro.launch import report

    rf = Roofline(
        label="a/train_4k", mesh="single", chips=256, flops_per_device=1e12,
        bytes_per_device=1e12, collective_bytes_eff=1e10,
        collective_counts={"all-reduce": [3, 1e9]},
        model_flops_total=1e14, memory={"peak_bytes_est": 2**30,
                                        "argument_bytes": 0, "output_bytes": 0,
                                        "temp_bytes": 0, "alias_bytes": 0},
    )
    p = tmp_path / "a__train_4k__single.json"
    p.write_text(json.dumps(rf.to_dict()))
    rows = report.load_all(str(tmp_path))
    t1 = report.dryrun_table(rows)
    t2 = report.roofline_table(rows)
    assert "a/train_4k" in t1 and "all-reducex3" in t1
    assert "a/train_4k" in t2 and "%" in t2
