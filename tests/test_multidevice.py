"""Multi-device integration tests.  Each test runs a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the flag must be set
before jax initializes, so it cannot run in the main pytest process).

Covered: sharded-vs-unsharded train-step equivalence, GPipe pipeline
equivalence, elastic checkpoint restore across different meshes, and the
dry-run machinery on a small mesh.
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def run_subprocess(body: str, devices: int = 8, timeout: int = 900):
    code = textwrap.dedent(f"""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-4000:]}"
    return out.stdout


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    run_subprocess("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config, reduced_config
    from repro.models import LM
    from repro.models.spec import logical_axes
    from repro.distributed import sharding as shd
    from repro.distributed.act import use_act_sharding
    from repro.launch.mesh import make_host_mesh
    from repro.optim import adamw
    from repro.train.steps import make_train_step

    cfg = reduced_config(get_config("qwen3-0.6b")).replace(fsdp=True)
    m = LM(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = adamw(1e-2)
    opt_state = opt.init(params)
    step_fn = make_train_step(cfg, opt)
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab_size)}

    # single device reference
    p1, o1, m1 = jax.jit(step_fn)(params, opt_state, jnp.asarray(0), batch)

    # sharded on a 2x4 mesh
    mesh = make_host_mesh(2, 4)
    axes = logical_axes(m.specs())
    psh = shd.shardings_for(axes, jax.tree.map(lambda x: x, params), cfg, mesh)
    osh = shd.opt_shardings(psh, params, opt_state)
    bsh = shd.input_shardings(mesh, batch)
    with mesh:
        with use_act_sharding(mesh):
            p2, o2, m2 = jax.jit(step_fn, in_shardings=(psh, osh, None, bsh))(
                params, opt_state, jnp.asarray(0), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3, (m1["loss"], m2["loss"])
    l1 = jax.tree.leaves(p1); l2 = jax.tree.leaves(p2)
    err = max(float(jnp.abs(a - b).max()) for a, b in zip(l1, l2))
    assert err < 5e-3, err
    print("OK sharded==unsharded", float(m1["loss"]), err)
    """)


@pytest.mark.slow
def test_pipeline_parallel_matches_sequential():
    run_subprocess("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.pipeline import pipeline_forward, split_stages
    from repro.launch.mesh import make_host_mesh

    from repro.launch.mesh import _axis_type_kwargs
    mesh = jax.make_mesh((4,), ("pod",), **_axis_type_kwargs(1))
    L, D = 8, 16
    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (L, D, D)) * 0.3
    xs = jax.random.normal(jax.random.PRNGKey(1), (6, 4, D))  # 6 microbatches

    def stage_fn(wstack, x, stage_idx):
        for i in range(wstack.shape[0]):
            x = jnp.tanh(x @ wstack[i])
        return x

    stacked = split_stages({"w": ws}, 4)["w"]  # (4, 2, D, D)
    out = pipeline_forward(lambda w, x, s: stage_fn(w, x, s), stacked, xs,
                           mesh=mesh, axis="pod")
    # sequential reference
    ref = xs
    for i in range(L):
        ref = jnp.tanh(ref @ ws[i])
    err = float(jnp.abs(out - ref).max())
    assert err < 1e-5, err
    print("OK pipeline==sequential", err)
    """)


@pytest.mark.slow
def test_elastic_restore_across_meshes(tmp_path):
    run_subprocess(f"""
    import jax, jax.numpy as jnp, numpy as np
    from repro.checkpoint import CheckpointManager
    from repro.configs import get_config, reduced_config
    from repro.models import LM
    from repro.models.spec import logical_axes
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_host_mesh

    cfg = reduced_config(get_config("qwen3-0.6b"))
    m = LM(cfg)
    params = m.init(jax.random.PRNGKey(0))
    mgr = CheckpointManager(r"{tmp_path}", keep=2, async_writes=False)
    mgr.save({{"params": params}}, 1, extra={{"next_step": 1}})

    # restore onto a 4x2 mesh (different from the 1-device save layout)
    mesh = make_host_mesh(4, 2)
    axes = logical_axes(m.specs())
    psh = shd.shardings_for(axes, params, cfg, mesh)
    restored, extra, step = mgr.restore({{"params": params}},
                                        shardings={{"params": psh}})
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and the restored arrays actually carry the new shardings
    leaf = restored["params"]["lm_head"]
    assert len(leaf.sharding.device_set) == 8
    print("OK elastic restore", step)
    """)


@pytest.mark.slow
def test_dryrun_machinery_small_mesh():
    run_subprocess("""
    import jax, jax.numpy as jnp
    from repro.configs import get_config, reduced_config
    from repro.launch.cells import abstract_batch, build_cell
    from repro.launch.roofline import parse_collectives
    from repro.launch.mesh import make_host_mesh
    from repro.configs.base import SHAPES, register, ArchConfig

    # register a tiny arch so build_cell works end-to-end on 8 devices
    from repro.configs import base as cb
    tiny = reduced_config(get_config("qwen3-0.6b")).replace(fsdp=True)
    cb._REGISTRY["tiny-test"] = lambda: tiny
    cb.SHAPES["tiny_train"] = cb.ShapeSpec("tiny_train", 32, 8, "train")

    mesh = make_host_mesh(2, 4)
    cell = build_cell("tiny-test", "tiny_train", mesh)
    from repro.distributed.act import use_act_sharding
    with mesh:
        with use_act_sharding(mesh):
            compiled = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                               donate_argnums=cell.donate_argnums
                               ).lower(*cell.args).compile()
    from repro.launch.roofline import cost_dict
    ca = cost_dict(compiled)
    ma = compiled.memory_analysis()
    coll = parse_collectives(compiled.as_text(), 8)
    assert ca["flops"] > 0
    assert ma.temp_size_in_bytes > 0
    assert sum(coll.counts.values()) > 0  # sharded training must communicate
    print("OK dryrun machinery", ca["flops"], dict(coll.counts))
    """)


@pytest.mark.slow
def test_compressed_psum_shard_map():
    run_subprocess("""
    import jax, jax.numpy as jnp, numpy as np, functools
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.distributed.compression import compressed_psum_with_feedback

    from repro.launch.mesh import _axis_type_kwargs
    mesh = jax.make_mesh((8,), ("data",), **_axis_type_kwargs(1))
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
    e = jnp.zeros((8, 64))

    def body(g, e):
        red, e2 = compressed_psum_with_feedback({"g": g[0]}, {"g": e[0]}, "data")
        return red["g"][None], e2["g"][None]

    f = shard_map(body, mesh=mesh, in_specs=(P("data"), P("data")),
                  out_specs=(P("data"), P("data")), check_rep=False)
    red, e2 = f(g, e)
    ref = jnp.mean(g, axis=0)
    # every shard holds the same (approximately mean-reduced) gradient
    err = float(jnp.abs(red - ref[None]).max())
    assert err < float(jnp.abs(g).max()) / 64, err
    print("OK compressed psum", err)
    """)
