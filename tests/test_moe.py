"""MoE: dispatch vs dense reference, capacity semantics, EP shardability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st
from jax import lax

from repro.configs import get_config, reduced_config
from repro.models.layers import moe_apply, moe_capacity, moe_specs, rms_norm
from repro.models.spec import init_params


def dense_ref(p, x, cfg):
    b, s, d = x.shape
    h = rms_norm(x, p["ln"])
    hf = h.reshape(-1, d)
    logits = hf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, gi = lax.top_k(probs, cfg.experts_per_token)
    gv = gv / gv.sum(-1, keepdims=True)
    y = jnp.zeros_like(hf, dtype=jnp.float32)
    for e in range(cfg.num_experts):
        ge = hf @ p["w_gate"][e]
        ge = ge * jax.nn.sigmoid(ge)
        ue = hf @ p["w_up"][e]
        oe = (ge * ue) @ p["w_down"][e]
        w = jnp.where(gi == e, gv, 0.0).sum(-1)
        y += oe * w[:, None]
    out = x + y.reshape(b, s, d)
    if cfg.num_shared_experts:
        sg = hf @ p["sh_gate"]
        sg = sg * jax.nn.sigmoid(sg)
        su = hf @ p["sh_up"]
        out = out + ((sg * su) @ p["sh_down"]).reshape(b, s, d)
    return out


@pytest.mark.parametrize("arch", ["granite-moe-1b-a400m", "kimi-k2-1t-a32b"])
@pytest.mark.parametrize("groups", [1, 2])
def test_moe_matches_dense_reference(arch, groups):
    cfg = reduced_config(get_config(arch))
    p = init_params(moe_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model)) * 0.5
    out, aux = moe_apply(p, x, cfg, num_groups=groups)
    ref = dense_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


def test_capacity_drops_tokens():
    cfg = reduced_config(get_config("granite-moe-1b-a400m")).replace(
        capacity_factor=0.25)  # force overflow
    p = init_params(moe_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model)) * 0.5
    out, _ = moe_apply(p, x, cfg, num_groups=1)
    ref = dense_ref(p, x, cfg)
    # with drops, output differs from the dense reference on some tokens
    diff = np.abs(np.asarray(out) - np.asarray(ref)).max(axis=-1)[0]
    assert (diff > 1e-3).any()
    # dropped tokens pass through the residual untouched -> still finite
    assert np.isfinite(np.asarray(out)).all()


def test_moe_capacity_formula():
    cfg = get_config("kimi-k2-1t-a32b")
    c = moe_capacity(4096, cfg)
    expect = int(np.ceil(4096 * 8 / 384 * 1.25))
    assert c == expect


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 500), toks=st.integers(4, 40))
def test_moe_property_no_nans_and_residual(seed, toks):
    cfg = reduced_config(get_config("granite-moe-1b-a400m"))
    p = init_params(moe_specs(cfg), jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, toks, cfg.d_model))
    out, aux = moe_apply(p, x, cfg, num_groups=1)
    assert np.isfinite(np.asarray(out)).all()
    assert np.isfinite(float(aux))


def test_expert_params_shardable_over_model_axis():
    cfg = get_config("kimi-k2-1t-a32b")
    assert cfg.num_experts % 16 == 0  # 384 experts / 16-way model axis = 24
    cfg2 = get_config("granite-moe-1b-a400m")
    assert cfg2.num_experts % 16 == 0  # 32 / 16 = 2
