"""Int8 quantized engine datapath (quant-diff tier).

Four layers of guarantees:
  * kernel/oracle exactness — every execution path (Pallas arype/vpe, router
    emulate, router native) reproduces the NumPy int32 oracle bit-for-bit,
    per-tensor and per-output-channel;
  * routing fallbacks — a missing table entry, a missing table, or a
    scale-less artifact all degrade to the f32 path exactly (never
    mis-scaled int8), with the calibrated() warning;
  * calibration artifacts — scales round-trip through the backend-keyed
    artifact; corrupt/missing/schema-mismatched artifacts warn and fall back;
  * the differential harness — on a seeded traffic stream the quantized
    pipeline's decision flips stay within 1% of the f32 oracle and tracker
    state stays bit-exact (only engine outputs quantize).
"""
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import router
from repro.kernels.arype_matmul import arype_matmul_q, ref_matmul, ref_quantized_matmul
from repro.kernels.vpe_smallmm import vpe_matmul_q
from repro.runtime import (
    QuantScales,
    RoutePlan,
    RuntimeConfig,
    autotune,
    platform,
    record_scales,
    runtime_overrides,
)
from repro.runtime import quant
from repro.runtime.autotune import Calibration, load_calibration, save_calibration

FLIP_BOUND = 0.01  # the acceptance bound for the seeded-stream differential


def _operands(m, k, n, seed=0, lo=-3.0, hi=3.0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(lo, hi, (m, k)).astype(np.float32))
    w = jnp.asarray(rng.uniform(-1.0, 1.0, (k, n)).astype(np.float32))
    return x, w


def _scales_for(x, w, per_channel=False):
    sx = quant.pick_scale(float(jnp.max(jnp.abs(x))))
    if per_channel:
        sw = tuple(quant.pick_scale(float(v))
                   for v in jnp.max(jnp.abs(w), axis=0))
    else:
        sw = quant.pick_scale(float(jnp.max(jnp.abs(w))))
    return sx, sw


@pytest.fixture(scope="module")
def fitted_scales():
    """One traffic-sample calibration shared by the slow differential tests."""
    from repro.launch.calibrate import calibrate_quant_scales

    return calibrate_quant_scales(steps=16, flow_models=("cnn",))


# ---------------------------------------------------------------------------
# Kernel vs oracle: bit-exact on non-aligned shapes, both scale layouts
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("per_channel", [False, True])
@pytest.mark.parametrize("activation", ["none", "relu"])
@pytest.mark.parametrize("shape", [(7, 13, 5), (32, 64, 162), (130, 200, 96)])
def test_arype_q_matches_int32_oracle(shape, activation, per_channel):
    x, w = _operands(*shape)
    sx, sw = _scales_for(x, w, per_channel)
    got = arype_matmul_q(x, w, scale_x=sx, scale_w=sw, activation=activation)
    want = ref_quantized_matmul(x, w, scale_x=sx, scale_w=sw, activation=activation)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("per_channel", [False, True])
@pytest.mark.parametrize("shape", [(5, 3, 8), (33, 20, 12)])
def test_vpe_q_matches_int32_oracle(shape, per_channel):
    x, w = _operands(*shape, seed=1)
    sx, sw = _scales_for(x, w, per_channel)
    got = vpe_matmul_q(x, w, scale_x=sx, scale_w=sw, activation="relu")
    want = ref_quantized_matmul(x, w, scale_x=sx, scale_w=sw, activation="relu")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("per_channel", [False, True])
def test_router_impls_all_bit_exact(per_channel):
    """emulate (f32 lanes), native (int8/int32) and the Pallas kernels must
    agree with the oracle bit-for-bit — the f32-int emulation claim."""
    x, w = _operands(24, 48, 32, seed=2)
    sx, sw = _scales_for(x, w, per_channel)
    scales = QuantScales(entries=(("L", sx, sw),))
    want = np.asarray(ref_quantized_matmul(x, w, scale_x=sx, scale_w=sw,
                                           activation="relu"))
    for overrides in ({"quant_impl": "emulate"}, {"quant_impl": "native"},
                      {"use_pallas": True}):
        with runtime_overrides(quantize=True, quant_scales=scales, **overrides):
            got = np.asarray(router.matmul(x, w, name="L", activation="relu"))
        np.testing.assert_array_equal(got, want)


def test_dequant_error_is_scale_bounded():
    """|int8 - f32| per element is bounded by the two rounding half-steps."""
    x, w = _operands(64, 128, 32, seed=3)
    sx, sw = _scales_for(x, w, per_channel=True)
    q = np.asarray(ref_quantized_matmul(x, w, scale_x=sx, scale_w=sw))
    f = np.asarray(ref_matmul(x, w))
    k = x.shape[1]
    # worst case: every product off by (sx/2)|w| + (sw/2)|x| + cross term
    bound = k * (sx * 1.0 / 2 + max(sw) * 3.0 / 2 + sx * max(sw) / 4)
    assert np.max(np.abs(q - f)) <= bound


def test_resolve_quant_impl_policy():
    cfg = RuntimeConfig(quant_impl="auto")
    on_cpu = platform.backend() == "cpu"
    assert router._resolve_quant_impl(cfg, k=64) == (
        "emulate" if on_cpu else "native")
    # past the exact-emulation depth the int32 path is forced
    assert router._resolve_quant_impl(cfg, k=quant.EMULATE_MAX_K + 1) == "native"
    assert router._resolve_quant_impl(
        RuntimeConfig(quant_impl="native"), k=64) == "native"


# ---------------------------------------------------------------------------
# Routing fallbacks: quantize never silently mis-scales
# ---------------------------------------------------------------------------

def test_unknown_layer_name_stays_f32():
    x, w = _operands(16, 24, 8, seed=4)
    scales = QuantScales(entries=(("somebody_else", 0.1, 0.2),))
    with runtime_overrides(quantize=False):
        want = np.asarray(router.matmul(x, w, name="w0"))
    with runtime_overrides(quantize=True, quant_scales=scales):
        got = np.asarray(router.matmul(x, w, name="w0"))
    np.testing.assert_array_equal(got, want)


def test_quantize_without_table_stays_f32():
    x, w = _operands(16, 24, 8, seed=5)
    with runtime_overrides(quantize=False):
        want = np.asarray(router.matmul(x, w, name="w0"))
    with runtime_overrides(quantize=True, quant_scales=None):
        got = np.asarray(router.matmul(x, w, name="w0"))
    np.testing.assert_array_equal(got, want)


def test_scoped_lookup_prefers_scope_then_tail():
    scales = QuantScales(entries=(("pkt/w0", 0.1, 0.2), ("w1", 0.3, 0.4)))
    assert scales.lookup("w0", scope="pkt/") == (0.1, 0.2)
    assert scales.lookup("w0") is None
    assert scales.lookup("flow/w1") == (0.3, 0.4)


# ---------------------------------------------------------------------------
# Config + table validation
# ---------------------------------------------------------------------------

def test_invalid_quant_impl_rejected():
    with pytest.raises(ValueError, match="quant_impl"):
        RuntimeConfig(quant_impl="int4")


def test_scale_table_validation():
    with pytest.raises(ValueError, match="duplicate"):
        QuantScales(entries=(("a", 0.1, 0.1), ("a", 0.2, 0.2)))
    with pytest.raises(ValueError, match="positive"):
        QuantScales(entries=(("a", 0.0, 0.1),))
    with pytest.raises(ValueError, match="positive"):
        QuantScales(entries=(("a", 0.1, (0.1, -0.5)),))
    with pytest.raises(ValueError, match="layer name"):
        QuantScales(entries=(("", 0.1, 0.1),))


def test_fingerprint_is_stable_and_content_keyed():
    a = QuantScales(entries=(("w0", 0.1, (0.2, 0.3)),))
    b = QuantScales(entries=(("w0", 0.1, (0.2, 0.3)),))
    c = QuantScales(entries=(("w0", 0.1, (0.2, 0.31)),))
    assert a.fingerprint == b.fingerprint
    assert a.fingerprint != c.fingerprint
    assert a.fingerprint.startswith("int8/")


def test_dict_roundtrip_preserves_channel_scales():
    a = QuantScales(entries=(("w0", 0.1, (0.2, 0.3)), ("fc", 0.4, 0.5)))
    b = QuantScales.from_dict(json.loads(json.dumps(a.to_dict())))
    assert a == b and a.fingerprint == b.fingerprint
    assert isinstance(b.lookup("w0")[1], tuple)


def test_subset_restricts_lookup():
    a = QuantScales(entries=(("w0", 0.1, 0.2), ("w1", 0.3, 0.4)))
    s = a.subset(("w1",))
    assert s.names() == ("w1",) and s.lookup("w0") is None


def test_recorder_is_eager_only_and_per_channel():
    x, w = _operands(8, 6, 4, seed=6)
    with record_scales() as rec:
        router.matmul(x, w, name="eager_layer")
        jax.jit(lambda a, b: router.matmul(a, b, name="traced_layer"))(x, w)
    assert "eager_layer" in rec.stats and "traced_layer" not in rec.stats
    mx, mw = rec.stats["eager_layer"]
    assert mx == pytest.approx(float(jnp.max(jnp.abs(x))))
    assert len(mw) == 4  # one stat per output channel
    table = rec.scales()
    assert isinstance(table.lookup("eager_layer")[1], tuple)


# ---------------------------------------------------------------------------
# Plan/explain surface quantized placement
# ---------------------------------------------------------------------------

def test_plan_reports_quantized_layers():
    scales = QuantScales(entries=(("w0", 0.1, 0.2),))
    cfg = RuntimeConfig(quantize=True, quant_scales=scales)
    layers = [("w0", 8, 6, 12), ("w1", 8, 12, 6)]
    plan = RoutePlan.from_layers(layers, config=cfg)
    by_name = {s.name: s for s in plan.steps}
    assert by_name["w0"].quantized and not by_name["w1"].quantized
    text = plan.explain()
    assert "int8" in text and scales.fingerprint in text
    # f32 plans stay quiet about quantization
    assert "int8" not in RoutePlan.from_layers(layers).explain()


# ---------------------------------------------------------------------------
# Artifact flow: scales travel with the calibration, guarded like the rest
# ---------------------------------------------------------------------------

def _calib(**kw):
    return Calibration(tau=0.5, vpe_max_elems=1 << 20,
                       fingerprint=dict(platform.fingerprint()), **kw)


def test_artifact_roundtrip_with_scales(tmp_path):
    scales = QuantScales(entries=(("w0", 0.1, (0.2, 0.3)),))
    path = save_calibration(_calib(quant_scales=scales),
                            str(tmp_path / "calib.json"))
    loaded = load_calibration(path)
    assert loaded.quant_scales == scales
    cfg = loaded.apply(RuntimeConfig())
    # scales travel along, running int8 stays an explicit opt-in
    assert cfg.quant_scales == scales and cfg.quantize is False
    on = RuntimeConfig.calibrated(path, quantize=True)
    assert on.quantize is True and on.quant_scales == scales


def test_calibrated_quantize_without_scales_warns_and_stays_f32(tmp_path):
    path = save_calibration(_calib(), str(tmp_path / "calib.json"))
    with pytest.warns(UserWarning, match="no quant_scales"):
        cfg = RuntimeConfig.calibrated(path, quantize=True)
    assert cfg.quantize is False and cfg.quant_scales is None


def test_calibrated_quantize_missing_artifact_warns_and_stays_f32(tmp_path):
    with pytest.warns(UserWarning) as rec:
        cfg = RuntimeConfig.calibrated(str(tmp_path / "nope.json"),
                                       quantize=True)
    msgs = [str(w.message) for w in rec]
    assert any("no calibration artifact" in m for m in msgs)
    assert any("no quant_scales" in m for m in msgs)
    assert cfg.quantize is False


def test_corrupt_scale_entries_reject_artifact(tmp_path):
    path = save_calibration(_calib(), str(tmp_path / "calib.json"))
    raw = json.load(open(path))
    raw["quant_scales"] = {"entries": [["w0", -1.0, 0.5]]}  # negative scale
    with open(path, "w") as f:
        json.dump(raw, f)
    with pytest.warns(UserWarning, match="malformed"):
        assert load_calibration(path) is None
    with pytest.warns(UserWarning):
        cfg = RuntimeConfig.calibrated(path, quantize=True)
    assert cfg.quantize is False and cfg.quant_scales is None


def test_garbage_scale_block_rejects_artifact(tmp_path):
    path = save_calibration(_calib(), str(tmp_path / "calib.json"))
    raw = json.load(open(path))
    raw["quant_scales"] = {"entries": "garbage"}
    with open(path, "w") as f:
        json.dump(raw, f)
    with pytest.warns(UserWarning, match="malformed"):
        assert load_calibration(path) is None


def test_schema_mismatch_still_rejects_scaled_artifact(tmp_path):
    scales = QuantScales(entries=(("w0", 0.1, 0.2),))
    path = save_calibration(_calib(quant_scales=scales),
                            str(tmp_path / "calib.json"))
    raw = json.load(open(path))
    raw["schema_version"] = autotune.SCHEMA_VERSION + 1
    with open(path, "w") as f:
        json.dump(raw, f)
    with pytest.warns(UserWarning, match="schema_version"):
        assert load_calibration(path) is None


# ---------------------------------------------------------------------------
# Calibration pass + the seeded-stream differential (the acceptance harness)
# ---------------------------------------------------------------------------

def test_calibration_covers_engine_layers():
    """The unpruned fit must carry a scale for every routed engine matmul."""
    from repro.launch.calibrate import calibrate_quant_scales

    table = calibrate_quant_scales(steps=6, flow_models=("cnn",),
                                   max_flip_rate=None)
    names = set(table.names())
    assert {"w0", "w1", "w2", "w3"} <= names  # packet MLP
    assert {"conv1", "conv2", "conv3", "fc", "linear"} <= names  # flow CNN
    for n in names:
        sx, sw = table.lookup(n)
        assert sx > 0 and (sw > 0 if isinstance(sw, float)
                           else all(s > 0 for s in sw))


def test_sensitivity_pruning_respects_flip_budget(fitted_scales):
    """The pruned table keeps real coverage — the MAC-heavy CNN tail must
    survive — and prunes only whole layers (subset of the full fit)."""
    assert len(fitted_scales.entries) >= 3
    assert {"conv2", "conv3", "fc"} & set(fitted_scales.names())


@pytest.mark.parametrize("flow_model", ["cnn", "transformer"])
def test_differential_flips_bounded_and_tracker_exact(fitted_scales, flow_model):
    from repro.launch.calibrate import quant_divergence_report

    text, m = quant_divergence_report(fitted_scales, steps=8,
                                      flow_model=flow_model)
    assert m["tracker_bit_exact"], text
    assert m["pkt_flip_rate"] <= FLIP_BOUND, text
    assert m["flow_flip_rate"] <= FLIP_BOUND, text
    assert m["pkt_total"] > 0
    # the CLI-facing report must surface the flip counts
    assert "decision flips:" in text and "tracker state bit-exact: yes" in text
    assert f"pkt {m['pkt_flips']}/{m['pkt_total']}" in text


def test_quantized_pipeline_runs_under_masked_service(fitted_scales):
    """The serving frontend's pre-warmed masked buckets must dispatch the
    quantized pipeline unchanged (no retraces, all requests served)."""
    import asyncio

    from repro.data.traffic import TrafficConfig, TrafficGenerator
    from repro.models import paper_models
    from repro.serving import (
        OctopusPipeline,
        OctopusService,
        PipelineConfig,
        ServiceConfig,
        serve_stream,
    )

    pkt = paper_models.init_paper_model("mlp", jax.random.PRNGKey(0))
    flow = paper_models.init_paper_model("cnn", jax.random.PRNGKey(1))
    with runtime_overrides(quantize=True, quant_scales=fitted_scales):
        pipe = OctopusPipeline(pkt, flow, PipelineConfig(
            batch_size=32, max_ready=8, flow_model="cnn", table_size=128))
    gen = TrafficGenerator(TrafficConfig(batch_size=16, active_flows=8,
                                         table_size=128, seed=3))

    async def drive():
        async with OctopusService(pipe, ServiceConfig(buckets=(16, 32))) as svc:
            warm = svc.trace_count
            await serve_stream(svc, gen, requests=6)
            return svc.stats, svc.trace_count - warm

    stats, retraces = asyncio.run(drive())
    assert stats.served_requests == 6 and retraces == 0
    assert pipe.runtime.quantize and pipe.runtime.quant_scales is not None


def test_no_warnings_on_quantized_happy_path(fitted_scales):
    x, w = _operands(8, 6, 12, seed=7)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        with runtime_overrides(quantize=True, quant_scales=fitted_scales):
            router.matmul(x, w, name="w0")
