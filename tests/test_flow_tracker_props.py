"""Property-based tests for the flow tracker (establish/update/evict/ready/
drain semantics under random packet streams).

The invariant checker is plain code shared by two entry points: a
hypothesis-driven property test (random seeds/shapes, skipped gracefully when
hypothesis is absent — see tests/hypothesis_compat.py) and a deterministic
seeded sweep that always runs, so the invariants stay exercised even without
the dev extra installed.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import flow_tracker as ft
from repro.kernels.flow_features.ops import HIST, default_program


def single_packet(h: int, ts: int, size: int, *, dir_: int = 0, flags: int = 0,
                  proto: int = 0, pay_bytes: int = 4) -> ft.PacketBatch:
    return ft.PacketBatch(
        ts=jnp.asarray([ts], jnp.int32), size=jnp.asarray([size], jnp.int32),
        dir=jnp.asarray([dir_], jnp.int32), flags=jnp.asarray([flags], jnp.int32),
        proto=jnp.asarray([proto], jnp.int32),
        tuple_hash=jnp.asarray([h], jnp.int32),
        payload=jnp.zeros((1, pay_bytes), jnp.int32))


def check_stream_invariants(seed: int, n_pkts: int, table_size: int,
                            top_n: int, hash_pool: list, *,
                            max_ready: int = 2, drain_every: int = 7) -> int:
    """Feed a random packet stream one packet at a time and assert, at every
    step:
      * ``count`` is monotone (+1) for a live flow; 1 on establishment
      * an eviction frees exactly the colliding slot — every other slot's
        state is bit-identical before/after
      * the min lanes never exceed the observed minima of the live flow
      * drained (emitted) flows always carry ``count >= top_n``
    Returns the number of emitted flows (so callers can assert coverage)."""
    rng = np.random.default_rng(seed)
    program = default_program()
    state = ft.init_state(table_size, top_n, top_k=3, pay_bytes=4)
    observed: dict[int, dict] = {}  # slot -> {"tuple", "sizes", "intvs", "count", "last_ts"}
    clock = 0
    emitted = 0

    for i in range(n_pkts):
        h = int(rng.choice(hash_pool))
        clock += int(rng.integers(1, 50))
        size = int(rng.integers(40, 1500))
        pkt = single_packet(h, clock, size)
        prev = [np.asarray(a).copy() for a in state]
        prev_count = prev[1]
        prev_tuple = prev[0]

        state, out = ft.process_packets(state, pkt, program, top_n=top_n)
        slot = int(out.slot[0])
        new = bool(out.new_flow[0])
        ev = bool(out.evicted[0])

        # --- count monotone per live flow / establishment semantics
        if new:
            assert int(state.count[slot]) == 1
            if ev:  # eviction only ever hits an occupied slot of another flow
                assert prev_count[slot] > 0 and prev_tuple[slot] != h
            else:
                assert prev_count[slot] == 0
            flow = observed[slot] = {"tuple": h, "sizes": [], "intvs": [],
                                     "count": 0, "last_ts": None}
        else:
            assert not ev
            assert int(state.count[slot]) == prev_count[slot] + 1  # monotone
            flow = observed[slot]
            assert flow["tuple"] == h
        intv = clock - flow["last_ts"] if flow["last_ts"] is not None else 0
        flow["sizes"].append(size)
        flow["intvs"].append(intv)
        flow["count"] += 1
        flow["last_ts"] = clock

        # --- a packet touches exactly its slot (eviction frees only it)
        for arr_prev, arr_now in zip(prev, state):
            now = np.asarray(arr_now)
            keep = np.ones(table_size, bool)
            keep[slot] = False
            np.testing.assert_array_equal(arr_prev[keep], now[keep])

        # --- min lanes never exceed the observed minima of the live flow
        feats = np.asarray(state.features[slot])
        assert feats[HIST["min_size"]] <= min(flow["sizes"])
        assert feats[HIST["min_intv"]] <= min(flow["intvs"])
        # (and for this program they are exactly the observed minima)
        assert feats[HIST["min_size"]] == min(flow["sizes"])
        assert feats[HIST["min_intv"]] == min(flow["intvs"])
        assert feats[HIST["pkt_count"]] == flow["count"]

        # --- periodic drain: emissions always crossed the top-n threshold
        if i % drain_every == drain_every - 1:
            n_ready_before = int(np.asarray(ft.ready_mask(state, top_n=top_n)).sum())
            state, drained = ft.drain_ready(state, top_n=top_n,
                                            max_ready=max_ready)
            mask = np.asarray(drained.mask)
            assert int(mask.sum()) == min(n_ready_before, max_ready)
            for r in np.flatnonzero(mask):
                assert int(drained.count[r]) >= top_n
                s = int(drained.slots[r])
                assert int(drained.tuple_id[r]) == observed[s]["tuple"]
                assert int(state.count[s]) == 0  # slot recycled
                del observed[s]
                emitted += 1
            # overflow flows (beyond max_ready) stay ready for the next drain
            still = int(np.asarray(ft.ready_mask(state, top_n=top_n)).sum())
            assert still == max(0, n_ready_before - max_ready)
    return emitted


# -------------------------------------------------- deterministic (always on)

@pytest.mark.parametrize("seed", range(4))
def test_tracker_stream_invariants_seeded(seed):
    check_stream_invariants(seed, n_pkts=30, table_size=8, top_n=3,
                            hash_pool=list(range(1, 10)))


def test_tracker_stream_emits_flows():
    # a single hot flow must cross the threshold and actually be emitted
    emitted = check_stream_invariants(1, n_pkts=30, table_size=4, top_n=2,
                                      hash_pool=[5], drain_every=3)
    assert emitted > 0


def test_drain_ready_respects_max_ready_and_order():
    state = ft.init_state(16, 3, 2, 4)
    # hand-mark 5 ready flows on slots 1,4,7,9,12
    ready_slots = [1, 4, 7, 9, 12]
    counts = np.zeros(16, np.int32)
    tuples = np.zeros(16, np.int32)
    for s in ready_slots:
        counts[s], tuples[s] = 3 + s, 100 + s
    state = state._replace(count=jnp.asarray(counts), tuple_id=jnp.asarray(tuples))

    state, d = ft.drain_ready(state, top_n=3, max_ready=3)
    assert np.asarray(d.mask).tolist() == [True] * 3
    assert np.asarray(d.slots).tolist() == [1, 4, 7]  # lowest slots first
    assert np.asarray(d.tuple_id).tolist() == [101, 104, 107]
    # remaining two stay ready and drain next call (padding rows after)
    state, d2 = ft.drain_ready(state, top_n=3, max_ready=3)
    assert np.asarray(d2.mask).tolist() == [True, True, False]
    assert np.asarray(d2.slots).tolist()[:2] == [9, 12]
    assert int(np.asarray(ft.ready_mask(state, top_n=3)).sum()) == 0


def test_hash_slot_scalar_matches_array_version():
    """The host-side scalar hash (traffic generator collision avoidance) must
    stay bit-identical to the device hash the tracker uses."""
    rng = np.random.default_rng(0)
    hashes = rng.integers(1, 2**31 - 1, 200).astype(np.int32)
    for table in (4, 64, 1024, 8192):
        ref = np.asarray(ft.hash_slot(jnp.asarray(hashes), table))
        got = [ft.hash_slot_scalar(int(h), table) for h in hashes]
        np.testing.assert_array_equal(ref, got)


def test_drain_ready_validates_max_ready():
    state = ft.init_state(8, 2, 2, 4)
    with pytest.raises(ValueError):
        ft.drain_ready(state, top_n=2, max_ready=0)
    with pytest.raises(ValueError):
        ft.drain_ready(state, top_n=2, max_ready=9)


def _hash_for_slot(slot: int, table_size: int) -> int:
    return next(h for h in range(1, 10**7)
                if ft.hash_slot_scalar(h, table_size) == slot)


def _fill_ready(table_size: int, top_n: int, n_slots: int) -> ft.TrackerState:
    """A table whose first ``n_slots`` slots each hold a ready flow, built
    through the real packet path (not hand-poked leaves)."""
    program = default_program()
    state = ft.init_state(table_size, top_n, top_k=2, pay_bytes=4)
    hashes = [_hash_for_slot(s, table_size) for s in range(n_slots)]
    for rep in range(top_n):
        batch = ft.PacketBatch(
            ts=jnp.asarray([10 * rep + s for s in range(n_slots)], jnp.int32),
            size=jnp.full((n_slots,), 100, jnp.int32),
            dir=jnp.zeros((n_slots,), jnp.int32),
            flags=jnp.zeros((n_slots,), jnp.int32),
            proto=jnp.zeros((n_slots,), jnp.int32),
            tuple_hash=jnp.asarray(hashes, jnp.int32),
            payload=jnp.zeros((n_slots, 4), jnp.int32))
        state, _ = ft.process_packets(state, batch, program, top_n=top_n)
    return state


def test_drain_ready_all_slots_with_full_budget():
    """Boundary: every slot ready and ``max_ready == table_size`` — one call
    empties the whole table and leaves it bit-identical to a fresh init."""
    table, top_n = 8, 2
    state = _fill_ready(table, top_n, n_slots=table)
    state, d = ft.drain_ready(state, top_n=top_n, max_ready=table)
    assert np.asarray(d.mask).all()
    assert np.asarray(d.slots).tolist() == list(range(table))
    for a, b in zip(state, ft.init_state(table, top_n, 2, 4)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # drained dry: a second full-budget call emits nothing, all padding rows
    state, d2 = ft.drain_ready(state, top_n=top_n, max_ready=table)
    assert not np.asarray(d2.mask).any()
    assert np.asarray(d2.slots).tolist() == [table] * table


def test_drain_ready_budget_exceeds_ready_count():
    """Boundary: ``max_ready`` larger than the number of ready flows — the
    extra rows are sentinel padding and untouched slots stay live."""
    table, top_n = 8, 2
    state = _fill_ready(table, top_n, n_slots=3)
    state, d = ft.drain_ready(state, top_n=top_n, max_ready=table)
    assert np.asarray(d.mask).tolist() == [True] * 3 + [False] * 5
    assert np.asarray(d.slots).tolist()[:3] == [0, 1, 2]
    assert np.asarray(d.slots).tolist()[3:] == [table] * 5
    assert int(np.asarray(state.count).sum()) == 0


def test_release_flows_recycles_every_leaf():
    """Regression (two-level prerequisite): release must reset ALL seven
    leaves — a recycled slot that keeps stale series/sizes/payload/features
    poisons the next flow established there."""
    table, top_n = 8, 3
    state = _fill_ready(table, top_n, n_slots=4)
    state = ft.release_flows(state, jnp.arange(4, dtype=jnp.int32))
    fresh = ft.init_state(table, top_n, 2, 4)
    for name, a, b in zip(state._fields, state, fresh):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"leaf {name} not recycled")


def test_release_flows_sentinel_slot_is_noop():
    """Regression: the padding sentinel ``table_size`` must drop, not clamp.
    Clamping silently wipes the LAST slot whenever a drain emits fewer than
    ``max_ready`` flows (padding rows carry the sentinel)."""
    table, top_n = 8, 2
    state = _fill_ready(table, top_n, n_slots=table)  # slot 7 live
    before = state
    state = ft.release_flows(
        state, jnp.full((3,), table, jnp.int32))  # all-padding release
    for name, a, b in zip(state._fields, state, before):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"sentinel clobbered {name}")


# ------------------------------------------------------- hypothesis (CI)

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n_pkts=st.integers(1, 30),
       table_size=st.sampled_from([4, 8, 16]), top_n=st.integers(2, 5))
def test_tracker_stream_invariants_property(seed, n_pkts, table_size, top_n):
    check_stream_invariants(seed, n_pkts, table_size, top_n,
                            hash_pool=list(range(1, 12)))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), max_ready=st.integers(1, 4),
       drain_every=st.integers(2, 9))
def test_tracker_drain_property(seed, max_ready, drain_every):
    # heavy collisions (pool of 4 hashes, table of 4): constant evict/re-establish
    check_stream_invariants(seed, n_pkts=30, table_size=4, top_n=2,
                            hash_pool=[3, 5, 8, 13], max_ready=max_ready,
                            drain_every=drain_every)
