"""Two-level (hot/cold) flow table: differential tests against a pure-Python
oracle that mirrors the device step semantics one-for-one (promote -> merge
with spill capture -> sequential cold inserts -> scrub -> drain), spill-record
parity between the scan and segmented trackers, hot-only bit-equivalence
(``cold_size > 0`` with collision-free traffic == single-level pipeline),
eviction-policy unit tests, a spill/promote roundtrip proving flow history
survives eviction, and shard/no-shard equivalence with per-lane cold banks."""
import copy
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import assert_states_equal
from test_pipeline import OracleTracker, batch_as_dicts

from repro.core import cold_store, flow_tracker as ft
from repro.core import feature_extractor as fe
from repro.data.traffic import TrafficConfig, TrafficGenerator, shard_of
from repro.kernels.flow_features.ops import default_program
from repro.models import paper_models
from repro.serving import OctopusPipeline, PipelineConfig, ShardedOctopusPipeline


@pytest.fixture(scope="module")
def params():
    return {
        "mlp": paper_models.init_paper_model("mlp", jax.random.PRNGKey(0)),
        "transformer": paper_models.init_paper_model("transformer",
                                                     jax.random.PRNGKey(2)),
    }


def make_batch(hashes, ts, sizes=None, *, pay_bytes=16):
    n = len(hashes)
    sizes = [100] * n if sizes is None else sizes
    return ft.PacketBatch(
        ts=jnp.asarray(ts, jnp.int32),
        size=jnp.asarray(sizes, jnp.int32),
        dir=jnp.zeros((n,), jnp.int32), flags=jnp.zeros((n,), jnp.int32),
        proto=jnp.zeros((n,), jnp.int32),
        tuple_hash=jnp.asarray(hashes, jnp.int32),
        payload=jnp.zeros((n, pay_bytes), jnp.int32))


def hash_for_slot(slot: int, table_size: int, start: int = 1) -> int:
    return next(h for h in range(start, 10**7)
                if ft.hash_slot_scalar(h, table_size) == slot)


# ---------------------------------------------------------------------------
# Pure-Python two-level oracle: OracleTracker (the hot half) + a cold dict,
# mirroring repro.core.cold_store's documented step semantics exactly.
# ---------------------------------------------------------------------------

class TwoLevelOracle(OracleTracker):
    def __init__(self, table_size, cold_size, top_n, top_k, pay_bytes,
                 policy="age"):
        super().__init__(table_size, top_n, top_k, pay_bytes)
        self.cold_size = cold_size
        self.policy = policy
        self.cold: dict[int, dict] = {}  # cold slot -> entry dict + "stamp"
        self.tick = 0
        self.spilled = 0
        self.promoted = 0

    def _cold_find(self, h):
        a, b = cold_store.cold_slots_scalar(h, self.cold_size)
        if a in self.cold and self.cold[a]["tuple_id"] == h:
            return a
        if b in self.cold and self.cold[b]["tuple_id"] == h:
            return b
        return None

    def _cold_insert(self, entry):
        """Mirror of _choose_slot + _insert_one: own entry -> first empty
        candidate -> smaller stamp (tie prefers candidate a)."""
        h = entry["tuple_id"]
        a, b = cold_store.cold_slots_scalar(h, self.cold_size)
        ea, eb = self.cold.get(a), self.cold.get(b)
        if ea is not None and ea["tuple_id"] == h:
            dst = a
        elif eb is not None and eb["tuple_id"] == h:
            dst = b
        elif ea is None:
            dst = a
        elif eb is None:
            dst = b
        else:
            dst = a if ea["stamp"] <= eb["stamp"] else b
        entry = copy.deepcopy(entry)
        entry["stamp"] = entry["last_ts"] if self.policy == "age" else self.tick
        self.cold[dst] = entry
        self.tick += 1

    def step_batch(self, batch_dicts, max_ready):
        # 1. promote: segment heads, ascending hot-slot order
        heads = {}
        for pkt in batch_dicts:
            s = self.slot_of(pkt["tuple_hash"])
            heads.setdefault(s, pkt["tuple_hash"])
        for s in sorted(heads):
            h = heads[s]
            e = self.slots.get(s)
            if e is not None and e["tuple_id"] == h:
                continue  # already live in hot
            src = self._cold_find(h)
            if src is None:
                continue
            entry = self.cold.pop(src)
            if e is not None:  # displaced occupant spills (after src freed)
                self._cold_insert(e)
            entry.pop("stamp")
            self.slots[s] = entry
            self.promoted += 1
        # 2. merge with spill capture, in packet order
        spills = []
        for pkt in batch_dicts:
            s = self.slot_of(pkt["tuple_hash"])
            e = self.slots.get(s)
            if e is not None and e["tuple_id"] != pkt["tuple_hash"]:
                spills.append(copy.deepcopy(e))
            self.process(pkt)
        # 3. cold inserts, sequential in packet order
        for rec in spills:
            self._cold_insert(rec)
            self.spilled += 1
        # 4. scrub: no tuple live in hot may stay in cold
        for pkt in batch_dicts:
            h = pkt["tuple_hash"]
            e = self.slots.get(self.slot_of(h))
            if e is not None and e["tuple_id"] == h:
                c = self._cold_find(h)
                if c is not None:
                    del self.cold[c]
        # 5. drain (hot only)
        return self.drain_ready(max_ready)


def assert_drained_equal(out, expect, oracle):
    d = out.drained
    assert int(np.asarray(d.mask).sum()) == len(expect)
    for r, want in enumerate(expect):
        assert int(d.slots[r]) == want["slot"]
        assert int(d.tuple_id[r]) == want["tuple_id"]
        assert int(d.count[r]) == want["count"]
        np.testing.assert_array_equal(np.asarray(d.features[r]),
                                      np.asarray(want["features"], np.int32))
        np.testing.assert_array_equal(np.asarray(d.series[r]),
                                      np.asarray(want["series"], np.int32))
        np.testing.assert_array_equal(np.asarray(d.sizes[r]),
                                      np.asarray(want["sizes"], np.int32))
        np.testing.assert_array_equal(np.asarray(d.payload[r]),
                                      np.asarray(want["payload"], np.int32))


def assert_two_level_state_equal(state, oracle):
    hot, cold = state.hot, state.cold
    live = set(np.flatnonzero(np.asarray(hot.count) > 0).tolist())
    assert live == set(oracle.slots)
    for s in live:
        e = oracle.slots[s]
        assert int(hot.tuple_id[s]) == e["tuple_id"]
        assert int(hot.count[s]) == e["count"]
        np.testing.assert_array_equal(
            np.asarray(hot.features[s]),
            np.asarray(oracle.feature_word(e), np.int32))
        np.testing.assert_array_equal(np.asarray(hot.series[s]),
                                      np.asarray(e["series"], np.int32))
    occ = set(np.flatnonzero(np.asarray(cold.count) > 0).tolist())
    assert occ == set(oracle.cold)
    for c in occ:
        e = oracle.cold[c]
        assert int(cold.tuple_id[c]) == e["tuple_id"]
        assert int(cold.count[c]) == e["count"]
        assert int(cold.stamp[c]) == e["stamp"]
        np.testing.assert_array_equal(
            np.asarray(cold.features[c]),
            np.asarray(oracle.feature_word(e), np.int32))
    assert int(cold.tick) == oracle.tick


# ---------------------------------------------------------------------------
# Hashing + insert policy
# ---------------------------------------------------------------------------

def test_cold_slots_scalar_matches_array():
    rng = np.random.default_rng(0)
    hashes = np.concatenate([
        rng.integers(1, 2**31 - 1, size=256),
        rng.integers(-(2**31), 0, size=64), [0, 1, -1, 2**31 - 1]])
    for cold_size in (2, 64, 1 << 17):
        a, b = cold_store.cold_slots(jnp.asarray(hashes, jnp.int32), cold_size)
        for i, h in enumerate(hashes):
            sa, sb = cold_store.cold_slots_scalar(int(h), cold_size)
            assert (int(a[i]), int(b[i])) == (sa, sb)


def _spill(h, count, ts, *, top_n=2, top_k=2, pay_bytes=2):
    one = lambda v, shape=(1,): jnp.full(shape, v, jnp.int32)  # noqa: E731
    return ft.SpillRecords(
        mask=jnp.ones((1,), bool), slot=one(0),
        tuple_id=one(h), count=one(count), last_ts=one(ts),
        features=one(0, (1, 16)), series=one(0, (1, top_n)),
        sizes=one(0, (1, top_n)), payload=one(0, (1, top_k, pay_bytes)))


def _find_hash_with_cold_slots(want_a, want_b, cold_size, start=1):
    return next(h for h in range(start, 10**7)
                if cold_store.cold_slots_scalar(h, cold_size) == (want_a,
                                                                  want_b))


@pytest.mark.parametrize("policy,evicted_slot", [("age", 1), ("lru", 0)])
def test_insert_eviction_policy(policy, evicted_slot):
    """Full cold table, third insert: age evicts the longest-idle entry
    (smaller last_ts, slot 1 here), lru the earliest-inserted (slot 0)."""
    C = 2
    h1 = _find_hash_with_cold_slots(0, 1, C)
    h2 = _find_hash_with_cold_slots(1, 0, C, start=h1 + 1)
    h3 = _find_hash_with_cold_slots(0, 1, C, start=h2 + 1)
    cold = cold_store.init_cold(C, top_n=2, top_k=2, pay_bytes=2)
    cold, n1 = cold_store.apply_spills(cold, _spill(h1, 3, ts=100),
                                       policy=policy)
    cold, n2 = cold_store.apply_spills(cold, _spill(h2, 4, ts=50),
                                       policy=policy)
    assert int(n1) == int(n2) == 1
    assert int(cold.tuple_id[0]) == h1 and int(cold.tuple_id[1]) == h2
    cold, _ = cold_store.apply_spills(cold, _spill(h3, 5, ts=200),
                                      policy=policy)
    assert int(cold.tuple_id[evicted_slot]) == h3
    survivor = h2 if evicted_slot == 0 else h1
    assert int(cold.tuple_id[1 - evicted_slot]) == survivor
    assert int(cold.tick) == 3


def test_insert_overwrites_own_entry_never_duplicates():
    C = 64
    cold = cold_store.init_cold(C, top_n=2, top_k=2, pay_bytes=2)
    h = 1234
    cold, _ = cold_store.apply_spills(cold, _spill(h, 3, ts=10), policy="age")
    cold, _ = cold_store.apply_spills(cold, _spill(h, 7, ts=20), policy="age")
    assert int(cold_store.cold_occupancy(cold)) == 1
    a, _b = cold_store.cold_slots_scalar(h, C)
    assert int(cold.count[a]) == 7 and int(cold.last_ts[a]) == 20


def test_masked_spill_is_noop():
    cold = cold_store.init_cold(8, top_n=2, top_k=2, pay_bytes=2)
    sp = _spill(99, 3, ts=10)._replace(mask=jnp.zeros((1,), bool))
    cold2, n = cold_store.apply_spills(cold, sp, policy="lru")
    assert int(n) == 0
    assert_states_equal(cold, cold2)


# ---------------------------------------------------------------------------
# Spill-record parity: scan tracker vs segmented tracker, bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_spill_records_scan_vs_segmented(seed):
    rng = np.random.default_rng(seed)
    table, top_n, P = 16, 4, 32
    program = default_program()
    st_a = ft.init_state(table, top_n, top_k=3, pay_bytes=4)
    st_b = st_a
    pool = rng.integers(1, 10_000, size=40)
    clock = 0
    for rnd in range(6):
        hashes = rng.choice(pool, size=P)
        ts = clock + np.cumsum(rng.integers(1, 30, size=P))
        clock = int(ts[-1])
        batch = make_batch(hashes, ts, rng.integers(40, 1500, size=P).tolist(),
                           pay_bytes=4)
        keep = (None if rnd % 2 == 0
                else jnp.asarray(rng.random(P) < 0.8))
        st_a, out_a, sp_a = ft.process_packets(
            st_a, batch, program, top_n=top_n, keep=keep, with_spills=True)
        st_b, out_b, sp_b = fe.segmented_update(
            st_b, batch, top_n=top_n, keep=keep, with_spills=True)
        assert_states_equal(st_a, st_b)
        for name, fa, fb in zip(ft.SpillRecords._fields, sp_a, sp_b):
            np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb),
                                          err_msg=f"spill field {name}")
        # padding convention: masked-off rows are all-zero with sentinel slot
        m = np.asarray(sp_a.mask)
        np.testing.assert_array_equal(np.asarray(sp_a.slot)[~m], table)
        np.testing.assert_array_equal(np.asarray(sp_a.tuple_id)[~m], 0)


# ---------------------------------------------------------------------------
# Spill/promote roundtrip: eviction no longer loses flow history
# ---------------------------------------------------------------------------

def test_promote_roundtrip_preserves_history(params):
    cfg = PipelineConfig(batch_size=1, max_ready=4, flow_model="transformer",
                         table_size=8, top_n=4, top_k=15, pay_bytes=16,
                         cold_size=32)
    pipe = OctopusPipeline(params["mlp"], params["transformer"], cfg)
    base = OctopusPipeline(params["mlp"], params["transformer"],
                           replace(cfg, cold_size=0))
    h1 = 1
    h2 = next(h for h in range(2, 10**6)
              if ft.hash_slot_scalar(h, 8) == ft.hash_slot_scalar(h1, 8))
    oracle = TwoLevelOracle(8, 32, top_n=4, top_k=15, pay_bytes=16)
    stream = [(h1, 10, 100), (h1, 20, 200), (h1, 30, 300),  # 3 pkts of h1
              (h2, 40, 400),  # collides: h1 spills to cold
              (h1, 50, 500),  # h1 promotes back (h2 spills), 4th pkt -> ready
              (h2, 60, 150)]  # h2 promotes back in turn
    drained = []
    for h, ts, size in stream:
        batch = make_batch([h], [ts], [size])
        expect = oracle.step_batch(batch_as_dicts(batch), cfg.max_ready)
        out = pipe.step(batch)
        base.step(batch)
        assert_drained_equal(out, expect, oracle)
        drained += expect
    # the evicted-then-promoted flow drains with its FULL history intact
    assert [d["tuple_id"] for d in drained] == [h1]
    assert drained[0]["count"] == 4
    assert drained[0]["sizes"] == [100, 200, 300, 500]
    assert drained[0]["series"] == [0, 10, 10, 20]  # pre-spill intervals kept
    assert pipe.stats.spilled == oracle.spilled == 1  # h2's displacement into
    assert pipe.stats.promoted == oracle.promoted == 2  # cold is not a spill
    # the single-level pipeline restarted h1 from scratch and drained nothing
    assert base.stats.flows == 0 and base.stats.evicted == 3
    assert_two_level_state_equal(pipe.state, oracle)


# ---------------------------------------------------------------------------
# Big differential: collision storm vs the oracle, both trackers x policies
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tracker", ["segmented", "scan"])
@pytest.mark.parametrize("policy", ["age", "lru"])
def test_two_level_matches_oracle(params, tracker, policy):
    """Populations ~3x the hot table under collision_free=False traffic: the
    device two-level tracker must agree with the oracle on every drained
    flow, the residual hot table, the cold table (stamps and tick included),
    and the spill/promote totals — hot+cold never loses a flow the oracle
    keeps."""
    cfg = PipelineConfig(batch_size=24, max_ready=6, flow_model="transformer",
                         table_size=16, top_n=6, top_k=15, pay_bytes=16,
                         tracker=tracker, cold_size=64, cold_policy=policy)
    pipe = OctopusPipeline(params["mlp"], params["transformer"], cfg)
    gen = TrafficGenerator(TrafficConfig(
        batch_size=24, active_flows=48, elephant_fraction=0.5,
        table_size=16, seed=13, burst_prob=0.3, collision_free=False))
    oracle = TwoLevelOracle(16, 64, top_n=6, top_k=15, pay_bytes=16,
                            policy=policy)
    for _ in range(20):
        batch = gen.next_batch()
        expect = oracle.step_batch(batch_as_dicts(batch), cfg.max_ready)
        out = pipe.step(batch)
        assert_drained_equal(out, expect, oracle)
    assert_two_level_state_equal(pipe.state, oracle)
    assert pipe.stats.spilled == oracle.spilled
    assert pipe.stats.promoted == oracle.promoted
    assert pipe.stats.spilled > 50 and pipe.stats.promoted > 50  # a real storm
    assert pipe.trace_count == 1  # the cold path compiles once, like hot-only


# ---------------------------------------------------------------------------
# Hot-only equivalence: attaching a cold table must not perturb the hot path
# ---------------------------------------------------------------------------

def test_cold_attached_is_bit_identical_on_collision_free_traffic(params):
    cfg = PipelineConfig(batch_size=24, max_ready=4, flow_model="transformer",
                         table_size=64, top_n=6, top_k=15, pay_bytes=16)
    mk = lambda c: OctopusPipeline(params["mlp"], params["transformer"], c)  # noqa: E731
    base, two = mk(cfg), mk(replace(cfg, cold_size=512))

    def gen():
        return TrafficGenerator(TrafficConfig(
            batch_size=24, active_flows=16, elephant_fraction=0.5,
            table_size=64, seed=11, burst_prob=0.3))

    g0, g1 = gen(), gen()
    for _ in range(20):
        out0, out1 = base.step(g0.next_batch()), two.step(g1.next_batch())
        for name, a, b in zip(out0._fields, out0, out1):
            if name in ("spilled", "promoted"):
                continue
            jax.tree_util.tree_map(
                lambda x, y: np.testing.assert_array_equal(
                    np.asarray(x), np.asarray(y)), a, b)
    assert_states_equal(base.state, two.state.hot)
    assert two.stats.promoted == 0  # nothing live ever sat in cold
    assert base.trace_count == two.trace_count == 1


def test_hot_only_state_is_plain_tracker_state(params):
    cfg = PipelineConfig(batch_size=8, max_ready=4, flow_model="transformer",
                         table_size=16, top_n=4, top_k=15, pay_bytes=16)
    pipe = OctopusPipeline(params["mlp"], params["transformer"], cfg)
    assert isinstance(pipe.state, ft.TrackerState)  # no cold leaves to carry
    assert "cold" not in pipe.explain()
    two = OctopusPipeline(params["mlp"], params["transformer"],
                          replace(cfg, cold_size=128, cold_policy="lru"))
    assert isinstance(two.state, cold_store.TwoLevelState)
    assert "cold=128(lru)" in two.explain()


def test_config_validates_cold_knobs():
    with pytest.raises(ValueError, match="cold_size"):
        PipelineConfig(cold_size=-1)
    with pytest.raises(ValueError, match="policy"):
        PipelineConfig(cold_size=8, cold_policy="fifo")


# ---------------------------------------------------------------------------
# Sharded: per-lane cold banks match the single-lane pipeline on one shard
# ---------------------------------------------------------------------------

def test_sharded_two_level_matches_single_lane(params):
    """All flows steered to shard 0 of a 2-lane pipeline (with forced hot
    collisions inside the shard): lane 0's hot+cold banks and the drain
    stream must be bit-identical to an unsharded pipeline fed the same
    packets, and lane 1 must stay untouched."""
    S, table = 2, 16
    cfg = PipelineConfig(batch_size=24, max_ready=16, flow_model="transformer",
                         table_size=table, top_n=4, top_k=15, pay_bytes=16,
                         cold_size=64)
    ref = OctopusPipeline(params["mlp"], params["transformer"], cfg)
    sh = ShardedOctopusPipeline(params["mlp"], params["transformer"], cfg,
                                num_shards=S)
    assert f"cold=64x{S}" in sh.explain()

    # hashes in shard 0, grouped into colliding pairs on 6 hot slots
    cand = np.arange(1, 40_000, dtype=np.int64)
    in_shard = cand[np.asarray(shard_of(jnp.asarray(cand, jnp.int32), S)) == 0]
    by_slot: dict[int, list] = {}
    for h in in_shard.tolist():
        by_slot.setdefault(ft.hash_slot_scalar(h, table), []).append(h)
    pairs = [by_slot[s][:2] for s in sorted(by_slot) if len(by_slot[s]) >= 2]
    flows = [h for pair in pairs[:6] for h in pair]  # 12 flows, 6 hot slots

    rng = np.random.default_rng(5)
    clock = 0
    for _ in range(12):
        hashes = rng.choice(flows, size=cfg.batch_size)
        ts = clock + np.cumsum(rng.integers(1, 20, size=cfg.batch_size))
        clock = int(ts[-1])
        batch = make_batch(hashes.tolist(), ts.tolist(),
                           rng.integers(40, 1500, size=cfg.batch_size).tolist())
        out_r, out_s = ref.step(batch), sh.step(batch)
        jax.tree_util.tree_map(
            lambda x, y: np.testing.assert_array_equal(
                np.asarray(x), np.asarray(y)), out_r.drained, out_s.drained)
        assert int(out_r.spilled) == int(out_s.spilled)
        assert int(out_r.promoted) == int(out_s.promoted)
    lane0 = jax.tree_util.tree_map(lambda a: a[0], sh.state)
    assert_states_equal(ref.state.hot, lane0.hot)
    assert_states_equal(ref.state.cold, lane0.cold)
    lane1 = jax.tree_util.tree_map(lambda a: a[1], sh.state)
    assert int(cold_store.cold_occupancy(lane1.cold)) == 0
    assert int(lane1.hot.count.sum()) == 0
    assert ref.stats.spilled == sh.stats.spilled > 0
    assert ref.stats.promoted == sh.stats.promoted > 0
