"""Optional-hypothesis shim (see requirements-dev.txt).

The property tests use ``hypothesis``, which is a dev-only dependency.  Import
``given``/``settings``/``st`` from here instead of from ``hypothesis`` so that
when it is missing the suite *degrades* (property tests skip) instead of dying
with 5 collection errors.  With hypothesis installed this module is a
pass-through.
"""
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # degrade: property tests become explicit skips
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Collection-time stand-in for ``hypothesis.strategies``: any
        attribute is a callable returning None (the values are never used —
        the test body is replaced by a skip)."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = strategies = _AnyStrategy()

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    def given(*_args, **_kwargs):
        def deco(fn):
            def _skip():
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")

            _skip.__name__ = fn.__name__
            _skip.__doc__ = fn.__doc__
            return _skip

        return deco
