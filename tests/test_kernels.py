"""Per-kernel correctness: shape/dtype sweeps + hypothesis, all against the
pure-jnp oracles, in interpret mode (CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels.arype_matmul import arype_matmul, arype_matmul_unfused, ref_matmul
from repro.kernels.flash_attention import flash_attention, ref_attention
from repro.kernels.flow_features import flow_feature_update, ref_flow_feature_update
from repro.kernels.flow_features.flow_features import apply_alu_program
from repro.kernels.flow_features.ops import META_WIDTH, default_program
from repro.kernels.vpe_smallmm import ref_vpe_matmul, vpe_matmul


# ---------------------------------------------------------------- arype_matmul

@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (100, 200, 300), (8, 512, 64),
                                   (257, 129, 65), (16, 16, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("act", ["none", "relu", "silu"])
def test_arype_matmul_sweep(m, k, n, dtype, act):
    kx, kw = jax.random.split(jax.random.PRNGKey(m * 1000 + k + n))
    x = jax.random.normal(kx, (m, k), dtype)
    w = jax.random.normal(kw, (k, n), dtype)
    out = arype_matmul(x, w, activation=act)
    ref = ref_matmul(x, w, activation=act)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32),
                               rtol=tol, atol=tol * 8)


def test_arype_unfused_matches_fused():
    x = jax.random.normal(jax.random.PRNGKey(0), (96, 384), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (384, 160), jnp.float32)
    a = arype_matmul(x, w)
    b = arype_matmul_unfused(x, w)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------- vpe_smallmm

@pytest.mark.parametrize("m,k,n", [(1000, 3, 32), (7, 16, 8), (4096, 6, 12), (33, 1, 2)])
@pytest.mark.parametrize("act", ["none", "relu"])
def test_vpe_matmul_sweep(m, k, n, act):
    kx, kw = jax.random.split(jax.random.PRNGKey(m + k * 7 + n))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32)
    out = vpe_matmul(x, w, activation=act)
    ref = ref_vpe_matmul(x, w, activation=act)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------- flash_attention

@pytest.mark.parametrize("b,hq,hkv,sq,sk,d,mask,win", [
    (2, 4, 2, 256, 256, 32, "causal", 0),
    (1, 4, 1, 128, 384, 16, "full", 0),
    (2, 2, 2, 300, 300, 32, "local", 64),
    (1, 8, 4, 256, 512, 64, "causal", 0),
    (1, 2, 2, 64, 64, 128, "local", 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, hq, hkv, sq, sk, d, mask, win, dtype):
    ks = jax.random.split(jax.random.PRNGKey(b * 7 + sq), 3)
    q = jax.random.normal(ks[0], (b, hq, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, sk, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, sk, d), dtype)
    out = flash_attention(q, k, v, mask=mask, window=win)
    g = hq // hkv
    kr = jnp.repeat(k, g, 1).reshape(b * hq, sk, d)
    vr = jnp.repeat(v, g, 1).reshape(b * hq, sk, d)
    ref = ref_attention(q.reshape(b * hq, sq, d), kr, vr, mask=mask, window=win)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out.reshape(b * hq, sq, d), np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


@settings(max_examples=15, deadline=None)
@given(
    sq=st.integers(17, 200), sk=st.integers(17, 200), d=st.sampled_from([8, 16, 32]),
    mask=st.sampled_from(["causal", "full", "local"]),
)
def test_flash_attention_property(sq, sk, d, mask):
    ks = jax.random.split(jax.random.PRNGKey(sq * 211 + sk), 3)
    q = jax.random.normal(ks[0], (1, 2, sq, d), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, sk, d), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, sk, d), jnp.float32)
    out = flash_attention(q, k, v, mask=mask, window=13, bq=32, bk=32)
    ref = ref_attention(q.reshape(2, sq, d), k.reshape(2, sk, d), v.reshape(2, sk, d),
                        mask=mask, window=13)
    np.testing.assert_allclose(np.asarray(out.reshape(2, sq, d)), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------- flow_features

def _random_packets(rng, p, f, meta_range=1000):
    slots = jnp.asarray(rng.integers(0, f - 1, p), jnp.int32)
    meta = jnp.asarray(rng.integers(0, meta_range, (p, META_WIDTH)), jnp.int32)
    return slots, meta


@pytest.mark.parametrize("p,f,block", [(256, 32, 64), (512, 128, 256), (100, 8, 32)])
def test_flow_features_sweep(p, f, block, rng):
    slots, meta = _random_packets(rng, p, f)
    init = jnp.zeros((f, 16), jnp.int32).at[:, 4].set(2**30).at[:, 6].set(2**30)
    prog = default_program()
    out = flow_feature_update(prog, slots, meta, init, block=block)
    ref = ref_flow_feature_update(prog, slots, meta, init)
    assert bool(jnp.all(out == ref))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), ops=st.lists(st.integers(0, 6), min_size=16, max_size=16))
def test_alu_program_property(seed, ops):
    """A random micro-op program produces identical results through the Pallas
    kernel and the scan oracle."""
    rng = np.random.default_rng(seed)
    prog = np.stack([np.asarray(ops, np.int32),
                     rng.integers(0, META_WIDTH, 16).astype(np.int32),
                     rng.integers(0, 16, 16).astype(np.int32)], axis=1)
    prog = jnp.asarray(prog)
    slots = jnp.asarray(rng.integers(0, 7, 64), jnp.int32)
    meta = jnp.asarray(rng.integers(-50, 50, (64, META_WIDTH)), jnp.int32)
    init = jnp.asarray(rng.integers(-5, 5, (8, 16)), jnp.int32)
    out = flow_feature_update(prog, slots, meta, init, block=32)
    ref = ref_flow_feature_update(prog, slots, meta, init)
    assert bool(jnp.all(out == ref))


def test_alu_single_ops():
    meta = jnp.arange(META_WIDTH, dtype=jnp.int32) * 10
    hist = jnp.arange(16, dtype=jnp.int32)
    prog = jnp.asarray([[2, 1, 0]] + [[0, 0, i] for i in range(1, 16)], jnp.int32)
    out = apply_alu_program(prog, meta, hist)
    assert out[0] == hist[0] + meta[1]
    assert bool(jnp.all(out[1:] == hist[1:]))
