"""Overlapped (deferred-sync) dispatch: differential proof that
``PipelineConfig.overlap=True`` is bit-identical to the eager loop —
tracker state, drained flows, rule table, stats packet counts — for
single-lane and sharded pipelines, scan_len 1 and >1, partial final
chunks and multi-round (lane_batch < batch_size) sharded steps; plus the
InflightDispatch handle contract, the host/device stats split, and the
order/exception guarantees of the traffic prefetcher."""
import math

import jax
import numpy as np
import pytest

from repro.data.traffic import TrafficConfig, TrafficGenerator, prefetch
from repro.models.paper_models import init_paper_model
from repro.serving import (
    InflightDispatch,
    OctopusPipeline,
    PipelineConfig,
    ShardedOctopusPipeline,
)


@pytest.fixture(scope="module")
def mlp_params():
    return init_paper_model("mlp", jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def cnn_params():
    return init_paper_model("cnn", jax.random.PRNGKey(1))


def make_pipeline(mlp_params, cnn_params, *, overlap, scan_len=1,
                  num_shards=0, lane_batch=None, batch_size=16):
    cfg = PipelineConfig(batch_size=batch_size, max_ready=8, table_size=128,
                         scan_len=scan_len, overlap=overlap)
    if num_shards:
        return ShardedOctopusPipeline(mlp_params, cnn_params, cfg,
                                      num_shards=num_shards,
                                      lane_batch=lane_batch)
    return OctopusPipeline(mlp_params, cnn_params, cfg)


def gen(batch_size=16, seed=7):
    return TrafficGenerator(TrafficConfig(batch_size=batch_size,
                                          active_flows=48, table_size=128,
                                          seed=seed))


def assert_trees_equal(a, b, msg=""):
    leaves_a = jax.tree_util.tree_leaves(a)
    leaves_b = jax.tree_util.tree_leaves(b)
    assert len(leaves_a) == len(leaves_b)
    for x, y in zip(leaves_a, leaves_b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


def assert_runs_identical(eager, ovl, steps):
    """Drive both pipelines over the same seeded stream and assert the full
    differential contract: residual tracker state, rule table (verdicts AND
    generation order), and every stats count."""
    se = eager.run(gen(eager.cfg.batch_size), steps=steps)
    so = ovl.run(gen(ovl.cfg.batch_size), steps=steps)
    assert_trees_equal(eager.state, ovl.state, "tracker state")
    assert eager.rules.rules == ovl.rules.rules
    assert eager.rules.generation == ovl.rules.generation
    for f in ("packets", "steps", "flows", "new_flows", "evicted",
              "spilled", "promoted", "dispatches", "padded"):
        assert getattr(se, f) == getattr(so, f), f
    assert so.packets == steps * ovl.cfg.batch_size


# ------------------------------------------------------------- single lane

@pytest.mark.parametrize("scan_len,steps", [
    (1, 9),  # per-step dispatch
    (3, 9),  # chunked, steps a multiple of scan_len
    (3, 8),  # chunked + PARTIAL final chunk (per-step fallback, overlapped)
])
def test_overlap_bit_identical_single_lane(mlp_params, cnn_params,
                                           scan_len, steps):
    eager = make_pipeline(mlp_params, cnn_params, overlap=False,
                          scan_len=scan_len)
    ovl = make_pipeline(mlp_params, cnn_params, overlap=True,
                        scan_len=scan_len)
    eager.warmup()
    ovl.warmup()
    assert_runs_identical(eager, ovl, steps)


def test_overlap_stepwise_outputs_identical(mlp_params, cnn_params):
    """Every per-step output — packet verdicts, drained flow rows + masks,
    flow decisions, churn counters — matches the eager loop when handles
    are waited in dispatch order with depth-1 lag (what run() does)."""
    eager = make_pipeline(mlp_params, cnn_params, overlap=False)
    ovl = make_pipeline(mlp_params, cnn_params, overlap=True)
    eager.warmup()
    ovl.warmup()
    batches = list(gen().batches(6))
    eager_outs = [eager.step(b) for b in batches]
    ovl_outs = []
    pending = None
    for b in batches:
        h = ovl.step(b)
        assert isinstance(h, InflightDispatch)
        if pending is not None:
            ovl_outs.append(pending.wait())
        pending = h
    ovl_outs.append(pending.wait())
    for eo, oo in zip(eager_outs, ovl_outs):
        assert_trees_equal(eo, oo, "step output")
    assert_trees_equal(eager.state, ovl.state, "tracker state")
    assert eager.rules.rules == ovl.rules.rules


# ----------------------------------------------------------------- sharded

@pytest.mark.parametrize("scan_len,steps,lane_batch", [
    (1, 7, None),  # lockstep single-round lanes
    (3, 8, None),  # chunked lanes + partial final chunk
    (1, 6, 8),     # multi-round: overflow merges enqueue without readbacks
])
def test_overlap_bit_identical_sharded(mlp_params, cnn_params, scan_len,
                                       steps, lane_batch):
    eager = make_pipeline(mlp_params, cnn_params, overlap=False,
                          scan_len=scan_len, num_shards=2,
                          lane_batch=lane_batch)
    ovl = make_pipeline(mlp_params, cnn_params, overlap=True,
                        scan_len=scan_len, num_shards=2,
                        lane_batch=lane_batch)
    eager.warmup()
    ovl.warmup()
    assert_runs_identical(eager, ovl, steps)


# ------------------------------------------------------------------ handle

def test_handle_contract(mlp_params, cnn_params):
    """step() under overlap returns an InflightDispatch; wait() is
    idempotent, records the dispatch exactly once, and the rule-table
    feedback is DEFERRED until wait (the lag the bit-identity argument
    rests on: the device step never reads the rule table)."""
    p = make_pipeline(mlp_params, cnn_params, overlap=True)
    p.warmup()
    g = gen()
    gen_before = p.rules.generation
    h = p.step(g.next_batch())
    assert isinstance(h, InflightDispatch)
    assert not h.done
    assert h.steps == 1 and h.packets == p.cfg.batch_size
    assert p.rules.generation == gen_before  # feedback not yet applied
    assert p.stats.dispatches == 0  # nothing recorded while in flight
    out1 = h.wait()
    out2 = h.wait()
    assert out1 is out2 and h.done
    assert p.stats.dispatches == 1 and p.stats.steps == 1
    assert p.rules.generation > gen_before


def test_eager_mode_returns_outputs_not_handles(mlp_params, cnn_params):
    p = make_pipeline(mlp_params, cnn_params, overlap=False, scan_len=2)
    p.warmup()
    g = gen()
    out = p.step_many([g.next_batch(), g.next_batch()])
    assert not isinstance(out, InflightDispatch)
    assert np.asarray(out.pkt_actions).shape == (2, p.cfg.batch_size)


def test_stats_host_device_split(mlp_params, cnn_params):
    """total_s decomposes exactly into host_s + device_s, in both modes,
    and the per-dispatch means are finite once something ran."""
    for overlap in (False, True):
        p = make_pipeline(mlp_params, cnn_params, overlap=overlap)
        p.warmup()
        s = p.run(gen(), steps=5)
        assert s.total_s == pytest.approx(s.host_s + s.device_s)
        assert s.host_s > 0 and s.device_s >= 0
        assert math.isfinite(s.host_us) and math.isfinite(s.device_us)
    idle = make_pipeline(mlp_params, cnn_params, overlap=True).stats
    assert math.isnan(idle.host_us) and math.isnan(idle.device_us)


# ---------------------------------------------------------------- prefetch

def test_prefetch_preserves_order_exactly():
    src = list(gen().batches(12))
    out = list(prefetch(iter(src), depth=3))
    assert len(out) == len(src)
    for a, b in zip(src, out):
        assert_trees_equal(a, b, "prefetched batch")


def test_prefetch_forwards_producer_exception():
    def boom():
        yield 1
        yield 2
        raise RuntimeError("producer died")

    it = prefetch(boom(), depth=2)
    assert next(it) == 1 and next(it) == 2
    with pytest.raises(RuntimeError, match="producer died"):
        next(it)


def test_prefetch_rejects_bad_depth():
    with pytest.raises(ValueError, match="depth"):
        next(prefetch(iter([]), depth=0))


def test_prefetch_passes_through_tuples():
    # tagged merge_streams yields (client_id, batch) tuples — the end
    # sentinel must not be confused with user 2-tuples
    src = [(0, "a"), (1, "b")]
    assert list(prefetch(iter(src), depth=1)) == src


def test_prefetched_run_is_bit_identical(mlp_params, cnn_params):
    a = make_pipeline(mlp_params, cnn_params, overlap=True)
    b = make_pipeline(mlp_params, cnn_params, overlap=True)
    a.warmup()
    b.warmup()
    a.run(gen(), steps=8)
    b.run(prefetch(gen().batches(8), depth=2), steps=8)
    assert_trees_equal(a.state, b.state, "tracker state")
    assert a.rules.rules == b.rules.rules
