import os
import sys

# tests run on the default single CPU device; multi-device tests spawn
# subprocesses with XLA_FLAGS themselves (never set it globally here).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def assert_states_equal(a, b):
    """Exact (int32) equality of two TrackerState pytrees, field by field —
    the differential contract shared by the tracker and pipeline tests."""
    for name, x, y in zip(a._fields, a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"TrackerState.{name}")
