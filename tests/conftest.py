import os
import sys

# tests run on the default single CPU device; multi-device tests spawn
# subprocesses with XLA_FLAGS themselves (never set it globally here).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
