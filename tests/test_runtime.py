"""The unified Octopus runtime: RuntimeConfig context semantics (nesting,
override precedence, validation) and RoutePlan as the single placement truth
(trace == from_layers == cycle model).  Calibration is covered in
test_autotune.py."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import router
from repro.core.collaborative import (
    OctopusCycleModel,
    collaborative_forward,
    usecase2_layers,
    usecase2_plan,
    usecase3_layers,
    usecase3_plan,
)
from repro.models import paper_models
from repro.runtime import (
    DEFAULT_RUNTIME,
    RoutePlan,
    RuntimeConfig,
    current_runtime,
    octopus_runtime,
    runtime_overrides,
)
from repro.serving.packet_path import FlowPath, PacketPath


# ---------------------------------------------------------------------------
# RuntimeConfig + context semantics
# ---------------------------------------------------------------------------

def test_default_runtime_matches_legacy_globals():
    cfg = current_runtime()
    assert cfg == DEFAULT_RUNTIME
    assert (cfg.policy, cfg.tau, cfg.mxu_tile, cfg.fill_depth, cfg.vpe_max_elems) == (
        "collaborative", 0.35, 128, 8, 1 << 21)
    # legacy module aliases still resolve and agree
    assert (router.TAU, router.MXU, router.FILL_DEPTH, router.VPE_MAX_ELEMS) == (
        0.35, 128, 8, 1 << 21)


def test_context_nesting_and_restore():
    assert current_runtime().policy == "collaborative"
    with octopus_runtime(RuntimeConfig(policy="arype_only")) as outer:
        assert current_runtime() is outer
        with runtime_overrides(tau=0.9) as inner:
            # overrides compose on the innermost config
            assert inner.policy == "arype_only" and inner.tau == 0.9
            assert current_runtime() is inner
        assert current_runtime() is outer
    assert current_runtime() == DEFAULT_RUNTIME


def test_context_restores_on_exception():
    with pytest.raises(RuntimeError):
        with octopus_runtime(RuntimeConfig(policy="vpe_only")):
            raise RuntimeError("boom")
    assert current_runtime() == DEFAULT_RUNTIME


def test_explicit_config_beats_ambient():
    with octopus_runtime(RuntimeConfig(policy="vpe_only")):
        r = router.route_matmul(4096, 4096, 4096,
                                config=RuntimeConfig(policy="arype_only"))
    assert r.path == "arype"


def test_config_validation():
    with pytest.raises(ValueError):
        RuntimeConfig(policy="bogus")
    with pytest.raises(ValueError):
        RuntimeConfig(tau=0.0)
    with pytest.raises(ValueError):
        RuntimeConfig(mxu_tile=0)


def test_tau_and_vpe_cap_are_live_knobs():
    # (128,64)x(64,96): util = 0.5*0.75 = 0.375 — arype at tau=0.35, vpe at 0.5
    assert router.route_matmul(128, 64, 96).path == "arype"
    with runtime_overrides(tau=0.5):
        assert router.route_matmul(128, 64, 96).path == "vpe"
    with runtime_overrides(vpe_max_elems=10):
        assert router.route_matmul(10, 3, 32).path == "arype"  # cap excludes it


# ---------------------------------------------------------------------------
# The config-first API (the deprecated per-call kwargs were removed on the
# PR 1 schedule — passing them is now a TypeError)
# ---------------------------------------------------------------------------

def test_api_does_not_warn():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        router.matmul(jnp.ones((4, 4)), jnp.ones((4, 4)),
                      config=RuntimeConfig(policy="arype_only"))
        router.route_matmul(32, 32, 32)


def test_removed_kwargs_are_rejected():
    with pytest.raises(TypeError):
        router.route_matmul(4096, 4096, 4096, policy="vpe_only")
    with pytest.raises(TypeError):
        router.matmul(jnp.ones((4, 4)), jnp.ones((4, 4)), use_pallas=False)
    params = paper_models.init_paper_model("mlp", jax.random.PRNGKey(0))
    with pytest.raises(TypeError):
        paper_models.mlp_apply(params, jnp.ones((4, 6), jnp.float32),
                               policy="arype_only")


# ---------------------------------------------------------------------------
# RoutePlan: one placement truth for execution, cycle model and explain()
# ---------------------------------------------------------------------------

def test_routeplan_from_layers_matches_router():
    plan = usecase2_plan(1000)
    assert plan.layers() == usecase2_layers(1000)
    for step in plan:
        assert step.engine == router.route_matmul(step.m, step.k, step.n).path
    # paper's placement: conv1 (20000,3,32) is the VPE offload
    assert plan.engines()["conv1"] == "vpe"
    assert plan.engines()["conv2"] == "arype"


def test_routeplan_trace_cnn_matches_from_layers():
    """Tracing the *executable* CNN yields the exact paper stack — the plan
    seen by the cycle model and the plan executed by JAX cannot diverge."""
    f = 1000
    params = paper_models.init_paper_model("cnn", jax.random.PRNGKey(0))
    traced = RoutePlan.trace(lambda x: paper_models.cnn_apply(params, x),
                             jax.ShapeDtypeStruct((f, paper_models.CNN_SEQ),
                                                  jnp.float32))
    assert traced.layers() == usecase2_layers(f)
    assert traced.engines() == usecase2_plan(f).engines()


def test_routeplan_trace_transformer_matches_paper_shapes():
    f = 50
    params = paper_models.init_paper_model("transformer", jax.random.PRNGKey(0))
    traced = RoutePlan.trace(
        lambda x: paper_models.transformer_apply(params, x),
        jax.ShapeDtypeStruct((f, paper_models.TF_PKTS, paper_models.TF_BYTES),
                             jnp.float32))
    by_name = {s.name: s.shape for s in traced}
    paper = {name: (m, k, n) for name, m, k, n in usecase3_layers(f)}
    # the routed matmuls (qk/av run as einsum attention, cls is extra-paper)
    for name in ("wq", "wk", "wv", "mlp1", "mlp2"):
        assert by_name[name] == paper[name]
    ref = usecase3_plan(f).engines()
    for name in ("wq", "wk", "wv", "mlp1", "mlp2"):
        assert traced.engines()[name] == ref[name]


def test_cycle_model_consumes_plan_placement():
    plan = usecase2_plan(1000)
    rep = OctopusCycleModel().stack_report(plan, collaborative=True)
    assert rep["placements"] == plan.engines()
    off = OctopusCycleModel().stack_report(plan, collaborative=False)
    assert set(off["placements"].values()) == {"arype"}
    # a bare layer list still works (routed into a plan internally)
    rep2 = OctopusCycleModel().stack_report(usecase2_layers(1000), collaborative=True)
    assert rep2["placements"] == rep["placements"]
    assert rep2["total_cycles"] == rep["total_cycles"]


def test_cycle_model_bare_layers_ignore_forced_ambient_policy():
    """The legacy bare-list form always routed with the router-decides policy;
    a forced ambient policy must not silently defeat collaborative=True."""
    with octopus_runtime(RuntimeConfig(policy="arype_only")):
        rep = OctopusCycleModel().stack_report(usecase2_layers(1000),
                                               collaborative=True)
    assert "vpe" in set(rep["placements"].values())


def test_collaborative_forward_rejects_mismatched_plan():
    ws = [jnp.ones((8, 8)), jnp.ones((8, 8))]
    from repro.core.collaborative import plan_stack

    short = plan_stack(jnp.ones((4, 8)), ws[:1])
    with pytest.raises(ValueError, match="rebuild the plan"):
        collaborative_forward(jnp.ones((4, 8)), ws, [None, None], plan=short)


def test_collaborative_forward_inherits_plan_config(monkeypatch):
    """A supplied plan's config governs execution: a plan built for the
    unfused ablation must take the unfused path without config= repeated."""
    import repro.core.collaborative as collab
    from repro.core.collaborative import plan_stack

    calls = []
    orig = collab._unfused_jnp
    monkeypatch.setattr(collab, "_unfused_jnp",
                        lambda *a, **k: (calls.append(1), orig(*a, **k))[1])
    ws = [jax.random.normal(jax.random.PRNGKey(0), (300, 64))]
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 300))
    plan = plan_stack(x, ws, config=RuntimeConfig(policy="arype_only",
                                                  fused_aggregation=False))
    out = collab.collaborative_forward(x, ws, [None], plan=plan)
    assert calls, "plan's fused_aggregation=False was ignored"
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ ws[0]),
                               rtol=1e-4, atol=1e-4)


def test_cycle_model_respects_plan_config():
    forced = usecase2_plan(1000, config=RuntimeConfig(policy="arype_only"))
    rep = OctopusCycleModel().stack_report(forced, collaborative=True)
    assert set(rep["placements"].values()) == {"arype"}


def test_collaborative_forward_accepts_plan():
    ws = [jax.random.normal(jax.random.PRNGKey(i), s) for i, s in
          enumerate([(300, 64), (64, 96), (96, 8)])]
    x = jax.random.normal(jax.random.PRNGKey(9), (32, 300))
    from repro.core.collaborative import plan_stack

    plan = plan_stack(x, ws)
    out = collaborative_forward(x, ws, ["relu", "relu", None], plan=plan)
    ref = collaborative_forward(x, ws, ["relu", "relu", None])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref))


def test_plan_explain_is_readable():
    text = usecase2_plan(1000).explain()
    assert "policy=collaborative" in text
    assert "conv1" in text and "(20000,3,32)" in text
    assert "vpe" in text and "arype" in text
    lines = text.splitlines()
    assert len(lines) == 2 + len(usecase2_layers(1000))  # header + rows + summary


def test_serving_paths_expose_plans():
    mlp = paper_models.init_paper_model("mlp", jax.random.PRNGKey(0))
    pplan = PacketPath(mlp).route_plan(batch=8)
    assert [s.shape for s in pplan] == [(8, 6, 12), (8, 12, 6), (8, 6, 3), (8, 3, 2)]
    assert all(s.engine == "vpe" for s in pplan)  # the paper's latency path
    cnn = paper_models.init_paper_model("cnn", jax.random.PRNGKey(1))
    fplan = FlowPath(cnn, model="cnn").route_plan(flows=1000)
    assert fplan.layers() == usecase2_layers(1000)


def test_jit_traces_under_construction_config():
    """Serving paths capture their config at construction: the jitted callable
    keeps its placement even if the ambient runtime changes afterwards."""
    params = paper_models.init_paper_model("mlp", jax.random.PRNGKey(0))
    path = PacketPath(params, config=RuntimeConfig(policy="arype_only"))
    with octopus_runtime(RuntimeConfig(policy="vpe_only")):
        assert path.route_plan(8).engines() == {
            "w0": "arype", "w1": "arype", "w2": "arype", "w3": "arype"}


def test_name_scope_prefixes_recorded_routes():
    """name_scope labels composite traces; RoutePlan.scoped extracts the
    sub-plan (how the streaming pipeline splits packet vs flow engines)."""
    from repro.runtime import name_scope, record_routes, route_matmul

    with record_routes() as records:
        route_matmul(8, 8, 8, name="plain")
        with name_scope("pkt"):
            route_matmul(8, 8, 8, name="w0")
            with name_scope("inner"):
                route_matmul(8, 8, 8, name="w1")
            route_matmul(8, 8, 8)  # unnamed: bare scope label
        route_matmul(8, 8, 8, name="after")
    assert [r.name for r in records] == [
        "plain", "pkt/w0", "pkt/inner/w1", "pkt/", "after"]

    mlp = paper_models.init_paper_model("mlp", jax.random.PRNGKey(0))

    def scoped_fn(x):
        with name_scope("pkt"):
            return paper_models.mlp_apply(mlp, x, config=current_runtime())

    plan = RoutePlan.trace(scoped_fn, jax.ShapeDtypeStruct((8, 6), jnp.float32))
    sub = plan.scoped("pkt")
    assert len(sub) == 4 and [s.name for s in sub] == [
        "pkt/w0", "pkt/w1", "pkt/w2", "pkt/w3"]
    assert plan.scoped("missing").layers() == []
