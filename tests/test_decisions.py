"""Decision-layer unit & property tests (PR 9): RuleTable invariants
(generation strictly monotone, packet-granularity updates never regress the
flow class, stable lookup default, seeded churn vs a dict model), the
DecisionHead registries and built-in heads, ``deny_threshold`` plumbing
through :class:`PipelineConfig`, and the ``p == deny_threshold`` boundary —
regression-tested to agree between the f32 and int8-emulate datapaths."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st
from test_cold_store import make_batch

from repro.core import flow_tracker as ft
from repro.core.decisions import (
    ACTIONS,
    AnomalyHead,
    BinaryHead,
    ClassHead,
    DecisionHead,
    PassHead,
    RuleTable,
    TopKHead,
    decide_binary,
    decide_class,
    flow_head,
    packet_head,
)
from repro.kernels.flow_features.ops import HIST
from repro.models import paper_models
from repro.runtime import QuantScales, runtime_overrides
from repro.runtime import quant
from repro.serving import PipelineConfig

_DENY = ACTIONS.index("deny")
_MARK = ACTIONS.index("mark")


# ---------------------------------------------------------------------------
# RuleTable invariants
# ---------------------------------------------------------------------------

def test_generation_strictly_monotone():
    t = RuleTable()
    gens = [t.generation]
    for k in range(5):
        t.update(np.array([k % 2]), np.array([k % len(ACTIONS)]))
        gens.append(t.generation)
    assert gens == sorted(set(gens)), "every update must bump the generation"


def test_packet_update_never_regresses_class():
    t = RuleTable()
    t.update(np.array([7]), np.array([_MARK]), classes=np.array([3]))
    assert t.lookup(7)["class"] == 3
    # packet-granularity update (no classes): action changes, class survives
    t.update(np.array([7]), np.array([_DENY]))
    assert t.lookup(7) == {"action": "deny", "class": 3, "generation": 2}
    # a flow never classified stays at the unknown class
    t.update(np.array([8]), np.array([_DENY]))
    assert t.lookup(8)["class"] == -1


def test_lookup_default_stable():
    t = RuleTable()
    default = t.lookup(12345)
    assert default == {"action": "allow", "class": -1, "generation": 0}
    # mutating the returned dict must not poison later lookups
    default["action"] = "deny"
    assert t.lookup(12345)["action"] == "allow"
    # and a miss never materialises an entry
    assert 12345 not in t.rules


def _apply_model(model, fids, actions, classes, generation):
    for i, fid in enumerate(fids):
        cls = (classes[i] if classes is not None
               else model.get(fid, {"class": -1})["class"])
        model[fid] = {"action": ACTIONS[actions[i]], "class": cls,
                      "generation": generation}


def test_seeded_churn_matches_dict_model():
    rng = np.random.default_rng(42)
    t, model = RuleTable(), {}
    for step in range(40):
        n = int(rng.integers(1, 6))
        fids = rng.integers(0, 12, n)
        actions = rng.integers(0, len(ACTIONS), n)
        classes = rng.integers(0, 8, n) if rng.random() < 0.5 else None
        t.update(fids, actions, classes)
        _apply_model(model, fids.tolist(), actions.tolist(),
                     None if classes is None else classes.tolist(), step + 1)
    assert t.generation == 40
    for fid in range(12):
        want = model.get(fid, {"action": "allow", "class": -1,
                               "generation": 0})
        assert t.lookup(fid) == want


@settings(max_examples=50, deadline=None)
@given(ops=st.lists(
    st.tuples(st.integers(0, 7),  # fid
              st.integers(0, len(ACTIONS) - 1),  # action
              st.one_of(st.none(), st.integers(0, 9))),  # class (None = pkt)
    max_size=60))
def test_ruletable_properties(ops):
    t, model = RuleTable(), {}
    for fid, action, cls in ops:
        gen_before = t.generation
        t.update(np.array([fid]), np.array([action]),
                 None if cls is None else np.array([cls]))
        assert t.generation == gen_before + 1
        _apply_model(model, [fid], [action],
                     None if cls is None else [cls], t.generation)
    for fid in range(8):
        want = model.get(fid, {"action": "allow", "class": -1,
                               "generation": 0})
        assert t.lookup(fid) == want


# ---------------------------------------------------------------------------
# Head registries and built-in heads
# ---------------------------------------------------------------------------

def test_head_registries():
    assert isinstance(packet_head("binary", deny_threshold=0.7), BinaryHead)
    assert packet_head("binary", deny_threshold=0.7).deny_threshold == 0.7
    assert isinstance(packet_head("pass"), PassHead)
    assert isinstance(flow_head("class"), ClassHead)
    assert isinstance(flow_head("anomaly", malicious_class=2), AnomalyHead)
    assert isinstance(flow_head("topk"), TopKHead)
    with pytest.raises(ValueError, match="packet head must be one of"):
        packet_head("topk")
    with pytest.raises(ValueError, match="flow head must be one of"):
        flow_head("binary")


def test_heads_satisfy_protocol_and_hash():
    for head in (BinaryHead(), PassHead(), ClassHead(), AnomalyHead(),
                 TopKHead()):
        assert isinstance(head, DecisionHead)
        hash(head)  # frozen: usable inside the jit-cache-key config
    assert BinaryHead(0.7) == BinaryHead(0.7)
    assert BinaryHead(0.7) != BinaryHead(0.5)
    assert BinaryHead().needs_logits and ClassHead().needs_logits
    assert not PassHead().needs_logits and not TopKHead().needs_logits


def test_pass_head_allows_everything():
    batch = make_batch([1, 2, 3], [10, 20, 30], pay_bytes=4)
    out = np.asarray(PassHead().decide(None, batch))
    np.testing.assert_array_equal(out, np.zeros(3, np.int32))


def test_binary_head_matches_decide_binary():
    logits = jnp.asarray([[0.0, 0.0], [0.0, 1.0], [1.0, 0.0]])
    head = BinaryHead()
    np.testing.assert_array_equal(np.asarray(head.decide(logits, None)),
                                  np.asarray(decide_binary(logits, 0.5)))
    # ties (p == 0.5) allow; a clear attack logit denies
    np.testing.assert_array_equal(np.asarray(head.decide(logits, None)),
                                  [0, 1, 0])


def test_class_head_scores_are_confidences():
    logits = jnp.asarray([[0.0, 2.0], [3.0, 0.0]])
    actions, cls, scores = ClassHead().decide(logits, None)
    want_a, want_c = decide_class(logits)
    np.testing.assert_array_equal(np.asarray(actions), np.asarray(want_a))
    np.testing.assert_array_equal(np.asarray(cls), np.asarray(want_c))
    p = np.asarray(jax.nn.softmax(np.asarray(logits), axis=-1))
    np.testing.assert_allclose(np.asarray(scores), p.max(axis=-1), rtol=1e-6)


def test_anomaly_head_boundary_is_inclusive():
    # tied logits -> malicious probability exactly 0.5; score >= thr denies
    logits = jnp.asarray([[0.0, 0.0], [0.0, 4.0], [4.0, 0.0]])
    actions, cls, scores = AnomalyHead(deny_threshold=0.5,
                                       malicious_class=0).decide(logits, None)
    np.testing.assert_array_equal(np.asarray(actions),
                                  [_DENY, _MARK, _DENY])
    assert float(scores[0]) == 0.5
    np.testing.assert_array_equal(np.asarray(cls),
                                  np.argmax(np.asarray(logits), axis=-1))


def test_topk_head_scores_byte_counters():
    feats = np.zeros((4, 16), np.int32)
    feats[:, HIST["flow_size"]] = [100, 7, 0, 9000]
    drained = ft.DrainResult(
        slots=jnp.arange(4, dtype=jnp.int32),
        mask=jnp.ones(4, bool),
        tuple_id=jnp.asarray([11, 22, 33, 44], jnp.int32),
        count=jnp.ones(4, jnp.int32),
        features=jnp.asarray(feats),
        series=jnp.zeros((4, 6), jnp.int32),
        sizes=jnp.zeros((4, 6), jnp.int32),
        payload=jnp.zeros((4, 4, 4), jnp.int32))
    actions, cls, scores = TopKHead().decide(None, drained)
    np.testing.assert_array_equal(np.asarray(scores), [100, 7, 0, 9000])
    np.testing.assert_array_equal(np.asarray(cls), np.full(4, -1))
    np.testing.assert_array_equal(np.asarray(actions), np.full(4, _MARK))


# ---------------------------------------------------------------------------
# deny_threshold plumbing and the f32/int8 boundary agreement
# ---------------------------------------------------------------------------

def test_deny_threshold_plumbs_into_default_head():
    cfg = PipelineConfig(deny_threshold=0.7)
    assert cfg.pkt_head == BinaryHead(deny_threshold=0.7)
    assert cfg.flow_head == ClassHead()
    # an explicit head wins over the scalar knob
    cfg = PipelineConfig(deny_threshold=0.7, pkt_head=PassHead())
    assert cfg.pkt_head == PassHead()


def test_config_rejects_non_heads():
    with pytest.raises(ValueError, match="pkt_head"):
        PipelineConfig(pkt_head=object())
    with pytest.raises(ValueError, match="flow_head"):
        PipelineConfig(flow_head=object())


def _tied_mlp_params(seed=3):
    """Paper MLP whose final layer has identical allow/deny columns, so the
    logits tie bit-for-bit and p lands exactly on 0.5 — the deny boundary."""
    params = dict(paper_models.init_paper_model("mlp", jax.random.PRNGKey(seed)))
    w3 = np.asarray(params["w3"]).copy()
    b3 = np.asarray(params["b3"]).copy()
    w3[:, 1] = w3[:, 0]
    b3[1] = b3[0]
    params["w3"] = jnp.asarray(w3)
    params["b3"] = jnp.asarray(b3)
    return params


def _hidden_before_final(params, x):
    h = np.asarray(x, np.float32)
    for i in range(len(paper_models.MLP_DIMS) - 2):
        h = np.maximum(h @ np.asarray(params[f"w{i}"])
                       + np.asarray(params[f"b{i}"]), 0.0)
    return h


def test_deny_boundary_consistent_f32_and_int8_emulate():
    """``p == deny_threshold`` must decide identically (allow — the
    comparison is strict) in the f32 datapath and the int8-emulate datapath:
    identical final-layer columns quantize identically, so the logit tie —
    and hence the boundary verdict — survives quantization bit-for-bit."""
    params = _tied_mlp_params()
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(-2, 2, (16, paper_models.MLP_DIMS[0]))
                    .astype(np.float32))
    head = BinaryHead(deny_threshold=0.5)

    logits_f32 = paper_models.mlp_apply(params, x)
    np.testing.assert_array_equal(np.asarray(logits_f32[:, 0]),
                                  np.asarray(logits_f32[:, 1]))
    p_f32 = np.asarray(jax.nn.softmax(np.asarray(logits_f32), axis=-1))
    np.testing.assert_array_equal(p_f32[:, 1], np.full(16, 0.5))

    # quantize the final layer (per-output-channel scales: tied columns get
    # the same scale, so their int8 lanes stay identical)
    h = _hidden_before_final(params, x)
    w3 = np.asarray(params["w3"])
    sx = quant.pick_scale(float(np.abs(h).max()))
    sw = tuple(quant.pick_scale(float(v)) for v in np.abs(w3).max(axis=0))
    assert sw[0] == sw[1]
    scales = QuantScales(entries=(("w3", sx, sw),))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # w0..w2 miss the table: f32 fallback
        with runtime_overrides(quantize=True, quant_scales=scales,
                               quant_impl="emulate"):
            logits_q = paper_models.mlp_apply(params, x)
    np.testing.assert_array_equal(np.asarray(logits_q[:, 0]),
                                  np.asarray(logits_q[:, 1]))

    for logits in (logits_f32, logits_q):
        got = np.asarray(head.decide(jnp.asarray(logits), None))
        np.testing.assert_array_equal(got, np.zeros(16, np.int32),
                                      err_msg="p == deny_threshold must allow")
    # and the boundary is genuinely strict: nudging one deny logit up flips it
    bumped = np.asarray(logits_f32).copy()
    bumped[:, 1] += 0.1
    assert np.all(np.asarray(head.decide(jnp.asarray(bumped), None)) == 1)
