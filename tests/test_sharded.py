"""Sharded multi-lane pipeline: differential harness proving shard/no-shard
equivalence (union of drained flows, residual tables modulo shard, per-flow
decisions — exact int32), partition_batch conservation laws (deterministic +
hypothesis), forced cross-shard-collision coverage, no-retrace/donation
checks, and vmap-vs-shard_map backend parity."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import flow_tracker as ft
from repro.data.traffic import (
    TrafficConfig,
    TrafficGenerator,
    partition_batch,
    shard_of,
)
from repro.kernels.flow_features.ops import default_program
from repro.models import paper_models
from repro.serving import OctopusPipeline, PipelineConfig, ShardedOctopusPipeline

ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.fixture(scope="module")
def params():
    return {
        "mlp": paper_models.init_paper_model("mlp", jax.random.PRNGKey(0)),
        "cnn": paper_models.init_paper_model("cnn", jax.random.PRNGKey(1)),
        "transformer": paper_models.init_paper_model("transformer",
                                                     jax.random.PRNGKey(2)),
    }


def make_batch(hashes, ts, *, size=100, pay_bytes=16):
    n = len(hashes)
    return ft.PacketBatch(
        ts=jnp.asarray(ts, jnp.int32),
        size=jnp.full((n,), size, jnp.int32),
        dir=jnp.zeros((n,), jnp.int32), flags=jnp.zeros((n,), jnp.int32),
        proto=jnp.zeros((n,), jnp.int32),
        tuple_hash=jnp.asarray(hashes, jnp.int32),
        payload=jnp.zeros((n, pay_bytes), jnp.int32))


def collect_drained(out, dst: dict):
    """Union of drained flows: tuple_id -> list of emitted snapshots (an
    elephant can cross the ready threshold several times) + decisions."""
    mask = np.asarray(out.drained.mask)
    for i in np.flatnonzero(mask):
        tid = int(out.drained.tuple_id[i])
        dst.setdefault(tid, []).append((
            int(out.drained.slots[i]), int(out.drained.count[i]),
            np.asarray(out.drained.features[i]).tolist(),
            np.asarray(out.drained.series[i]).tolist(),
            np.asarray(out.drained.sizes[i]).tolist(),
            np.asarray(out.drained.payload[i]).tolist(),
            int(out.flow_actions[i]), int(out.flow_cls[i]),
        ))


def assert_residual_modulo_shard(ref: OctopusPipeline,
                                 sh: ShardedOctopusPipeline, S: int):
    """Every live flow of the single-lane table exists bit-identically at
    the same slot of its shard's bank; any extra sharded-live row is a stale
    flow the oracle recycled by a cross-shard collision (its slot in the
    oracle table holds a different tuple)."""
    live = np.flatnonzero(np.asarray(ref.state.count) > 0)
    for slot in live:
        tid = int(ref.state.tuple_id[slot])
        lane = shard_of(tid, S)
        assert int(sh.state.tuple_id[lane, slot]) == tid
        for field in ("count", "last_ts", "features", "series", "sizes",
                      "payload"):
            np.testing.assert_array_equal(
                np.asarray(getattr(ref.state, field)[slot]),
                np.asarray(getattr(sh.state, field)[lane, slot]),
                err_msg=f"residual {field} @ slot {slot}")
    ref_live = {(int(ref.state.tuple_id[s]), int(s)) for s in live}
    sh_count = np.asarray(sh.state.count)
    for lane, slot in zip(*np.nonzero(sh_count > 0)):
        tid = int(sh.state.tuple_id[lane, slot])
        if (tid, int(slot)) not in ref_live:
            # stale leftover: the oracle's slot was recycled by another flow
            assert int(ref.state.tuple_id[slot]) != tid


def run_differential(params, num_shards, *, tracker="segmented", steps=16,
                     seed=7, lane_batch=None, scan_len=1, table_size=64):
    from dataclasses import replace

    cfg = PipelineConfig(batch_size=24, max_ready=16, flow_model="transformer",
                         table_size=table_size, top_n=6, top_k=15,
                         pay_bytes=16, tracker=tracker, scan_len=scan_len)
    ref = OctopusPipeline(params["mlp"], params["transformer"],
                          replace(cfg, scan_len=1))
    sh = ShardedOctopusPipeline(params["mlp"], params["transformer"], cfg,
                                num_shards=num_shards, lane_batch=lane_batch)

    def gen():
        return TrafficGenerator(TrafficConfig(
            batch_size=24, active_flows=12, elephant_fraction=0.5,
            table_size=table_size, seed=seed, burst_prob=0.3))

    g_ref, g_sh = gen(), gen()
    drained_ref, drained_sh = {}, {}
    if scan_len > 1:
        sh.warmup()
        for _ in range(steps // scan_len):
            batches = [g_sh.next_batch() for _ in range(scan_len)]
            out = sh.step_many(batches)
            for j in range(scan_len):
                collect_drained(jax.tree_util.tree_map(lambda a: a[j], out),
                                drained_sh)
        for _ in range(steps):
            collect_drained(ref.step(g_ref.next_batch()), drained_ref)
    else:
        for _ in range(steps):
            o_ref = ref.step(g_ref.next_batch())
            o_sh = sh.step(g_sh.next_batch())
            np.testing.assert_array_equal(np.asarray(o_ref.pkt_actions),
                                          np.asarray(o_sh.pkt_actions))
            assert int(o_ref.new_flows) == int(o_sh.new_flows)
            collect_drained(o_ref, drained_ref)
            collect_drained(o_sh, drained_sh)
            # ample budget is a precondition of drain-timing equality; make
            # it a tested invariant instead of luck
            assert int(np.asarray(
                ft.ready_mask(ref.state, top_n=cfg.top_n)).sum()) == 0
            assert int(np.asarray(sh.state.count >= cfg.top_n).sum()) == 0
    assert drained_ref, "stream never exercised the emission path"
    assert drained_ref == drained_sh
    assert ref.rules.rules == sh.rules.rules
    return ref, sh


# ------------------------------------------------------------- differential

@pytest.mark.parametrize("num_shards", [1, 2, 4])
def test_sharded_matches_single_lane_oracle(params, num_shards):
    """The issue's core acceptance: exact int32 equality of the union of
    drained flows, the residual tables (modulo shard) and every per-flow
    class decision, for num_shards in {1, 2, 4} on one seeded stream."""
    ref, sh = run_differential(params, num_shards)
    assert_residual_modulo_shard(ref, sh, num_shards)
    assert sh.trace_count == 1
    assert sh.stats.packets == ref.stats.packets  # padding never counted
    if num_shards > 1:
        assert sh.stats.padded > 0


@pytest.mark.parametrize("tracker", ["segmented", "scan"])
def test_sharded_trackers_agree(params, tracker):
    ref, sh = run_differential(params, 2, tracker=tracker, steps=10)
    assert_residual_modulo_shard(ref, sh, 2)


def test_sharded_multi_round_matches_lockstep(params):
    """A small lane_batch only changes dispatch granularity: the overflow
    rounds compose sequentially, bit-exact to the skew-proof single round."""
    cfg = PipelineConfig(batch_size=24, max_ready=8, flow_model="transformer",
                         table_size=64, top_n=6, top_k=15, pay_bytes=16)
    a = ShardedOctopusPipeline(params["mlp"], params["transformer"], cfg,
                               num_shards=4)
    b = ShardedOctopusPipeline(params["mlp"], params["transformer"], cfg,
                               num_shards=4, lane_batch=8)

    def gen():
        return TrafficGenerator(TrafficConfig(
            batch_size=24, active_flows=12, elephant_fraction=0.5,
            table_size=64, seed=7))

    ga, gb = gen(), gen()
    for _ in range(12):
        oa, ob = a.step(ga.next_batch()), b.step(gb.next_batch())
        for x, y in zip(jax.tree_util.tree_leaves(oa),
                        jax.tree_util.tree_leaves(ob)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(jax.tree_util.tree_leaves(a.state),
                    jax.tree_util.tree_leaves(b.state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert b.stats.dispatches > a.stats.dispatches  # rounds actually spilled
    assert b.stats.packets == a.stats.packets  # honest packet accounting
    assert b.rules.rules == a.rules.rules


def test_forced_cross_shard_collision(params):
    """Two flows whose hashes collide mod num_shards (same shard) AND on the
    same table slot: the in-lane eviction dance must match the single-lane
    oracle bit-for-bit — the freeing rule is shard-local state, preserved by
    hash partitioning."""
    S, table = 4, 32
    h1 = 101
    h2 = next(h for h in range(h1 + S, 50_000, S)
              if ft.hash_slot_scalar(h, table) == ft.hash_slot_scalar(h1, table))
    assert shard_of(h1, S) == shard_of(h2, S)

    cfg = PipelineConfig(batch_size=8, max_ready=4, flow_model="transformer",
                         table_size=table, top_n=4, top_k=15, pay_bytes=16)
    ref = OctopusPipeline(params["mlp"], params["transformer"], cfg)
    sh = ShardedOctopusPipeline(params["mlp"], params["transformer"], cfg,
                                num_shards=S)
    # h1 sends 3 (below top_n), h2 collides and evicts, then h2 drains;
    # then h1 re-establishes over h2's drained slot
    seq = [
        make_batch([h1] * 3 + [h2] * 5, [10, 20, 30, 40, 50, 60, 70, 80]),
        make_batch([h1] * 8, [90 + 10 * i for i in range(8)]),
    ]
    drained_ref, drained_sh = {}, {}
    for batch in seq:
        o_ref, o_sh = ref.step(batch), sh.step(batch)
        np.testing.assert_array_equal(np.asarray(o_ref.pkt_actions),
                                      np.asarray(o_sh.pkt_actions))
        assert int(o_ref.new_flows) == int(o_sh.new_flows)
        assert int(o_ref.evicted) == int(o_sh.evicted)
        # drained-row ORDER may differ (lane-major vs slot-major); the union
        # of emitted snapshots must not
        collect_drained(o_ref, drained_ref)
        collect_drained(o_sh, drained_sh)
    assert drained_ref == drained_sh and set(drained_ref) == {h1, h2}
    assert ref.stats.evicted == sh.stats.evicted > 0  # the collision fired
    assert ref.stats.flows == sh.stats.flows >= 2  # both flows drained
    lane = shard_of(h1, S)
    for x, y in zip(jax.tree_util.tree_leaves(ref.state),
                    jax.tree_util.tree_leaves(sh.state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y[lane]))


def test_sharded_chunked_dispatch_matches_per_step(params):
    """scan_len > 1 over the sharded step: same drained union and rule table
    as the per-step sharded run, one trace, steps/scan_len dispatches."""
    ref, sh = run_differential(params, 2, scan_len=4, steps=12)
    assert sh.trace_count == 1
    assert sh.stats.dispatches == 3 and sh.stats.steps == 12
    assert sh.stats.packets == 12 * 24
    # padded counts per step: lockstep lanes pad (S*C - B) rows each step
    assert sh.stats.padded == 12 * (2 * 24 - 24)


# ------------------------------------------------- partition conservation

def check_partition_conservation(batch: ft.PacketBatch, num_shards: int,
                                 lane_batch=None):
    """Shared invariant checker: every valid packet appears in exactly one
    shard/round with keep set, on the lane shard_of names, in arrival order;
    padding rows are zeroed with src == P."""
    n = int(np.asarray(batch.ts).shape[0])
    hashes = np.asarray(batch.tuple_hash)
    rounds = partition_batch(batch, num_shards, lane_batch=lane_batch)
    seen = []
    for sb in rounds:
        keep = np.asarray(sb.keep)
        src = np.asarray(sb.src)
        for lane in range(num_shards):
            idx = src[lane][keep[lane]]
            seen.extend(idx.tolist())
            # lane assignment is a pure function of tuple_hash
            np.testing.assert_array_equal(shard_of(hashes[idx], num_shards),
                                          lane)
            # kept rows carry the original packet fields verbatim
            for f_src, f_dst in zip(batch, sb.shards):
                np.testing.assert_array_equal(
                    np.asarray(f_src)[idx], np.asarray(f_dst)[lane][keep[lane]])
            # padding rows are inert: zeroed fields, sentinel src
            pad = ~keep[lane]
            assert (src[lane][pad] == n).all()
            for f_dst in sb.shards:
                assert (np.asarray(f_dst)[lane][pad] == 0).all()
        # per-lane arrival order is preserved within and across rounds
    assert sorted(seen) == list(range(n))  # exactly-once conservation
    for lane in range(num_shards):
        lane_order = [i for sb in rounds
                      for i in np.asarray(sb.src)[lane][np.asarray(sb.keep)[lane]]]
        assert lane_order == sorted(lane_order)
    return rounds


def random_batch(rng, n, pool, pay_bytes=4):
    return ft.PacketBatch(
        ts=jnp.asarray(np.cumsum(rng.integers(1, 50, n)).astype(np.int32)),
        size=jnp.asarray(rng.integers(40, 1500, n).astype(np.int32)),
        dir=jnp.asarray(rng.integers(0, 2, n).astype(np.int32)),
        flags=jnp.asarray(rng.integers(0, 64, n).astype(np.int32)),
        proto=jnp.asarray(rng.integers(0, 3, n).astype(np.int32)),
        tuple_hash=jnp.asarray(rng.choice(pool, n).astype(np.int32)),
        payload=jnp.asarray(rng.integers(0, 256, (n, pay_bytes)).astype(np.int32)))


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("num_shards", [1, 2, 3, 4])
def test_partition_conservation_seeded(seed, num_shards):
    rng = np.random.default_rng(seed)
    batch = random_batch(rng, 32, np.arange(1, 20))
    check_partition_conservation(batch, num_shards)
    check_partition_conservation(batch, num_shards, lane_batch=8)


def test_partition_validates_arguments():
    rng = np.random.default_rng(0)
    batch = random_batch(rng, 8, np.arange(1, 5))
    with pytest.raises(ValueError):
        partition_batch(batch, 0)
    with pytest.raises(ValueError):
        partition_batch(batch, 2, lane_batch=0)
    with pytest.raises(ValueError):
        partition_batch(batch, 2, lane_batch=9)


def test_shard_of_is_pure_and_host_device_consistent():
    rng = np.random.default_rng(0)
    hashes = rng.integers(1, 2**31 - 1, 200).astype(np.int32)
    for S in (1, 2, 3, 4, 8):
        dev = np.asarray(shard_of(jnp.asarray(hashes), S))
        host = shard_of(hashes, S)
        scalar = [shard_of(int(h), S) for h in hashes]
        np.testing.assert_array_equal(dev, host)
        np.testing.assert_array_equal(dev, scalar)
        assert (dev >= 0).all() and (dev < S).all()


def check_sharded_count_monotonicity(seed: int, num_shards: int,
                                     n_batches: int = 6, batch: int = 16,
                                     table_size: int = 32, top_n: int = 4):
    """Re-merge invariant (the sharded sibling of
    test_flow_tracker_props.check_stream_invariants): feeding each lane its
    partition keeps every flow's count identical to the unsharded tracker
    and monotone across batches — summed over lanes, nothing is lost or
    double-counted."""
    rng = np.random.default_rng(seed)
    program = default_program()
    # collision-free pool: distinct slots so lane-local state == global state
    pool, used = [], set()
    for h in range(1, 10_000):
        s = ft.hash_slot_scalar(h, table_size)
        if s not in used:
            used.add(s)
            pool.append(h)
        if len(pool) == 8:
            break
    ref = ft.init_state(table_size, top_n, 3, 4)
    lanes = [ft.init_state(table_size, top_n, 3, 4) for _ in range(num_shards)]
    last_counts: dict[int, int] = {}
    for _ in range(n_batches):
        b = random_batch(rng, batch, np.asarray(pool))
        ref, _ = ft.process_packets(ref, b, program, top_n=top_n)
        for sb in partition_batch(b, num_shards):
            for lane in range(num_shards):
                pkts = jax.tree_util.tree_map(lambda a: a[lane], sb.shards)
                lanes[lane], _ = ft.process_packets(
                    lanes[lane], pkts, program, top_n=top_n,
                    keep=sb.keep[lane])
        ref_count = np.asarray(ref.count)
        merged = np.zeros_like(ref_count)
        for lane_state in lanes:
            merged += np.asarray(lane_state.count)
        np.testing.assert_array_equal(ref_count, merged)
        for h in pool:
            s = ft.hash_slot_scalar(h, table_size)
            c = int(ref_count[s])
            assert c >= last_counts.get(s, 0)  # count monotone under re-merge
            last_counts[s] = c


@pytest.mark.parametrize("seed", range(3))
def test_sharded_count_monotonicity_seeded(seed):
    check_sharded_count_monotonicity(seed, num_shards=seed % 3 + 2)


# --------------------------------------------------------- hypothesis (CI)

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), num_shards=st.integers(1, 5),
       n=st.integers(1, 48))
def test_partition_conservation_property(seed, num_shards, n):
    rng = np.random.default_rng(seed)
    batch = random_batch(rng, n, np.arange(1, 30))
    check_partition_conservation(batch, num_shards)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), num_shards=st.integers(1, 4),
       lane_frac=st.integers(1, 4))
def test_partition_rounds_property(seed, num_shards, lane_frac):
    rng = np.random.default_rng(seed)
    n = 32
    batch = random_batch(rng, n, np.arange(1, 12))
    check_partition_conservation(batch, num_shards,
                                 lane_batch=max(1, n // lane_frac))


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), num_shards=st.integers(2, 4))
def test_sharded_count_monotonicity_property(seed, num_shards):
    check_sharded_count_monotonicity(seed, num_shards, n_batches=4)


# --------------------------------------------- retrace / donation / backends

def test_sharded_no_retrace_and_state_sustained(params):
    """One trace across sharded steps; per-shard TrackerState is donated to
    the jit'd step and carried — a flow split across global microbatches
    still reaches the ready threshold inside its lane."""
    cfg = PipelineConfig(batch_size=4, max_ready=4, flow_model="transformer",
                         table_size=16, top_n=8, top_k=15, pay_bytes=16)
    sh = ShardedOctopusPipeline(params["mlp"], params["transformer"], cfg,
                                num_shards=2)
    sh.warmup()
    assert sh.trace_count == 1
    assert all(d == 0 for d in
               (sh.stats.steps, sh.stats.dispatches))  # warmup is untimed

    h = 77
    out1 = sh.step(make_batch([h] * 4, [100, 110, 120, 130]))
    assert int(np.asarray(out1.drained.mask).sum()) == 0
    old_state = sh.state
    out2 = sh.step(make_batch([h] * 4, [140, 150, 160, 170]))
    mask = np.asarray(out2.drained.mask)
    assert int(mask.sum()) == 1
    row = int(np.flatnonzero(mask)[0])
    assert int(out2.drained.tuple_id[row]) == h
    assert int(out2.drained.count[row]) == 8
    assert row // sh.lane_ready == shard_of(h, 2)  # drained from its lane
    assert sh.trace_count == 1  # cache hits only: no per-step retrace
    # the state argument is donated: the previous buffers are consumed by
    # the dispatch (deleted) wherever the backend supports donation
    del old_state
    assert sh.stats.steps == 2 and sh.stats.packets == 8


def test_step_many_dispatches_every_overflow_round(params):
    """Regression: with lane_batch < batch_size and scan_len == 1 (the only
    chunked shape the constructor allows for multi-round mode), step_many
    must not drop the overflow rounds — skewed batches whose packets all
    land in one lane keep every packet."""
    cfg = PipelineConfig(batch_size=8, max_ready=2, flow_model="transformer",
                         table_size=16, top_n=8, top_k=15, pay_bytes=16)
    sh = ShardedOctopusPipeline(params["mlp"], params["transformer"], cfg,
                                num_shards=2, lane_batch=2)
    h = 4  # even: every packet lands in lane 0 -> 4 overflow rounds
    assert shard_of(h, 2) == 0
    out = sh.step_many([make_batch([h] * 8, [10 * i for i in range(1, 9)])])
    assert out.pkt_actions.shape == (1, 8)  # stacked like the lockstep path
    assert int(np.asarray(out.drained.mask).sum()) == 1  # all 8 pkts tracked
    assert sh.stats.steps == 1 and sh.stats.packets == 8
    assert sh.stats.dispatches == 4  # the rounds actually dispatched


def test_sharded_step_rejects_wrong_batch_size(params):
    cfg = PipelineConfig(batch_size=8, max_ready=2, flow_model="cnn",
                         table_size=64)
    sh = ShardedOctopusPipeline(params["mlp"], params["cnn"], cfg,
                                num_shards=2)
    with pytest.raises(ValueError, match="batch_size"):
        sh.step(make_batch([1] * 4, [1, 2, 3, 4]))


def test_sharded_config_validation(params):
    cfg = PipelineConfig(batch_size=8, max_ready=4, flow_model="cnn",
                         table_size=64)
    with pytest.raises(ValueError, match="num_shards"):
        ShardedOctopusPipeline(params["mlp"], params["cnn"], cfg, num_shards=0)
    with pytest.raises(ValueError, match="divide"):
        ShardedOctopusPipeline(params["mlp"], params["cnn"], cfg, num_shards=3)
    with pytest.raises(ValueError, match="lane_batch"):
        ShardedOctopusPipeline(params["mlp"], params["cnn"], cfg, num_shards=2,
                               lane_batch=9)
    with pytest.raises(ValueError, match="backend"):
        ShardedOctopusPipeline(params["mlp"], params["cnn"], cfg, num_shards=2,
                               backend="pmap")
    chunked = PipelineConfig(batch_size=8, max_ready=4, flow_model="cnn",
                             table_size=64, scan_len=2)
    with pytest.raises(ValueError, match="lane_batch"):
        ShardedOctopusPipeline(params["mlp"], params["cnn"], chunked,
                               num_shards=2, lane_batch=4)


def test_sharded_explain_scopes_lanes(params):
    cfg = PipelineConfig(batch_size=16, max_ready=4, flow_model="cnn",
                         table_size=64)
    sh = ShardedOctopusPipeline(params["mlp"], params["cnn"], cfg,
                                num_shards=2)
    plan = sh.plan()
    assert len(plan.scoped("lane0")) == len(plan.scoped("lane1")) == 9
    assert len(plan.scoped("lane0").scoped("lane0/pkt")) == 4
    text = sh.explain()
    assert "lanes=2" in text and "lane_batch=16" in text
    assert "lane0: 4 pkt + 5 flow matmuls" in text
    assert "lane1:" in text


@pytest.mark.skipif(jax.local_device_count() < 2,
                    reason="shard_map parity needs >= 2 devices")
def test_vmap_vs_shard_map_parity_direct(params):
    """On multi-device hosts the two lane backends must be bit-identical."""
    cfg = PipelineConfig(batch_size=16, max_ready=4, flow_model="cnn",
                         table_size=64)
    gen = lambda: TrafficGenerator(TrafficConfig(
        batch_size=16, active_flows=8, elephant_fraction=0.5, table_size=64,
        seed=3))
    a = ShardedOctopusPipeline(params["mlp"], params["cnn"], cfg,
                               num_shards=2, backend="vmap")
    b = ShardedOctopusPipeline(params["mlp"], params["cnn"], cfg,
                               num_shards=2, backend="shard_map")
    ga, gb = gen(), gen()
    for _ in range(6):
        oa, ob = a.step(ga.next_batch()), b.step(gb.next_batch())
        for x, y in zip(jax.tree_util.tree_leaves(oa),
                        jax.tree_util.tree_leaves(ob)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(jax.tree_util.tree_leaves(a.state),
                    jax.tree_util.tree_leaves(b.state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.slow
def test_vmap_vs_shard_map_parity_subprocess():
    """Force 4 host devices in a subprocess (the flag must precede jax init)
    and assert the shard_map lanes match the vmap lanes bit-for-bit."""
    code = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, numpy as np
    from repro.data.traffic import TrafficConfig, TrafficGenerator
    from repro.models import paper_models
    from repro.runtime import platform
    from repro.serving import PipelineConfig, ShardedOctopusPipeline

    assert jax.local_device_count() == 4
    assert platform.lanes_backend(4) == "shard_map"
    pm = paper_models.init_paper_model("mlp", jax.random.PRNGKey(0))
    pc = paper_models.init_paper_model("cnn", jax.random.PRNGKey(1))
    cfg = PipelineConfig(batch_size=16, max_ready=4, flow_model="cnn",
                         table_size=64)
    gen = lambda: TrafficGenerator(TrafficConfig(
        batch_size=16, active_flows=8, elephant_fraction=0.5, table_size=64,
        seed=3))
    a = ShardedOctopusPipeline(pm, pc, cfg, num_shards=4, backend="vmap")
    b = ShardedOctopusPipeline(pm, pc, cfg, num_shards=4)  # auto: shard_map
    assert b.backend == "shard_map"
    ga, gb = gen(), gen()
    for _ in range(6):
        oa, ob = a.step(ga.next_batch()), b.step(gb.next_batch())
        for x, y in zip(jax.tree_util.tree_leaves(oa),
                        jax.tree_util.tree_leaves(ob)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(jax.tree_util.tree_leaves(a.state),
                    jax.tree_util.tree_leaves(b.state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert a.rules.rules == b.rules.rules
    print("OK shard_map == vmap")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900, env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-4000:]}"
    assert "OK shard_map == vmap" in out.stdout
