"""Teacher-forced decode/prefill logits must match the train-mode forward for
every decoding arch (validates KV caches, ring buffers, recurrent states)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs, reduced_config
from repro.models import LM

DECODE_ARCHS = [a for a in list_archs()
                if get_config(a).supports_decode]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    cfg = reduced_config(get_config(arch))
    m = LM(cfg)
    key = jax.random.PRNGKey(42)
    params = m.init(key)
    B, S = 2, 17  # odd length exercises chunk padding
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.frontend == "vision_patches":
        batch["vision"] = jax.random.normal(key, (B, cfg.num_image_tokens, cfg.d_model),
                                            jnp.float32)
    extras = {k: v for k, v in batch.items() if k != "tokens"}
    logits_full, _ = jax.jit(m.forward)(params, batch)

    cache = m.init_cache(B, 64)
    _, cache = jax.jit(m.prefill)(params, {"tokens": toks[:, : S - 3], **extras}, cache)
    for t in range(S - 3, S):
        lg, cache = jax.jit(m.decode_step)(params, {"tokens": toks[:, t : t + 1], **extras},
                                           cache)
        err = float(jnp.abs(lg[:, 0] - logits_full[:, t]).max())
        assert err < 2e-2, (arch, t, err)


def test_prefill_last_logit_matches_forward():
    cfg = reduced_config(get_config("qwen3-0.6b"))
    m = LM(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
    logits_full, _ = jax.jit(m.forward)(params, {"tokens": toks})
    cache = m.init_cache(2, 32)
    lg, _ = jax.jit(m.prefill)(params, {"tokens": toks}, cache)
    err = float(jnp.abs(lg[:, 0] - logits_full[:, -1]).max())
    assert err < 2e-3, err


def test_sliding_window_ring_buffer():
    """gemma3 local attention: decode far beyond the window must equal the
    train-mode forward (ring overwrite correctness)."""
    cfg = reduced_config(get_config("gemma3-1b"))
    m = LM(cfg)  # window 16 after reduction
    params = m.init(jax.random.PRNGKey(0))
    B, S = 1, 40  # > 2x window
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    logits_full, _ = jax.jit(m.forward)(params, {"tokens": toks})
    cache = m.init_cache(B, 64)
    _, cache = jax.jit(m.prefill)(params, {"tokens": toks[:, :-5]}, cache)
    for t in range(S - 5, S):
        lg, cache = jax.jit(m.decode_step)(params, {"tokens": toks[:, t : t + 1]}, cache)
        err = float(jnp.abs(lg[:, 0] - logits_full[:, t]).max())
        assert err < 2e-2, (t, err)
