"""Checkpointing: roundtrip, atomicity, retention, async error surfacing,
and bit-exact resume through the trainer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree


def tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2,), jnp.bfloat16), "c": jnp.asarray(3, jnp.int32)},
    }


def test_roundtrip(tmp_path):
    t = tree()
    path = save_pytree(t, str(tmp_path), step=7, extra={"note": "hi"})
    restored, extra = load_pytree(path, jax.tree.map(jnp.zeros_like, t))
    assert extra == {"note": "hi"}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_atomic_no_tmp_left(tmp_path):
    save_pytree(tree(), str(tmp_path), step=1)
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_writes=False)
    for s in (1, 2, 3, 4):
        mgr.save(tree(), s)
    assert mgr.all_steps() == [3, 4]


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_writes=True)
    mgr.save(tree(), 5, extra={"next_step": 5})
    mgr.wait()
    restored, extra, step = mgr.restore(jax.tree.map(jnp.zeros_like, tree()))
    assert step == 5 and extra["next_step"] == 5


def test_missing_leaf_raises(tmp_path):
    path = save_pytree({"a": jnp.ones(3)}, str(tmp_path), step=1)
    with pytest.raises(KeyError):
        load_pytree(path, {"a": jnp.zeros(3), "b": jnp.zeros(2)})


def test_trainer_resume_bit_exact(tmp_path):
    """Run 20 steps straight vs 10 + crash + resume 10: identical trajectory."""
    from repro.configs import get_config, reduced_config
    from repro.data.tokens import TokenPipelineConfig
    from repro.train.loop import Trainer, TrainLoopConfig

    cfg = reduced_config(get_config("qwen3-0.6b"))
    data = TokenPipelineConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)

    d1 = str(tmp_path / "straight")
    t1 = Trainer(cfg, TrainLoopConfig(total_steps=20, checkpoint_every=10,
                                      checkpoint_dir=d1, log_every=100,
                                      async_checkpoints=False), data)
    out1 = t1.run()

    d2 = str(tmp_path / "resumed")
    t2 = Trainer(cfg, TrainLoopConfig(total_steps=20, checkpoint_every=10,
                                      checkpoint_dir=d2, log_every=100,
                                      fail_at_step=13, async_checkpoints=False), data)
    with pytest.raises(RuntimeError):
        t2.run()
    t3 = Trainer(cfg, TrainLoopConfig(total_steps=20, checkpoint_every=10,
                                      checkpoint_dir=d2, log_every=100,
                                      async_checkpoints=False), data)
    out3 = t3.run()
    np.testing.assert_allclose(out1["history"][10:], out3["history"],
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(out1["final_loss"], out3["final_loss"], rtol=1e-5)
