"""Feature extracting domain: tracker semantics (establish/update/evict/ready/
release), scan-vs-segmented equivalence (empty table, live-state composition,
collision fallback, Pallas arms), whole-feature derivation (Table 7)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import assert_states_equal
from hypothesis_compat import given, settings, st

from repro.core import flow_tracker as ft
from repro.core.feature_extractor import (
    ExtractorConfig,
    FeatureExtractor,
    derive_whole_features,
    segmented_update,
)
from repro.data.packets import PacketTraceConfig, synth_packet_trace
from repro.kernels.flow_features.ops import HIST


def make_extractor(**kw):
    return FeatureExtractor(ExtractorConfig(**kw))


def test_flow_establish_and_ready():
    ex = make_extractor(table_size=64, top_n=3)
    st_ = ex.init_state()
    pkts = ft.PacketBatch(
        ts=jnp.asarray([10, 20, 30, 40], jnp.int32),
        size=jnp.asarray([100, 200, 300, 50], jnp.int32),
        dir=jnp.asarray([0, 1, 0, 0], jnp.int32),
        flags=jnp.asarray([1, 2, 4, 8], jnp.int32),
        proto=jnp.asarray([1, 1, 1, 2], jnp.int32),
        tuple_hash=jnp.asarray([7, 7, 7, 9], jnp.int32),
        payload=jnp.zeros((4, 16), jnp.int32),
    )
    st2, outs = ex.extract_scan(st_, pkts)
    assert list(np.asarray(outs.new_flow)) == [True, False, False, True]
    assert list(np.asarray(outs.ready)) == [False, False, True, False]
    slot = int(outs.slot[0])
    feats = np.asarray(st2.features[slot])
    assert feats[HIST["pkt_count"]] == 3
    assert feats[HIST["flow_size"]] == 600
    assert feats[HIST["flow_dur"]] == 20  # 10 + 10
    assert feats[HIST["max_size"]] == 300
    assert feats[HIST["min_size"]] == 100
    assert feats[HIST["size_fwd"]] == 400
    assert feats[HIST["size_bwd"]] == 200
    # series memory holds per-packet intervals
    assert list(np.asarray(st2.series[slot])[:3]) == [0, 10, 10]


def test_collision_evicts_stale_flow():
    ex = make_extractor(table_size=8, top_n=5)
    st_ = ex.init_state()
    # two tuples that collide onto the same slot
    h1, h2 = None, None
    base = int(ft.hash_slot(jnp.asarray([123], jnp.int32), 8)[0])
    cands = []
    for t in range(200, 400):
        if int(ft.hash_slot(jnp.asarray([t], jnp.int32), 8)[0]) == base:
            cands.append(t)
        if len(cands) == 2:
            break
    h1, h2 = cands
    pkts = ft.PacketBatch(
        ts=jnp.asarray([1, 2, 3], jnp.int32),
        size=jnp.asarray([10, 20, 30], jnp.int32),
        dir=jnp.zeros(3, jnp.int32), flags=jnp.zeros(3, jnp.int32),
        proto=jnp.zeros(3, jnp.int32),
        tuple_hash=jnp.asarray([h1, h2, h2], jnp.int32),
        payload=jnp.zeros((3, 16), jnp.int32),
    )
    st2, outs = ex.extract_scan(st_, pkts)
    assert list(np.asarray(outs.evicted)) == [False, True, False]
    slot = int(outs.slot[0])
    assert int(st2.features[slot][HIST["pkt_count"]]) == 2  # only h2's packets


def test_release_recycles_storage():
    ex = make_extractor(table_size=16, top_n=2)
    st_ = ex.init_state()
    pkts = ft.PacketBatch(
        ts=jnp.asarray([1, 2], jnp.int32), size=jnp.asarray([5, 6], jnp.int32),
        dir=jnp.zeros(2, jnp.int32), flags=jnp.zeros(2, jnp.int32),
        proto=jnp.zeros(2, jnp.int32), tuple_hash=jnp.asarray([3, 3], jnp.int32),
        payload=jnp.zeros((2, 16), jnp.int32),
    )
    st2, outs = ex.extract_scan(st_, pkts)
    slot = int(outs.slot[0])
    st3 = ft.release_flows(st2, jnp.asarray([slot]))
    assert int(st3.count[slot]) == 0


def test_segmented_matches_scan_on_trace():
    cfg = PacketTraceConfig(num_flows=50, pkts_per_flow=8, seed=3, table_size=512)
    packets, classes, hashes, labels = synth_packet_trace(cfg)
    ex = make_extractor(table_size=512, top_n=8, top_k=4, pay_bytes=16)
    st_ = ex.init_state()
    st_scan, _ = ex.extract_scan(st_, packets)
    feats, series, sizes, payload, counts = ex.extract_segmented(packets)
    occupied = np.asarray(counts) > 0
    np.testing.assert_array_equal(np.asarray(st_scan.features)[occupied],
                                  np.asarray(feats)[occupied])
    np.testing.assert_array_equal(np.asarray(st_scan.series)[occupied],
                                  np.asarray(series)[occupied])
    np.testing.assert_array_equal(np.asarray(st_scan.payload)[occupied],
                                  np.asarray(payload)[occupied])


def test_segmented_update_composes_with_live_state():
    """The microbatch merge must be exact when flows already live in the
    table: scan batch 1, segment-merge batch 2, compare against scanning
    both (full state, event counts included)."""
    cfg = PacketTraceConfig(num_flows=40, pkts_per_flow=8, seed=5, table_size=256)
    packets, *_ = synth_packet_trace(cfg)
    ex = make_extractor(table_size=256, top_n=8, top_k=4, pay_bytes=16)
    P = int(packets.ts.shape[0])
    b1 = jax.tree_util.tree_map(lambda a: a[: P // 2], packets)
    b2 = jax.tree_util.tree_map(lambda a: a[P // 2 :], packets)

    st_mid, _ = ft.process_packets(ex.init_state(), b1, ex.program, top_n=8)
    st_scan, outs = ft.process_packets(st_mid, b2, ex.program, top_n=8)
    st_seg, seg = ex.segmented_update(st_mid, b2)
    assert_states_equal(st_scan, st_seg)
    assert int(seg.new_flows) == int(np.asarray(outs.new_flow).sum())
    assert int(seg.evicted) == int(np.asarray(outs.evicted).sum())
    assert int(seg.fallback_slots) == 0  # collision-free trace: no fallback


def test_segmented_update_collision_fallback_exact():
    """In-batch slot collisions (mixed tuple hashes in one segment) must take
    the scan oracle's values — bit-exact state and event counts."""
    cfg = PacketTraceConfig(num_flows=40, pkts_per_flow=6, seed=7,
                            table_size=16, collision_free=False)
    packets, *_ = synth_packet_trace(cfg)
    ex = make_extractor(table_size=16, top_n=6, top_k=4, pay_bytes=16)
    st_scan, outs = ft.process_packets(ex.init_state(), packets, ex.program,
                                       top_n=6)
    st_seg, seg = jax.jit(ex.segmented_update)(ex.init_state(), packets)
    assert int(seg.fallback_slots) > 0  # the trace actually collides
    assert_states_equal(st_scan, st_seg)
    assert int(seg.new_flows) == int(np.asarray(outs.new_flow).sum())
    assert int(seg.evicted) == int(np.asarray(outs.evicted).sum())


def test_segmented_update_pallas_matches_oracle():
    """With use_pallas the feature lanes come from the Pallas ALU fold —
    still bit-exact, collisions included."""
    cfg = PacketTraceConfig(num_flows=30, pkts_per_flow=6, seed=9,
                            table_size=32, collision_free=False)
    packets, *_ = synth_packet_trace(cfg)
    ex = make_extractor(table_size=32, top_n=6, top_k=4, pay_bytes=16,
                        use_pallas=True, interpret=True)
    st_scan, _ = ft.process_packets(ex.init_state(), packets, ex.program,
                                    top_n=6)
    st_seg, _ = ex.segmented_update(ex.init_state(), packets)
    assert_states_equal(st_scan, st_seg)


def test_extract_scan_pallas_arm_matches_plain():
    """The use_pallas arm of extract_scan replays the ALU fold through the
    kernel — identical state to the plain scan, establish/evict included."""
    cfg = PacketTraceConfig(num_flows=30, pkts_per_flow=6, seed=11,
                            table_size=32, collision_free=False)
    packets, *_ = synth_packet_trace(cfg)
    plain = make_extractor(table_size=32, top_n=6, top_k=4, pay_bytes=16)
    pallas = make_extractor(table_size=32, top_n=6, top_k=4, pay_bytes=16,
                            use_pallas=True, interpret=True)
    st_a, outs_a = plain.extract_scan(plain.init_state(), packets)
    st_b, outs_b = pallas.extract_scan(pallas.init_state(), packets)
    assert_states_equal(st_a, st_b)
    for name, x, y in zip(outs_a._fields, outs_a, outs_b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"StepOut.{name}")


def test_segmented_update_rejects_custom_program_without_pallas():
    """The jnp segment-reduction lanes hard-code the default program; a
    different concrete program must be refused loudly (use_pallas folds any
    program, so it is exempt)."""
    cfg = PacketTraceConfig(num_flows=4, pkts_per_flow=2, seed=0, table_size=32)
    packets, *_ = synth_packet_trace(cfg)
    ex = make_extractor(table_size=32, top_n=4, top_k=4, pay_bytes=16)
    custom = jnp.zeros((16, 3), jnp.int32)
    with pytest.raises(ValueError, match="default"):
        segmented_update(ex.init_state(), packets, custom, top_n=4)
    # the same program folds fine through the Pallas kernel
    segmented_update(ex.init_state(), packets, custom, top_n=4,
                     use_pallas=True, interpret=True)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), nflows=st.integers(2, 30),
       npkts=st.integers(1, 10), collision_free=st.booleans())
def test_segmented_scan_property(seed, nflows, npkts, collision_free):
    table = 256 if collision_free else 16  # small table forces collisions
    cfg = PacketTraceConfig(num_flows=nflows, pkts_per_flow=npkts, seed=seed,
                            table_size=table, collision_free=collision_free)
    packets, *_ = synth_packet_trace(cfg)
    ex = make_extractor(table_size=table, top_n=max(npkts, 2), top_k=2, pay_bytes=16)
    st_scan, _ = ex.extract_scan(ex.init_state(), packets)
    feats, series, sizes, payload, counts = ex.extract_segmented(packets)
    np.testing.assert_array_equal(np.asarray(st_scan.features), np.asarray(feats))
    np.testing.assert_array_equal(np.asarray(st_scan.count), np.asarray(counts))
    np.testing.assert_array_equal(np.asarray(st_scan.series), np.asarray(series))


def test_derive_whole_features():
    ex = make_extractor(table_size=32, top_n=4)
    st_ = ex.init_state()
    pkts = ft.PacketBatch(
        ts=jnp.asarray([0, 10, 30], jnp.int32), size=jnp.asarray([100, 300, 200], jnp.int32),
        dir=jnp.asarray([0, 1, 0], jnp.int32), flags=jnp.ones(3, jnp.int32),
        proto=jnp.ones(3, jnp.int32), tuple_hash=jnp.asarray([5, 5, 5], jnp.int32),
        payload=jnp.zeros((3, 16), jnp.int32),
    )
    st2, outs = ex.extract_scan(st_, pkts)
    slot = int(outs.slot[0])
    w = np.asarray(derive_whole_features(st2.features[slot]))
    assert w[0] == 30  # duration
    assert w[1] == 3  # packets
    assert w[2] == 600  # flow size
    assert w[3] == 200  # mean size
    assert w[4] == 300 and w[5] == 100  # max/min size
    assert w[9] == 300 and w[10] == 300  # fwd/bwd sizes
