"""Bench trajectory CI gate: slim-point append, >25% pkt/s regression
detection, the [bench-skip] escape hatch, and the run.py failure contract
(raising suites AND silently-empty suites exit nonzero)."""
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from tools import bench_trend  # noqa: E402


def _artifact(pkt_per_s, extra_rows=()):
    """A minimal benchmarks/run.py --json artifact with every tracked row at
    ``pkt_per_s`` (plus any extra untracked rows)."""
    rows = [{"name": name, "us_per_call": 100.0,
             "derived": f"pkt_per_s={v};steps=24"}
            for name, v in pkt_per_s.items()]
    rows += [{"name": n, "us_per_call": 1.0, "derived": d}
             for n, d in extra_rows]
    return {"schema_version": 1, "smoke": True,
            "platform": {"backend": "cpu"},
            "suites": [{"suite": "pipeline(streaming)", "wall_s": 1.0,
                        "rows": rows, "error": None}]}


def _write_run(tmp_path, name, pkt_per_s, **kw):
    path = str(tmp_path / name)
    with open(path, "w") as f:
        json.dump(_artifact(pkt_per_s, **kw), f)
    return path


def _tracked(v):
    return {name: v for name in bench_trend.TRACKED}


def test_append_then_check_two_point_trajectory_green(tmp_path, capsys):
    traj = str(tmp_path / "traj")
    run1 = _write_run(tmp_path, "r1.json", _tracked(1000))
    assert bench_trend.main(["append", "--trajectory", traj, "--run", run1,
                             "--label", "aaa"]) == 0
    # flat-to-slightly-better second run passes the gate and appends
    run2 = _write_run(tmp_path, "r2.json", _tracked(1050))
    assert bench_trend.main(["check", "--trajectory", traj, "--run", run2]) == 0
    assert bench_trend.main(["append", "--trajectory", traj, "--run", run2,
                             "--label", "bbb"]) == 0
    points = bench_trend.load_trajectory(traj)
    assert [idx for idx, _ in points] == [1, 2]
    assert points[1][1]["label"] == "bbb"
    out = capsys.readouterr().out
    assert "within threshold" in out


def test_check_fails_on_tracked_drop(tmp_path, capsys):
    traj = str(tmp_path / "traj")
    run1 = _write_run(tmp_path, "r1.json", _tracked(1000))
    bench_trend.main(["append", "--trajectory", traj, "--run", run1])
    run2 = _write_run(tmp_path, "r2.json", _tracked(700))  # -30%
    assert bench_trend.main(["check", "--trajectory", traj, "--run", run2]) == 1
    out = capsys.readouterr().out
    assert "REGRESSION" in out and "[bench-skip]" in out


def test_skip_flag_reports_but_passes(tmp_path, capsys):
    traj = str(tmp_path / "traj")
    run1 = _write_run(tmp_path, "r1.json", _tracked(1000))
    bench_trend.main(["append", "--trajectory", traj, "--run", run1])
    run2 = _write_run(tmp_path, "r2.json", _tracked(500))
    assert bench_trend.main(["check", "--trajectory", traj, "--run", run2,
                             "--skip"]) == 0
    assert "not failing" in capsys.readouterr().out


def test_seed_baseline_reports_but_never_fails(tmp_path, capsys):
    """The committed bootstrap point (label "seed") was measured on another
    machine — a drop against it reports but exits zero.  The gate arms as
    soon as CI appends its own first point."""
    traj = str(tmp_path / "traj")
    run1 = _write_run(tmp_path, "r1.json", _tracked(1000))
    bench_trend.main(["append", "--trajectory", traj, "--run", run1,
                      "--label", "seed"])
    run2 = _write_run(tmp_path, "r2.json", _tracked(500))  # -50% vs seed
    assert bench_trend.main(["check", "--trajectory", traj, "--run", run2]) == 0
    assert "report-only" in capsys.readouterr().out
    bench_trend.main(["append", "--trajectory", traj, "--run", run2,
                      "--label", "ci-1"])
    run3 = _write_run(tmp_path, "r3.json", _tracked(300))  # -40% vs ci-1
    assert bench_trend.main(["check", "--trajectory", traj, "--run", run3]) == 1


def test_drop_within_threshold_passes(tmp_path):
    traj = str(tmp_path / "traj")
    run1 = _write_run(tmp_path, "r1.json", _tracked(1000))
    bench_trend.main(["append", "--trajectory", traj, "--run", run1])
    run2 = _write_run(tmp_path, "r2.json", _tracked(800))  # -20% < 25%
    assert bench_trend.main(["check", "--trajectory", traj, "--run", run2]) == 0


def test_untracked_rows_never_gate(tmp_path):
    traj = str(tmp_path / "traj")
    extra = (("pipeline_cnn_b32_segmented_x16_int8", "pkt_per_s=9000"),)
    run1 = _write_run(tmp_path, "r1.json", _tracked(1000), extra_rows=extra)
    bench_trend.main(["append", "--trajectory", traj, "--run", run1])
    # the int8 twin row collapses; tracked rows hold -> still green
    extra2 = (("pipeline_cnn_b32_segmented_x16_int8", "pkt_per_s=10"),)
    run2 = _write_run(tmp_path, "r2.json", _tracked(1000), extra_rows=extra2)
    assert bench_trend.main(["check", "--trajectory", traj, "--run", run2]) == 0
    # and the untracked row never entered the slim points
    (_, p), = bench_trend.load_trajectory(traj)
    assert "pipeline_cnn_b32_segmented_x16_int8" not in p["rows"]


def test_first_run_with_no_trajectory_is_green(tmp_path, capsys):
    run1 = _write_run(tmp_path, "r1.json", _tracked(1000))
    assert bench_trend.main(["check", "--trajectory", str(tmp_path / "none"),
                             "--run", run1]) == 0
    assert "no prior trajectory" in capsys.readouterr().out


def test_append_rejects_artifact_without_tracked_rows(tmp_path):
    path = str(tmp_path / "empty.json")
    with open(path, "w") as f:
        json.dump({"schema_version": 1, "suites": []}, f)
    assert bench_trend.main(["append", "--trajectory", str(tmp_path / "t"),
                             "--run", path]) == 1


def test_unreadable_points_are_skipped(tmp_path):
    traj = tmp_path / "traj"
    traj.mkdir()
    (traj / "BENCH_0001.json").write_text("{not json")
    (traj / "BENCH_0002.json").write_text(json.dumps({"schema_version": 99}))
    run1 = _write_run(tmp_path, "r1.json", _tracked(1000))
    # both points unusable -> behaves like an empty trajectory
    assert bench_trend.main(["check", "--trajectory", str(traj),
                             "--run", run1]) == 0


def test_summary_markdown_renders_curve(tmp_path, capsys):
    traj = str(tmp_path / "traj")
    for i, v in enumerate((1000, 1100)):
        run = _write_run(tmp_path, f"r{i}.json", _tracked(v))
        bench_trend.main(["append", "--trajectory", traj, "--run", run,
                          "--label", f"sha{i}"])
    capsys.readouterr()
    assert bench_trend.main(["summary", "--trajectory", traj,
                             "--markdown"]) == 0
    out = capsys.readouterr().out
    assert "### Bench trajectory (2 runs)" in out
    assert "| 1 | sha0 |" in out and "| 2 | sha1 |" in out
    assert "1000" in out and "1100" in out


# ---------------------------------------------------------------------------
# run.py failure contract
# ---------------------------------------------------------------------------

def _patched_run(monkeypatch, suites):
    from benchmarks import run as bench_run

    monkeypatch.setattr(bench_run, "_suites", lambda smoke: suites)
    return bench_run


def test_run_fails_when_suite_raises(tmp_path, monkeypatch, capsys):
    def boom():
        raise RuntimeError("suite exploded")
        yield  # pragma: no cover

    bench_run = _patched_run(monkeypatch, [("boom", boom)])
    path = str(tmp_path / "bench.json")
    assert bench_run.main(["--smoke", "--json", path]) == 1
    artifact = json.load(open(path))
    assert "suite exploded" in artifact["suites"][0]["error"]


def test_run_fails_when_suite_emits_no_rows(tmp_path, monkeypatch, capsys):
    bench_run = _patched_run(monkeypatch, [("silent", lambda: iter(()))])
    path = str(tmp_path / "bench.json")
    assert bench_run.main(["--smoke", "--json", path]) == 1
    artifact = json.load(open(path))
    assert artifact["suites"][0]["error"] == "no rows emitted"
    assert "no rows emitted" in capsys.readouterr().out


def test_run_artifact_records_quant_runtime(tmp_path, monkeypatch):
    def one_row():
        yield "r1,1.00,pkt_per_s=5"

    bench_run = _patched_run(monkeypatch, [("ok", one_row)])
    path = str(tmp_path / "bench.json")
    assert bench_run.main(["--smoke", "--json", path]) == 0
    artifact = json.load(open(path))
    rt = artifact["runtime"]
    assert rt["quantize"] is False and rt["quant_scales"] is None
    assert rt["quant_impl"] in ("auto", "native", "emulate")
