"""Gradient compression: quantization error bounds + error-feedback property."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.distributed.compression import (
    compress_tree,
    decode_int8,
    decompress_tree,
    encode_int8,
)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), scale=st.floats(1e-4, 1e3))
def test_int8_error_bound(seed, scale):
    g = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * scale
    deq = decode_int8(encode_int8(g))
    max_abs = float(jnp.max(jnp.abs(g)))
    err = float(jnp.max(jnp.abs(deq - g)))
    assert err <= max_abs / 127.0 + 1e-6  # half-step rounding bound (scaled)


def test_tree_roundtrip_structure():
    g = {"a": jnp.ones((4, 4)), "b": {"c": jnp.full((3,), -2.0)}}
    out = decompress_tree(compress_tree(g))
    assert jax.tree.structure(out) == jax.tree.structure(g)
    np.testing.assert_allclose(np.asarray(out["b"]["c"]), -2.0, rtol=1e-2)


def test_error_feedback_unbiased_over_time():
    """With error feedback, the accumulated applied update converges to the
    accumulated true gradient (residual stays bounded)."""
    rng = np.random.default_rng(0)
    e = jnp.zeros((64,))
    applied = jnp.zeros((64,))
    true_sum = jnp.zeros((64,))
    for step in range(50):
        g = jnp.asarray(rng.normal(0, 1e-3, 64), jnp.float32)  # tiny grads stress quantizer
        true_sum = true_sum + g
        c = encode_int8(g + e)
        deq = decode_int8(c)
        e = (g + e) - deq
        applied = applied + deq
    # residual is bounded by one quantization step, so averages converge
    assert float(jnp.max(jnp.abs(applied - true_sum))) <= float(jnp.max(jnp.abs(e))) + 1e-6
    assert float(jnp.max(jnp.abs(e))) < 1e-3
