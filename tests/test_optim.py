"""Optimizers: AdamW against hand-computed math, Adafactor memory shape +
convergence, clipping, schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adafactor, adamw, clip_by_global_norm, cosine_schedule, sgd


def test_adamw_matches_manual_math():
    lr, b1, b2, eps, wd = 0.1, 0.9, 0.95, 1e-8, 0.0
    opt = adamw(lr, b1, b2, eps, wd)
    params = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.5])}
    state = opt.init(params)
    p1, s1 = opt.update(g, state, params, jnp.asarray(0))
    m = 0.1 * 0.5
    v = 0.05 * 0.25
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.95)
    expect = 1.0 - lr * mhat / (np.sqrt(vhat) + eps)
    np.testing.assert_allclose(float(p1["w"][0]), expect, rtol=1e-6)


def test_adamw_weight_decay():
    opt = adamw(0.1, weight_decay=0.1)
    params = {"w": jnp.asarray([1.0])}
    g = {"w": jnp.asarray([0.0])}
    p1, _ = opt.update(g, opt.init(params), params, jnp.asarray(0))
    np.testing.assert_allclose(float(p1["w"][0]), 1.0 - 0.1 * 0.1 * 1.0, rtol=1e-6)


@pytest.mark.parametrize("make", [lambda: adamw(0.05), lambda: adafactor(0.05),
                                  lambda: sgd(0.01)])
def test_optimizers_minimize_quadratic(make):
    opt = make()
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1, (8, 4)), jnp.float32)}
    state = opt.init(params)

    def loss(p):
        return jnp.sum((p["w"] - 3.0) ** 2)

    l0 = float(loss(params))
    for step in range(150):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, jnp.asarray(step))
    assert float(loss(params)) < 0.05 * l0


def test_adafactor_state_is_factored():
    opt = adafactor(0.01)
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((32,))}
    st = opt.init(params)
    assert st.vr["w"].shape == (64,)
    assert st.vc["w"].shape == (32,)
    assert st.vr["b"].shape == (32,)
    n_opt = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(st))
    n_par = 64 * 32 + 32
    assert n_opt < 0.1 * n_par  # sub-linear optimizer memory


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}  # norm 5
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 5.0, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8], rtol=1e-5)
    unclipped, _ = clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(np.asarray(unclipped["a"]), [3.0, 4.0], rtol=1e-6)


def test_cosine_schedule():
    lr = cosine_schedule(1.0, warmup=10, total=110)
    assert float(lr(0)) == 0.0
    np.testing.assert_allclose(float(lr(5)), 0.5, rtol=1e-5)
    np.testing.assert_allclose(float(lr(10)), 1.0, rtol=1e-5)
    assert float(lr(110)) <= 0.11
