"""Octopus router: utilization model (incl. the paper's 9.3% example), path
equivalence, and the policy's routing decisions — all through the unified
runtime API (deprecated kwargs are covered in test_runtime.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import router
from repro.runtime import RuntimeConfig, octopus_runtime


def test_paper_utilization_example():
    # §3.2.3: first CNN layer (10,3)x(3,32) on a 32x32 array -> 9.3%
    u = router.systolic_utilization(10, 3, 32, array=32)
    assert abs(u - 0.09375) < 1e-9


def test_utilization_full_tiles():
    assert router.mxu_utilization(1024, 1024, 1024) == 1.0
    assert router.mxu_utilization(1024, 64, 1024) == 0.5
    assert router.mxu_utilization(4, 128, 128) == 0.5


def test_routing_decisions():
    assert router.route_matmul(10, 3, 32).path == "vpe"
    assert router.route_matmul(4096, 4096, 4096).path == "arype"
    assert router.route_matmul(20000, 3, 32).path == "vpe"  # CNN layer 1, f=1000
    forced = RuntimeConfig(policy="arype_only")
    assert router.route_matmul(10000, 96, 32, config=forced).path == "arype"
    # big working set never goes to VPE even at low util
    assert router.route_matmul(10**6, 64, 64).path == "arype"


def test_routing_follows_ambient_runtime():
    with octopus_runtime(RuntimeConfig(policy="vpe_only")):
        assert router.route_matmul(4096, 4096, 4096).path == "vpe"
    assert router.route_matmul(4096, 4096, 4096).path == "arype"


@pytest.mark.parametrize("policy", ["collaborative", "arype_only", "vpe_only"])
@pytest.mark.parametrize("shape", [((4, 10, 3), (3, 32)), ((128, 64), (64, 96)),
                                   ((2, 3, 7, 5), (5, 9))])
def test_matmul_path_equivalence(policy, shape):
    xs, ws = shape
    x = jax.random.normal(jax.random.PRNGKey(0), xs, jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), ws, jnp.float32)
    out = router.matmul(x, w, config=RuntimeConfig(policy=policy))
    ref = jnp.einsum("...k,kn->...n", x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 300), k=st.integers(1, 300), n=st.integers(1, 300),
       act=st.sampled_from([None, "relu", "silu", "gelu"]))
def test_matmul_property(m, k, n, act):
    x = jax.random.normal(jax.random.PRNGKey(m * 7 + k), (m, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(n), (k, n), jnp.float32)
    out = router.matmul(x, w, activation=act)
    ref = jnp.dot(x, w)
    if act == "relu":
        ref = jnp.maximum(ref, 0)
    elif act == "silu":
        ref = ref * jax.nn.sigmoid(ref)
    elif act == "gelu":
        ref = jax.nn.gelu(ref)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_pallas_paths_match_jnp():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 48), jnp.float32)
    w_small = jax.random.normal(jax.random.PRNGKey(1), (48, 8), jnp.float32)
    w_big = jax.random.normal(jax.random.PRNGKey(2), (48, 256), jnp.float32)
    for w in (w_small, w_big):
        with octopus_runtime(RuntimeConfig(use_pallas=True, interpret=True)):
            a = router.matmul(x, w)
        b = router.matmul(x, w)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)
