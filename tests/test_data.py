"""Data pipelines: determinism, shard partition, learnability, packet traces."""
import numpy as np
import pytest

from repro.data.packets import PacketTraceConfig, synth_packet_trace
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.data.traffic import TrafficConfig, TrafficGenerator, merge_streams

from hypothesis_compat import given, settings, st


def test_token_batches_deterministic():
    cfg = TokenPipelineConfig(vocab_size=128, seq_len=32, global_batch=8, seed=5)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    for step in (0, 3, 17):
        b1, b2 = p1.batch(step), p2.batch(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_token_labels_shifted():
    cfg = TokenPipelineConfig(vocab_size=64, seq_len=16, global_batch=2)
    b = TokenPipeline(cfg).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_shards_partition_global_batch():
    base = TokenPipelineConfig(vocab_size=64, seq_len=8, global_batch=8, seed=1)
    TokenPipeline(base).batch(4)
    # different shards must produce different data; same shard reproducible
    s0 = TokenPipeline(base.__class__(**{**base.__dict__, "num_shards": 2, "shard": 0})).batch(4)
    s1 = TokenPipeline(base.__class__(**{**base.__dict__, "num_shards": 2, "shard": 1})).batch(4)
    assert s0["tokens"].shape == (4, 8)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_markov_stream_learnable():
    """The stream has low conditional entropy: a bigram table predicts it."""
    cfg = TokenPipelineConfig(vocab_size=64, seq_len=256, global_batch=4, branching=2)
    pipe = TokenPipeline(cfg)
    b = pipe.batch(0)
    correct = 0
    total = 0
    for row_t, row_l in zip(b["tokens"], b["labels"]):
        for t, l in zip(row_t, row_l):
            correct += int(l in pipe.table[t])
            total += 1
    assert correct / total > 0.9


def test_packet_trace_structure():
    cfg = PacketTraceConfig(num_flows=20, pkts_per_flow=5, seed=0, table_size=256)
    packets, classes, hashes, labels = synth_packet_trace(cfg)
    assert packets.ts.shape == (100,)
    assert np.all(np.diff(np.asarray(packets.ts)) >= 0)  # arrival order
    assert classes.shape == (20,) and hashes.shape == (20,) and labels.shape == (20,)
    assert packets.payload.shape == (100, 16)


# ------------------------------------------------------------- merge_streams

def _gen(client_id: int, seed: int) -> TrafficGenerator:
    return TrafficGenerator(TrafficConfig(
        batch_size=8, active_flows=8, table_size=64,
        seed=seed, client_id=client_id))


def _batch_key(batch):
    return (np.asarray(batch.ts).tolist(), np.asarray(batch.tuple_hash).tolist())


def test_traffic_generator_carries_client_id():
    assert TrafficGenerator(TrafficConfig()).client_id == 0
    assert _gen(7, 0).client_id == 7


def test_merge_streams_seed_stable():
    a = [_batch_key(b) for b in merge_streams(_gen(0, 1), _gen(1, 2),
                                              seed=5, steps=12)]
    b = [_batch_key(b) for b in merge_streams(_gen(0, 1), _gen(1, 2),
                                              seed=5, steps=12)]
    assert a == b  # same seed + same configs => the same stream, batch for batch

    c = [_batch_key(b) for b in merge_streams(_gen(0, 1), _gen(1, 2),
                                              seed=6, steps=12)]
    assert a != c  # the interleave really is seed-keyed


def test_merge_streams_requires_generators():
    with pytest.raises(ValueError, match="at least one"):
        next(merge_streams(seed=0, steps=1))


@settings(max_examples=15, deadline=None)
@given(num_clients=st.integers(min_value=1, max_value=4),
       seed=st.integers(min_value=0, max_value=2**16),
       steps=st.integers(min_value=1, max_value=10))
def test_merge_streams_conserves_per_client_order(num_clients, seed, steps):
    """Conservation: the merged stream is exactly each client's own stream,
    interleaved — no batch lost, duplicated, or reordered within a client."""
    gens = [_gen(cid, seed=100 + cid) for cid in range(num_clients)]
    merged = list(merge_streams(*gens, seed=seed, steps=steps, tagged=True))
    assert len(merged) == steps

    per_client: dict[int, list] = {}
    for cid, batch in merged:
        per_client.setdefault(cid, []).append(_batch_key(batch))
    assert set(per_client) <= set(range(num_clients))

    for cid, got in per_client.items():
        ref = _gen(cid, seed=100 + cid)  # same config => same solo stream
        want = [_batch_key(b) for b in ref.batches(len(got))]
        assert got == want


def test_packet_trace_collision_free():
    from repro.core.flow_tracker import hash_slot
    import jax.numpy as jnp

    cfg = PacketTraceConfig(num_flows=64, pkts_per_flow=2, seed=1, table_size=1024)
    _, _, hashes, _ = synth_packet_trace(cfg)
    slots = np.asarray(hash_slot(jnp.asarray(hashes), 1024))
    assert len(set(slots.tolist())) == 64


def test_traffic_collision_free_needs_room():
    # populations beyond the table need collision_free=False (two-level store)
    with pytest.raises(ValueError, match="collision_free"):
        TrafficGenerator(TrafficConfig(active_flows=65, table_size=64))
    gen = TrafficGenerator(TrafficConfig(active_flows=65, table_size=64,
                                         collision_free=False))
    assert len(gen._flows) == 65


def test_traffic_clock_overflow_raises():
    gen = TrafficGenerator(TrafficConfig(batch_size=4, active_flows=2,
                                         table_size=64, seed=0))
    gen.clock = 2**31 - 1  # int32 ts ceiling: the next tick must overflow
    with pytest.raises(RuntimeError, match="restart the generator"):
        gen.next_batch()


class _ScriptedRNG:
    """Wraps a Generator, forcing the first `integers` draws to a script."""

    def __init__(self, inner, script):
        self.inner, self.script = inner, list(script)

    def integers(self, *a, **k):
        if self.script:
            return self.script.pop(0)
        return self.inner.integers(*a, **k)

    def __getattr__(self, name):
        return getattr(self.inner, name)


def test_spawn_flow_rejects_duplicate_live_hash():
    """Regression: two live flows must never share a tuple hash, in ANY mode
    (collision_free only guarded slots) — the tracker would silently merge
    them while labels/counters see two flows."""
    gen = TrafficGenerator(TrafficConfig(batch_size=4, active_flows=2,
                                         table_size=64, seed=0,
                                         collision_free=False))
    live = next(iter(gen._live_hashes))
    gen.rng = _ScriptedRNG(gen.rng, [live, live, live + 1])
    f = gen._spawn_flow()
    assert f.tuple_hash == live + 1  # the two scripted duplicates rejected
    assert len(gen._live_hashes) == 3


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_live_hashes_unique_under_churn(seed):
    """Property: across heavy retire/respawn churn the live population keeps
    pairwise-distinct tuple hashes and the dedupe set mirrors it exactly."""
    gen = TrafficGenerator(TrafficConfig(
        batch_size=32, active_flows=24, table_size=32, seed=seed,
        collision_free=False, elephant_fraction=0.2))
    for _ in range(20):
        gen.next_batch()
        hashes = [f.tuple_hash for f in gen._flows]
        assert len(set(hashes)) == len(hashes)
        assert set(hashes) == gen._live_hashes
        assert {f.slot for f in gen._flows} <= set(range(32))


# ------------------------------------------------- adversarial traffic modes

def _adv_cfg(mode: str, *, client_id: int = 0, seed: int = 0) -> TrafficConfig:
    shaped = {
        "flash_crowd": dict(adv_period=2, collision_free=False),
        "elephant_storm": dict(burst_len=4),
        "collision_attack": dict(adv_slots=2, collision_free=False),
    }[mode]
    return TrafficConfig(batch_size=8, active_flows=8, table_size=64,
                         adversarial=mode, client_id=client_id, seed=seed,
                         **shaped)


def test_adversarial_config_validation():
    with pytest.raises(ValueError, match="adversarial must be one of"):
        TrafficConfig(adversarial="slowloris")
    with pytest.raises(ValueError, match="adv_period must be positive"):
        TrafficConfig(adversarial="flash_crowd", adv_period=0)
    with pytest.raises(ValueError, match="adv_slots must be in"):
        TrafficConfig(adversarial="collision_attack", collision_free=False,
                      adv_slots=0)
    with pytest.raises(ValueError, match="adv_slots must be in"):
        TrafficConfig(adversarial="collision_attack", collision_free=False,
                      table_size=16, adv_slots=17)
    with pytest.raises(ValueError, match="adv_shards must be >= 0"):
        TrafficConfig(adversarial="collision_attack", collision_free=False,
                      adv_shards=-1)
    with pytest.raises(ValueError, match="collision_free=False"):
        TrafficConfig(adversarial="collision_attack", collision_free=True)


def test_flash_crowd_collision_free_needs_room():
    with pytest.raises(ValueError, match="flash_crowd spawns"):
        TrafficGenerator(TrafficConfig(
            adversarial="flash_crowd", batch_size=32, active_flows=48,
            table_size=64, collision_free=True))
    # enough headroom: the crowd's extra live flows fit the table
    TrafficGenerator(TrafficConfig(
        adversarial="flash_crowd", batch_size=16, active_flows=32,
        table_size=64, collision_free=True))


def test_adversarial_merge_streams_seed_stable():
    """A mixed-mode merged stream (one client per attack) is reproducible
    batch for batch under the same merge seed."""
    modes = ("flash_crowd", "elephant_storm", "collision_attack")

    def stream(seed):
        gens = [TrafficGenerator(_adv_cfg(m, client_id=i, seed=10 + i))
                for i, m in enumerate(modes)]
        return [(cid, _batch_key(b)) for cid, b in
                merge_streams(*gens, seed=seed, steps=18, tagged=True)]

    assert stream(5) == stream(5)
    assert stream(5) != stream(6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**16),
       steps=st.integers(min_value=1, max_value=12))
def test_adversarial_merge_streams_conserve_per_client_order(seed, steps):
    """Conservation extends to adversarial configs: each attacking client's
    batches appear exactly once, in that client's own order, tagged with its
    client_id."""
    modes = ("flash_crowd", "elephant_storm", "collision_attack")
    gens = [TrafficGenerator(_adv_cfg(m, client_id=i, seed=100 + i))
            for i, m in enumerate(modes)]
    merged = list(merge_streams(*gens, seed=seed, steps=steps, tagged=True))
    assert len(merged) == steps

    per_client: dict[int, list] = {}
    for cid, batch in merged:
        per_client.setdefault(cid, []).append(_batch_key(batch))
    assert set(per_client) <= set(range(len(modes)))

    for cid, got in per_client.items():
        ref = TrafficGenerator(_adv_cfg(modes[cid], client_id=cid,
                                        seed=100 + cid))
        want = [_batch_key(b) for b in ref.batches(len(got))]
        assert got == want
