"""Data pipelines: determinism, shard partition, learnability, packet traces."""
import numpy as np

from repro.data.packets import PacketTraceConfig, synth_packet_trace
from repro.data.tokens import TokenPipeline, TokenPipelineConfig


def test_token_batches_deterministic():
    cfg = TokenPipelineConfig(vocab_size=128, seq_len=32, global_batch=8, seed=5)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    for step in (0, 3, 17):
        b1, b2 = p1.batch(step), p2.batch(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_token_labels_shifted():
    cfg = TokenPipelineConfig(vocab_size=64, seq_len=16, global_batch=2)
    b = TokenPipeline(cfg).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_shards_partition_global_batch():
    base = TokenPipelineConfig(vocab_size=64, seq_len=8, global_batch=8, seed=1)
    TokenPipeline(base).batch(4)
    # different shards must produce different data; same shard reproducible
    s0 = TokenPipeline(base.__class__(**{**base.__dict__, "num_shards": 2, "shard": 0})).batch(4)
    s1 = TokenPipeline(base.__class__(**{**base.__dict__, "num_shards": 2, "shard": 1})).batch(4)
    assert s0["tokens"].shape == (4, 8)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_markov_stream_learnable():
    """The stream has low conditional entropy: a bigram table predicts it."""
    cfg = TokenPipelineConfig(vocab_size=64, seq_len=256, global_batch=4, branching=2)
    pipe = TokenPipeline(cfg)
    b = pipe.batch(0)
    correct = 0
    total = 0
    for row_t, row_l in zip(b["tokens"], b["labels"]):
        for t, l in zip(row_t, row_l):
            correct += int(l in pipe.table[t])
            total += 1
    assert correct / total > 0.9


def test_packet_trace_structure():
    cfg = PacketTraceConfig(num_flows=20, pkts_per_flow=5, seed=0, table_size=256)
    packets, classes, hashes, labels = synth_packet_trace(cfg)
    assert packets.ts.shape == (100,)
    assert np.all(np.diff(np.asarray(packets.ts)) >= 0)  # arrival order
    assert classes.shape == (20,) and hashes.shape == (20,) and labels.shape == (20,)
    assert packets.payload.shape == (100, 16)


def test_packet_trace_collision_free():
    from repro.core.flow_tracker import hash_slot
    import jax.numpy as jnp

    cfg = PacketTraceConfig(num_flows=64, pkts_per_flow=2, seed=1, table_size=1024)
    _, _, hashes, _ = synth_packet_trace(cfg)
    slots = np.asarray(hash_slot(jnp.asarray(hashes), 1024))
    assert len(set(slots.tolist())) == 64
