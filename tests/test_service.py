"""Serving frontend: bucketed batching exactness, no-retrace-on-ragged
arrivals, admission control (shed + block), coalescing, buffer pooling, and
per-client latency observability.

The load-bearing test is the bucketed-padding differential: a request of
size ``b < bucket`` padded-then-served must produce verdicts, tracker state,
drained flows and rule-table contents bit-identical to serving it through
the unpadded synchronous pipeline — the keep-mask machinery from the sharded
lanes, re-used as the service's correctness story.
"""
import asyncio
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from asyncio_compat import async_test
from conftest import assert_states_equal

from repro.data.traffic import TrafficConfig, TrafficGenerator
from repro.models import paper_models
from repro.serving import (
    OctopusPipeline,
    OctopusService,
    PipelineConfig,
    Rejected,
    ServeResult,
    ServiceConfig,
    ShardedOctopusPipeline,
    serve_stream,
)


@pytest.fixture(scope="module")
def mlp_params():
    return paper_models.init_paper_model("mlp", jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def cnn_params():
    return paper_models.init_paper_model("cnn", jax.random.PRNGKey(1))


def make_pipeline(mlp_params, cnn_params, *, batch_size=32, max_ready=4,
                  table_size=128, num_shards=0, **kw):
    cfg = PipelineConfig(batch_size=batch_size, max_ready=max_ready,
                         flow_model="cnn", table_size=table_size, **kw)
    if num_shards:
        return ShardedOctopusPipeline(mlp_params, cnn_params, cfg,
                                      num_shards=num_shards)
    return OctopusPipeline(mlp_params, cnn_params, cfg)


def gen_of(batch_size, seed, client_id=0, table_size=128):
    return TrafficGenerator(TrafficConfig(
        batch_size=batch_size, active_flows=8, elephant_fraction=0.4,
        table_size=table_size, seed=seed, client_id=client_id))


def pad_batch(batch, bucket):
    """Tail-pad a PacketBatch to ``bucket`` rows; returns (padded, keep)."""
    n = int(np.asarray(batch.ts).shape[0])
    padded = jax.tree_util.tree_map(
        lambda a: jnp.concatenate(
            [a, jnp.zeros((bucket - n,) + a.shape[1:], a.dtype)]), batch)
    return padded, np.arange(bucket) < n


# ------------------------------------------------- config / surface guards

def test_service_config_validation():
    with pytest.raises(ValueError, match="buckets"):
        ServiceConfig(buckets=())
    with pytest.raises(ValueError, match="increasing"):
        ServiceConfig(buckets=(32, 16))
    with pytest.raises(ValueError, match="increasing"):
        ServiceConfig(buckets=(16, 16))
    with pytest.raises(ValueError, match="admission"):
        ServiceConfig(admission="drop")
    with pytest.raises(ValueError, match="positive"):
        ServiceConfig(depth_budget=0)
    with pytest.raises(ValueError, match="batch_wait_s"):
        ServiceConfig(batch_wait_s=-1.0)


@async_test
async def test_submit_before_start_raises(mlp_params, cnn_params):
    svc = OctopusService(make_pipeline(mlp_params, cnn_params))
    with pytest.raises(RuntimeError, match="not started"):
        await svc.submit(gen_of(4, 0).next_batch())


def test_warm_bucket_rejects_nonpositive(mlp_params, cnn_params):
    pipe = make_pipeline(mlp_params, cnn_params)
    with pytest.raises(ValueError, match="bucket"):
        pipe.warm_bucket(0)


# ------------------------------------------- bucketed padding differential

@pytest.mark.parametrize("tracker", ["segmented", "scan"])
def test_bucketed_padding_bit_exact_vs_sync_pipeline(mlp_params, cnn_params,
                                                     tracker):
    """Padded-masked serving == unpadded synchronous pipeline, bit for bit:
    verdicts, tracker state, drained emission, and the rule table."""
    b, bucket = 24, 32
    gen = gen_of(b, seed=3)
    ref = OctopusPipeline(mlp_params, cnn_params,
                          PipelineConfig(batch_size=b, max_ready=4,
                                         table_size=128, tracker=tracker))
    # a deliberately different cfg.batch_size: the masked entry must not
    # care about the config batch at all
    pad = OctopusPipeline(mlp_params, cnn_params,
                          PipelineConfig(batch_size=99, max_ready=4,
                                         table_size=128, tracker=tracker))
    pad.warm_bucket(bucket)
    for batch in gen.batches(6):
        o_ref = ref.step(batch)
        padded, keep = pad_batch(batch, bucket)
        o_pad = pad.step_masked(padded, keep)
        np.testing.assert_array_equal(np.asarray(o_ref.pkt_actions),
                                      np.asarray(o_pad.pkt_actions)[:b])
        np.testing.assert_array_equal(np.asarray(o_ref.drained.mask),
                                      np.asarray(o_pad.drained.mask))
        np.testing.assert_array_equal(np.asarray(o_ref.drained.tuple_id),
                                      np.asarray(o_pad.drained.tuple_id))
        np.testing.assert_array_equal(np.asarray(o_ref.flow_cls),
                                      np.asarray(o_pad.flow_cls))
        assert_states_equal(ref.state, pad.state)
    assert ref.rules.rules == pad.rules.rules
    # padding is accounted as padded rows, never as packets
    assert pad.stats.packets == ref.stats.packets == 6 * b
    assert pad.stats.padded == 6 * (bucket - b)


def test_bucketed_padding_bit_exact_sharded(mlp_params, cnn_params):
    """The same contract through the sharded lanes: masked bucket dispatch
    == the sharded pipeline stepping the unpadded batch."""
    b, bucket, S = 16, 24, 2
    gen = gen_of(b, seed=11)
    ref = make_pipeline(mlp_params, cnn_params, batch_size=b, num_shards=S)
    pad = make_pipeline(mlp_params, cnn_params, batch_size=48, num_shards=S)
    pad.warm_bucket(bucket)
    for batch in gen.batches(5):
        o_ref = ref.step(batch)
        padded, keep = pad_batch(batch, bucket)
        o_pad = pad.step_masked(padded, keep)
        np.testing.assert_array_equal(np.asarray(o_ref.pkt_actions),
                                      np.asarray(o_pad.pkt_actions)[:b])
        np.testing.assert_array_equal(np.asarray(o_ref.drained.mask),
                                      np.asarray(o_pad.drained.mask))
        np.testing.assert_array_equal(np.asarray(o_ref.drained.tuple_id),
                                      np.asarray(o_pad.drained.tuple_id))
        assert_states_equal(ref.state, pad.state)
    assert ref.rules.rules == pad.rules.rules


# ----------------------------------------------- no retrace across buckets

@async_test
async def test_ragged_sizes_never_retrace_after_warmup(mlp_params, cnn_params):
    """Acceptance: ragged request sizes spanning >= 3 buckets all pad to
    pre-warmed entry points — trace_count stays flat after start()."""
    pipe = make_pipeline(mlp_params, cnn_params)
    svc = OctopusService(pipe, ServiceConfig(buckets=(8, 16, 32)))
    async with svc:
        warmed = svc.trace_count
        assert warmed >= 3  # one masked trace per bucket
        for i, size in enumerate((3, 8, 11, 16, 17, 29, 32, 5, 24)):
            res = await svc.submit(gen_of(size, seed=i).next_batch())
            assert isinstance(res, ServeResult)
            assert res.pkt_actions.shape == (size,)
            assert res.bucket in (8, 16, 32) and res.bucket >= size
        assert svc.trace_count == warmed
    assert svc.stats.served == 3 + 8 + 11 + 16 + 17 + 29 + 32 + 5 + 24


@async_test
async def test_sharded_service_no_retrace(mlp_params, cnn_params):
    pipe = make_pipeline(mlp_params, cnn_params, batch_size=32, num_shards=2)
    svc = OctopusService(pipe, ServiceConfig(buckets=(8, 16)))
    async with svc:
        warmed = svc.trace_count
        for i, size in enumerate((5, 8, 13, 16, 3)):
            res = await svc.submit(gen_of(size, seed=i).next_batch())
            assert isinstance(res, ServeResult)
            assert res.pkt_actions.shape == (size,)
        assert svc.trace_count == warmed


# ------------------------------------------------------ batching semantics

@async_test
async def test_concurrent_submits_coalesce_into_one_dispatch(mlp_params,
                                                             cnn_params):
    """4 clients landing together become ONE padded bucket dispatch — the
    multiplexing win the frontend exists for."""
    pipe = make_pipeline(mlp_params, cnn_params)
    svc = OctopusService(pipe, ServiceConfig(buckets=(8, 16, 32)))
    async with svc:
        sizes = (5, 6, 7, 8)
        outs = await asyncio.gather(*(
            svc.submit(gen_of(n, seed=i).next_batch(), client_id=i)
            for i, n in enumerate(sizes)))
    assert all(isinstance(r, ServeResult) for r in outs)
    assert svc.stats.dispatches == 1
    assert svc.stats.coalesced == 4
    assert svc.stats.padded == 32 - sum(sizes)
    assert pipe.stats.packets == sum(sizes)


@async_test
async def test_coalescing_preserves_request_order_and_slices(mlp_params,
                                                             cnn_params):
    """Coalesced verdicts must slice back to requests exactly: serving two
    requests together equals serving their concatenation synchronously."""
    pipe = make_pipeline(mlp_params, cnn_params)
    svc = OctopusService(pipe, ServiceConfig(buckets=(32,)))
    b1 = gen_of(10, seed=1).next_batch()
    b2 = gen_of(12, seed=2).next_batch()
    async with svc:
        r1, r2 = await asyncio.gather(svc.submit(b1, client_id=1),
                                      svc.submit(b2, client_id=2))
    both = jax.tree_util.tree_map(
        lambda a, c: jnp.concatenate([a, c]), b1, b2)
    ref = OctopusPipeline(
        pipe.packet_engine.params, pipe.flow_engine.params,
        PipelineConfig(batch_size=22, max_ready=4, table_size=128))
    out = ref.step(both)
    acts = np.asarray(out.pkt_actions)
    np.testing.assert_array_equal(r1.pkt_actions, acts[:10])
    np.testing.assert_array_equal(r2.pkt_actions, acts[10:])


@async_test
async def test_oversized_request_splits_into_bucket_chunks(mlp_params,
                                                           cnn_params):
    pipe = make_pipeline(mlp_params, cnn_params)
    svc = OctopusService(pipe, ServiceConfig(buckets=(8, 16, 32),
                                             depth_budget=256))
    async with svc:
        res = await svc.submit(gen_of(70, seed=0).next_batch())
        assert isinstance(res, ServeResult)
        assert res.pkt_actions.shape == (70,)
    # 70 = 32 + 32 + 6 -> at least three dispatches, no lost packets
    assert svc.stats.dispatches >= 3
    assert svc.stats.served == 70 and pipe.stats.packets == 70


@async_test
async def test_empty_submit_answers_immediately(mlp_params, cnn_params):
    from repro.core.flow_tracker import PacketBatch

    empty = PacketBatch(
        ts=jnp.zeros((0,), jnp.int32), size=jnp.zeros((0,), jnp.int32),
        dir=jnp.zeros((0,), jnp.int32), flags=jnp.zeros((0,), jnp.int32),
        proto=jnp.zeros((0,), jnp.int32),
        tuple_hash=jnp.zeros((0,), jnp.int32),
        payload=jnp.zeros((0, 16), jnp.int32))
    svc = OctopusService(make_pipeline(mlp_params, cnn_params))
    async with svc:
        res = await svc.submit(empty)
        assert isinstance(res, ServeResult)
        assert res.pkt_actions.shape == (0,)
    assert svc.stats.requests == 0 and svc.stats.dispatches == 0


# ------------------------------------------------------- admission control

@async_test
async def test_shed_policy_rejects_over_budget(mlp_params, cnn_params):
    """Acceptance: overrun the depth budget -> explicit Rejected results,
    honest shed accounting, and everything accepted still gets served."""
    pipe = make_pipeline(mlp_params, cnn_params)
    svc = OctopusService(pipe, ServiceConfig(buckets=(16, 32),
                                             depth_budget=32,
                                             admission="shed"))
    async with svc:
        outs = await asyncio.gather(*(
            svc.submit(gen_of(16, seed=i).next_batch(), client_id=i)
            for i in range(4)))
    served = [r for r in outs if isinstance(r, ServeResult)]
    shed = [r for r in outs if isinstance(r, Rejected)]
    # submits enqueue in gather order: 16 + 16 fill the budget, 3rd and 4th shed
    assert len(served) == 2 and len(shed) == 2
    for r in shed:
        assert r.packets == 16
        assert r.depth_budget == 32 and r.queue_depth == 32
    s = svc.stats
    assert s.shed == 32 and s.served == 32 and s.submitted == 64
    assert s.shed_requests == 2 and s.served_requests == 2
    assert s.depth_hwm <= 32  # the budget really bounded the queue


@async_test
async def test_block_policy_serves_everything(mlp_params, cnn_params):
    pipe = make_pipeline(mlp_params, cnn_params)
    svc = OctopusService(pipe, ServiceConfig(buckets=(16, 32),
                                             depth_budget=32,
                                             admission="block"))
    async with svc:
        outs = await asyncio.gather(*(
            svc.submit(gen_of(16, seed=i).next_batch(), client_id=i)
            for i in range(5)))
    assert all(isinstance(r, ServeResult) for r in outs)
    assert svc.stats.shed == 0 and svc.stats.served == 80
    assert svc.stats.depth_hwm <= 32


# --------------------------------------------------- pooling + observability

@async_test
async def test_buffer_pool_reuses_staging_arrays(mlp_params, cnn_params):
    pipe = make_pipeline(mlp_params, cnn_params)
    svc = OctopusService(pipe, ServiceConfig(buckets=(16,)))
    async with svc:
        for i in range(8):
            await svc.submit(gen_of(10, seed=i).next_batch())
    # one miss allocates the bucket's staging struct; the rest reuse it
    assert svc.stats.pool_misses == 1
    assert svc.stats.pool_hits == 7


@async_test
async def test_per_client_and_global_latency_stats(mlp_params, cnn_params):
    pipe = make_pipeline(mlp_params, cnn_params)
    svc = OctopusService(pipe, ServiceConfig(buckets=(8, 16, 32)))
    # idle: percentile observability reports nan, never a fake 0
    assert math.isnan(svc.stats.wait.p50) and math.isnan(svc.stats.e2e.p99)
    async with svc:
        gens = [gen_of(bs, seed=i, client_id=i) for i, bs in
                enumerate((6, 11, 23))]
        outs = await asyncio.gather(*(
            serve_stream(svc, g, requests=4) for g in gens))
    for res_list, g in zip(outs, gens):
        for r in res_list:
            assert isinstance(r, ServeResult) and r.client_id == g.client_id
            assert 0 <= r.queue_wait_s <= r.e2e_s
    s = svc.stats
    assert set(s.clients) == {0, 1, 2}
    for cid, c in s.clients.items():
        assert c.requests == 4 and c.served == c.submitted
        assert c.e2e.p99 >= c.wait.p50 >= 0
        assert len(c.wait) == 4 and len(c.e2e) == 4
    assert len(s.wait) == 12 and s.e2e.p99 > 0
    assert s.depth_hwm > 0 and s.pkt_per_s > 0
    # the pipeline-level dispatch reservoir filled too
    assert pipe.stats.p99_us > 0


@async_test
async def test_queue_depth_returns_to_zero_after_drain(mlp_params, cnn_params):
    pipe = make_pipeline(mlp_params, cnn_params)
    svc = OctopusService(pipe, ServiceConfig(buckets=(32,)))
    async with svc:
        await asyncio.gather(*(
            svc.submit(gen_of(8, seed=i).next_batch()) for i in range(6)))
        assert svc.queue_depth == 0


@async_test
async def test_feature_only_heads_serve_through_buckets(mlp_params, cnn_params):
    """Pluggable heads serve through the bucketed frontend unchanged: a
    feature-only pipeline (no engine inference at all — empty RoutePlan)
    answers ragged concurrent clients from pre-warmed masked entries, never
    retraces, and emits the pass head's allow-everything verdicts."""
    from repro.core import decisions

    pipe = make_pipeline(mlp_params, cnn_params, batch_size=16,
                         pkt_head=decisions.PassHead(),
                         flow_head=decisions.TopKHead(), top_n=8)
    assert len(pipe.plan().steps) == 0
    gens = [gen_of(5, seed=1, client_id=0), gen_of(11, seed=2, client_id=1)]
    svc = OctopusService(pipe, ServiceConfig(buckets=(8, 16)))
    async with svc:
        warmed = svc.trace_count
        outs = await asyncio.gather(*(svc.submit(g.next_batch(), g.client_id)
                                      for g in gens))
        assert svc.trace_count == warmed
    assert sorted(o.pkt_actions.shape for o in outs) == [(5,), (11,)]
    for o in outs:
        np.testing.assert_array_equal(o.pkt_actions,
                                      np.zeros(o.pkt_actions.shape, np.int32))


# ------------------------------------------------------------ failure path

class _FailOnce:
    """Injected failing step: raises on the first call, then delegates —
    the regression harness for the dispatcher's failure path."""

    def __init__(self, inner, exc):
        self.inner = inner
        self.exc = exc
        self.calls = 0

    def __call__(self, batch, keep):
        self.calls += 1
        if self.calls == 1:
            raise self.exc
        return self.inner(batch, keep)


@async_test
async def test_failing_dispatch_resolves_futures_and_service_survives(
        mlp_params, cnn_params):
    """Regression: a raising step_masked used to leave every coalesced
    future unresolved (submit hung forever), leak the pooled staging buffer
    and keep _depth inflated, wedging admission control.  Now every affected
    client gets the error, the buffer returns to the pool, the depth drains,
    and the NEXT submit is served normally."""
    pipe = make_pipeline(mlp_params, cnn_params)
    svc = OctopusService(pipe, ServiceConfig(buckets=(8, 16, 32)))
    async with svc:
        boom = RuntimeError("injected device fault")
        pipe.step_masked = _FailOnce(pipe.step_masked, boom)
        outcomes = await asyncio.gather(
            svc.submit(gen_of(5, seed=1).next_batch(), client_id=0),
            svc.submit(gen_of(6, seed=2).next_batch(), client_id=1),
            return_exceptions=True)
        # both coalesced clients see the SAME injected error, not a hang
        assert all(o is boom for o in outcomes)
        assert svc.queue_depth == 0  # depth restored — admission not wedged
        assert svc.stats.failed_dispatches == 1
        assert svc.stats.failed == 11
        assert svc.stats.served == 0

        # the service keeps serving: next submit succeeds and — landing in
        # the same 16 bucket — reuses the staging buffer the failed dispatch
        # released (no pool leak)
        misses_before = svc.stats.pool_misses
        res = await svc.submit(gen_of(11, seed=3).next_batch(), client_id=0)
        assert isinstance(res, ServeResult)
        assert res.pkt_actions.shape == (11,)
        assert svc.stats.pool_misses == misses_before
        assert svc.stats.pool_hits >= 1
    assert svc.stats.served == 11


@async_test
async def test_failing_dispatch_unblocks_waiting_submitters(mlp_params,
                                                            cnn_params):
    """block-admission waiters must wake when a FAILING dispatch frees the
    queue — the _space event is re-set on the error path too."""
    pipe = make_pipeline(mlp_params, cnn_params)
    svc = OctopusService(pipe, ServiceConfig(
        buckets=(8,), depth_budget=8, admission="block"))
    async with svc:
        pipe.step_masked = _FailOnce(pipe.step_masked, RuntimeError("boom"))
        outcomes = await asyncio.gather(
            svc.submit(gen_of(8, seed=1).next_batch(), client_id=0),
            svc.submit(gen_of(8, seed=2).next_batch(), client_id=1),
            return_exceptions=True)
        # first fails, second (which had to wait for space) is served
        assert isinstance(outcomes[0], RuntimeError)
        assert isinstance(outcomes[1], ServeResult)
        assert svc.queue_depth == 0


# ---------------------------------------------------------- wall-s freshness

@async_test
async def test_wall_clock_snapshots_at_read_time(mlp_params, cnn_params):
    """Regression: wall_s was only refreshed inside the dispatcher, so
    pkt_per_s read after an idle tail used a stale clock and overstated
    throughput.  It must tick between reads while the service runs, and
    freeze at stop()."""
    pipe = make_pipeline(mlp_params, cnn_params)
    svc = OctopusService(pipe, ServiceConfig(buckets=(8,)))
    async with svc:
        await svc.submit(gen_of(8, seed=1).next_batch())
        w1 = svc.stats.wall_s
        r1 = svc.stats.pkt_per_s
        await asyncio.sleep(0.05)  # idle tail — no dispatches
        w2 = svc.stats.wall_s
        assert w2 >= w1 + 0.04  # the clock kept ticking
        assert svc.stats.pkt_per_s < r1  # throughput decays over idle time
    frozen = svc.stats.wall_s  # stop() freezes the clock
    await asyncio.sleep(0.02)
    assert svc.stats.wall_s == frozen


# ------------------------------------------------------- real dispatch bucket

@async_test
async def test_result_bucket_is_the_actual_dispatch_bucket(mlp_params,
                                                           cnn_params):
    """Regression: ServeResult.bucket was recomputed from the request's own
    chunk size, not the coalesced dispatch it actually rode in.  Two
    requests coalescing into one 16-bucket must BOTH report 16."""
    pipe = make_pipeline(mlp_params, cnn_params)
    svc = OctopusService(pipe, ServiceConfig(buckets=(8, 16, 32)))
    async with svc:
        results = await asyncio.gather(
            svc.submit(gen_of(5, seed=1).next_batch(), client_id=0),
            svc.submit(gen_of(6, seed=2).next_batch(), client_id=1))
        assert svc.stats.dispatches == 1  # they really coalesced
        for res in results:
            assert res.bucket == 16  # 5 + 6 = 11 -> the 16 bucket
            assert res.buckets == (16,)


@async_test
async def test_oversize_split_reports_per_chunk_buckets(mlp_params,
                                                        cnn_params):
    """A submit larger than the top bucket splits into chunks; the result
    reports every chunk's actual bucket and the max as `bucket` (the old
    code reported the LAST chunk's size class — 8 for a 70-packet submit)."""
    pipe = make_pipeline(mlp_params, cnn_params)
    svc = OctopusService(pipe, ServiceConfig(buckets=(8, 16, 32)))
    async with svc:
        res = await svc.submit(gen_of(70, seed=1).next_batch())
        assert res.pkt_actions.shape == (70,)
        assert res.buckets == (32, 32, 8)  # 70 = 32 + 32 + 6
        assert res.bucket == 32


# ----------------------------------------------------- offload on/off twins

@async_test
async def test_inline_dispatch_mode_serves_identically(mlp_params,
                                                       cnn_params):
    """offload=False keeps the old loop-inline dispatch (the bench twin);
    the serving surface — verdicts, buckets, failure path — is identical."""
    pipe = make_pipeline(mlp_params, cnn_params)
    svc = OctopusService(pipe, ServiceConfig(buckets=(8, 16), offload=False))
    async with svc:
        assert svc._executor is None
        res = await svc.submit(gen_of(11, seed=1).next_batch())
        assert isinstance(res, ServeResult)
        assert res.pkt_actions.shape == (11,) and res.bucket == 16
        pipe.step_masked = _FailOnce(pipe.step_masked, RuntimeError("boom"))
        with pytest.raises(RuntimeError, match="boom"):
            await svc.submit(gen_of(4, seed=2).next_batch())
        res = await svc.submit(gen_of(3, seed=3).next_batch())
        assert res.pkt_actions.shape == (3,)
    assert svc.stats.failed_dispatches == 1
    assert svc.stats.host_s > 0 and svc.stats.device_s > 0


@async_test
async def test_offload_dispatch_splits_host_device_time(mlp_params,
                                                        cnn_params):
    pipe = make_pipeline(mlp_params, cnn_params)
    svc = OctopusService(pipe, ServiceConfig(buckets=(8, 16)))
    async with svc:
        assert math.isnan(svc.stats.host_us)  # idle convention
        for i in range(3):
            await svc.submit(gen_of(8, seed=i).next_batch())
    s = svc.stats
    assert s.dispatches == 3
    assert s.host_s > 0 and s.device_s > 0
    assert math.isfinite(s.host_us) and math.isfinite(s.device_us)
