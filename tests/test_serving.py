"""LM serving engine: continuous batching correctness vs a reference
single-request greedy decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models import LM
from repro.serving import Request, ServeConfig, ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("qwen3-0.6b"))
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def reference_greedy(model, params, prompt, max_new, cache_len=96):
    cache = model.init_cache(1, cache_len)
    logits, cache = jax.jit(model.prefill)(params, {"tokens": prompt[None]}, cache)
    toks = [int(jnp.argmax(logits[0, -1, : model.cfg.vocab_size]))]
    for _ in range(max_new - 1):
        lg, cache = jax.jit(model.decode_step)(
            params, {"tokens": jnp.asarray([[toks[-1]]], jnp.int32)}, cache)
        toks.append(int(jnp.argmax(lg[0, 0, : model.cfg.vocab_size])))
    return toks


def test_engine_matches_reference(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 8 + i) for i in range(3)]
    refs = [reference_greedy(model, params, jnp.asarray(p, jnp.int32), 6)
            for p in prompts]
    eng = ServeEngine(cfg, params, ServeConfig(batch_slots=2, cache_len=96))
    reqs = [Request(rid=i, prompt=p, max_new=6) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained()
    assert len(done) == 3
    for r, ref in zip(reqs, refs):
        assert r.out_tokens == ref, (r.rid, r.out_tokens, ref)


def test_engine_more_requests_than_slots(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(1)
    eng = ServeEngine(cfg, params, ServeConfig(batch_slots=2, cache_len=64))
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 4), max_new=4)
            for i in range(5)]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained()
    assert len(done) == 5
    assert all(len(r.out_tokens) == 4 for r in reqs)


def test_engine_single_slot_exhaustion_queues_and_matches(setup):
    """batch_slots=1 with several queued requests: every request waits its
    turn and still decodes exactly the single-request reference."""
    cfg, model, params = setup
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, 5 + i) for i in range(3)]
    refs = [reference_greedy(model, params, jnp.asarray(p, jnp.int32), 5)
            for p in prompts]
    eng = ServeEngine(cfg, params, ServeConfig(batch_slots=1, cache_len=96))
    reqs = [Request(rid=i, prompt=p, max_new=5) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    assert len(eng.queue) == 3  # all queued, single slot
    done = eng.run_until_drained()
    assert len(done) == 3 and not eng.queue and not eng.active.any()
    for r, ref in zip(reqs, refs):
        assert r.out_tokens == ref, (r.rid, r.out_tokens, ref)


def test_engine_eos_early_stop(setup):
    """A request whose decode emits eos_id stops early — fewer than max_new
    tokens, the slot frees, and a queued request takes it over."""
    cfg, model, params = setup
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 6)
    ref = reference_greedy(model, params, jnp.asarray(prompt, jnp.int32), 8)
    eos = ref[2]  # first decode-loop emission we stop on (prefill token is ref[0])
    stop_at = ref.index(eos, 1) + 1

    eng = ServeEngine(cfg, params,
                      ServeConfig(batch_slots=1, cache_len=96, eos_id=eos))
    early = Request(rid=0, prompt=prompt, max_new=8)
    follower = Request(rid=1, prompt=rng.integers(0, cfg.vocab_size, 4), max_new=3)
    eng.submit(early)
    eng.submit(follower)
    done = eng.run_until_drained()
    assert len(done) == 2
    assert early.done and early.out_tokens == ref[:stop_at]
    assert len(early.out_tokens) < 8  # genuinely early
    assert early.out_tokens[-1] == eos
    assert follower.done and len(follower.out_tokens) == 3


def test_engine_reset_reuse(setup):
    """reset() returns the engine to a clean state: same prompts reproduce
    the same tokens, no slot/cache leakage from the first run."""
    cfg, model, params = setup
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, 6) for _ in range(3)]

    eng = ServeEngine(cfg, params, ServeConfig(batch_slots=2, cache_len=64))
    reqs1 = [Request(rid=i, prompt=p, max_new=4) for i, p in enumerate(prompts)]
    for r in reqs1:
        eng.submit(r)
    eng.run_until_drained()

    eng.reset()
    assert eng.queue == [] and eng.slots == [None, None]
    assert not eng.active.any()
    assert int(jnp.sum(eng.cache["lengths"])) == 0

    reqs2 = [Request(rid=i, prompt=p, max_new=4) for i, p in enumerate(prompts)]
    for r in reqs2:
        eng.submit(r)
    eng.run_until_drained()
    for a, b in zip(reqs1, reqs2):
        assert a.out_tokens == b.out_tokens, (a.rid, a.out_tokens, b.out_tokens)
