"""LM serving engine: continuous batching correctness vs a reference
single-request greedy decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models import LM
from repro.serving import Request, ServeConfig, ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("qwen3-0.6b"))
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def reference_greedy(model, params, prompt, max_new, cache_len=96):
    cache = model.init_cache(1, cache_len)
    logits, cache = jax.jit(model.prefill)(params, {"tokens": prompt[None]}, cache)
    toks = [int(jnp.argmax(logits[0, -1, : model.cfg.vocab_size]))]
    for _ in range(max_new - 1):
        lg, cache = jax.jit(model.decode_step)(
            params, {"tokens": jnp.asarray([[toks[-1]]], jnp.int32)}, cache)
        toks.append(int(jnp.argmax(lg[0, 0, : model.cfg.vocab_size])))
    return toks


def test_engine_matches_reference(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 8 + i) for i in range(3)]
    refs = [reference_greedy(model, params, jnp.asarray(p, jnp.int32), 6)
            for p in prompts]
    eng = ServeEngine(cfg, params, ServeConfig(batch_slots=2, cache_len=96))
    reqs = [Request(rid=i, prompt=p, max_new=6) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained()
    assert len(done) == 3
    for r, ref in zip(reqs, refs):
        assert r.out_tokens == ref, (r.rid, r.out_tokens, ref)


def test_engine_more_requests_than_slots(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(1)
    eng = ServeEngine(cfg, params, ServeConfig(batch_slots=2, cache_len=64))
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 4), max_new=4)
            for i in range(5)]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained()
    assert len(done) == 5
    assert all(len(r.out_tokens) == 4 for r in reqs)
