"""Recurrent mixers: chunked-parallel forms must equal naive step-by-step
recurrences (the gold standard for SSD / mLSTM correctness)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import recurrent as rec


def test_ssd_chunked_equals_sequential():
    B, S, H, P, N = 2, 23, 3, 8, 4
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    b_in = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    c_in = jax.random.normal(ks[4], (B, S, N), jnp.float32)
    state0 = jnp.zeros((B, H, N, P), jnp.float32)

    y_chunk, st_chunk = rec._ssd_chunked(xh, dt, a, b_in, c_in, chunk=5, state0=state0)

    # naive recurrence: h_t = exp(dt_t a) h_{t-1} + dt_t B_t (x) x_t; y = C.h
    st = state0
    ys = []
    for t in range(S):
        da = jnp.exp(dt[:, t] * a[None, :])  # (B,H)
        dbx = jnp.einsum("bh,bn,bhp->bhnp", dt[:, t], b_in[:, t], xh[:, t])
        st = da[:, :, None, None] * st + dbx
        ys.append(jnp.einsum("bn,bhnp->bhp", c_in[:, t], st))
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_chunk), np.asarray(st), rtol=2e-4, atol=2e-4)


def test_mlstm_chunked_equals_sequential():
    B, S, H, D = 2, 19, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    ig = jax.random.normal(ks[3], (B, S, H)) * 2.0
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, S, H)) * 2.0)

    cache = rec.MLSTMCache(
        c=jnp.zeros((B, H, D, D)), n=jnp.zeros((B, H, D)),
        m=jnp.full((B, H), -1e30),
    )
    h_chunk, out_cache = rec._mlstm_chunk_scan(q, k, v, ig, lf, chunk=4, cache=cache)

    # naive stabilized recurrence (xLSTM paper eqs)
    c = np.zeros((B, H, D, D)); n = np.zeros((B, H, D)); m = np.full((B, H), -1e30)
    qn, kn, vn = np.asarray(q) / np.sqrt(D), np.asarray(k), np.asarray(v)
    ign, lfn = np.asarray(ig), np.asarray(lf)
    hs = []
    for t in range(S):
        m_new = np.maximum(lfn[:, t] + m, ign[:, t])
        i_p = np.exp(ign[:, t] - m_new)
        f_p = np.exp(lfn[:, t] + m - m_new)
        c = f_p[:, :, None, None] * c + i_p[:, :, None, None] * np.einsum(
            "bhd,bhp->bhdp", kn[:, t], vn[:, t])
        n = f_p[:, :, None] * n + i_p[:, :, None] * kn[:, t]
        m = m_new
        num = np.einsum("bhd,bhdp->bhp", qn[:, t], c)
        den = np.abs(np.einsum("bhd,bhd->bh", qn[:, t], n))
        den = np.maximum(den, np.exp(-m))
        hs.append(num / den[:, :, None])
    h_seq = np.stack(hs, axis=1)
    np.testing.assert_allclose(np.asarray(h_chunk), h_seq, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(out_cache.c),
                               c / np.exp(m)[:, :, None, None] * np.exp(m)[:, :, None, None],
                               rtol=1e-3, atol=1e-3)


def test_mamba2_decode_matches_chunked_prefill():
    cfg = reduced_config(get_config("zamba2-2.7b"))
    p = __import__("repro.models.spec", fromlist=["init_params"]).init_params(
        rec.mamba2_specs(cfg), jax.random.PRNGKey(0))
    B, S = 2, 11
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model), jnp.float32) * 0.3
    y_full, cache_full = rec.mamba2_apply(p, x, cfg, mode="prefill",
                                          cache=rec.init_mamba2_cache(cfg, B))
    # process the first S-1, then one decode step
    y_pre, cache = rec.mamba2_apply(p, x[:, : S - 1], cfg, mode="prefill",
                                    cache=rec.init_mamba2_cache(cfg, B))
    y_dec, cache = rec.mamba2_apply(p, x[:, S - 1 :], cfg, mode="decode", cache=cache)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]), np.asarray(y_full[:, -1]),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(cache.ssm), np.asarray(cache_full.ssm),
                               rtol=2e-3, atol=2e-3)


def test_slstm_stability_long_sequence():
    cfg = reduced_config(get_config("xlstm-1.3b"))
    from repro.models.spec import init_params

    p = init_params(rec.slstm_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 200, cfg.d_model)) * 3.0
    y, cache = rec.slstm_apply(p, x, cfg, mode="prefill")
    assert bool(jnp.all(jnp.isfinite(y)))
    assert bool(jnp.all(jnp.isfinite(cache.c)))


def test_mlstm_gate_extremes_stable():
    cfg = reduced_config(get_config("xlstm-1.3b"))
    from repro.models.spec import init_params

    p = init_params(rec.mlstm_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model)) * 10.0
    y, _ = rec.mlstm_apply(p, x, cfg, mode="train")
    assert bool(jnp.all(jnp.isfinite(y)))
