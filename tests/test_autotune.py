"""Calibration subsystem: platform probe defaults, measured-crossover fit,
artifact round-trip, schema/backend guards, and analytic-vs-calibrated
placement divergence (the self-tuning acceptance path)."""
import json
import warnings

import pytest

from repro.core import router
from repro.runtime import (
    DEFAULT_RUNTIME,
    RoutePlan,
    RuntimeConfig,
    autotune,
    current_runtime,
    octopus_runtime,
    platform,
    runtime_overrides,
)
from repro.runtime.autotune import (
    Calibration,
    ShapeTiming,
    fit_crossover,
    load_calibration,
    save_calibration,
)


def _timing(m, k, n, vpe_wins, base=DEFAULT_RUNTIME):
    util = router.mxu_utilization(m, k, n, tile=base.mxu_tile, fill=base.fill_depth)
    us_a, us_v = (2.0, 1.0) if vpe_wins else (1.0, 2.0)
    return ShapeTiming(m, k, n, util, us_arype=us_a, us_vpe=us_v)


def _calib(tau=0.6, vpe_max_elems=1 << 21, backend=None, **kw):
    fp = dict(platform.fingerprint())
    if backend is not None:
        fp["backend"] = backend
    return Calibration(tau=tau, vpe_max_elems=vpe_max_elems, fingerprint=fp, **kw)


# ---------------------------------------------------------------------------
# Platform probe
# ---------------------------------------------------------------------------

def test_platform_probe_on_cpu_host():
    # The test container is a CPU host: Pallas needs interpret mode there.
    assert platform.backend() == "cpu"
    assert not platform.is_accelerator()
    assert platform.interpret_default() is True


def test_runtime_config_default_interpret_is_platform_derived():
    assert RuntimeConfig().interpret == platform.interpret_default()
    assert DEFAULT_RUNTIME.interpret is True  # CPU container


def test_fingerprint_identifies_backend():
    fp = platform.fingerprint()
    assert fp["backend"] == "cpu"
    assert platform.fingerprint_id(fp).startswith("cpu/")


# ---------------------------------------------------------------------------
# Crossover fit (pure function, synthetic timings)
# ---------------------------------------------------------------------------

def test_fit_separates_clean_crossover():
    # VPE wins exactly the low-utilization shapes: tau must land between the
    # highest vpe-winning util and the lowest arype-winning util.
    low = [_timing(10, 3, 32, vpe_wins=True), _timing(64, 3, 8, vpe_wins=True)]
    high = [_timing(512, 128, 128, vpe_wins=False),
            _timing(4096, 256, 512, vpe_wins=False)]
    tau, vpe_max = fit_crossover(low + high)
    assert max(t.util for t in low) < tau <= min(t.util for t in high)
    assert vpe_max >= max(t.elems for t in low)
    # the fitted thresholds route those shapes the way they measured
    cfg = RuntimeConfig(tau=tau, vpe_max_elems=vpe_max)
    for t in low:
        assert router.route_matmul(t.m, t.k, t.n, config=cfg).path == "vpe"
    for t in high:
        assert router.route_matmul(t.m, t.k, t.n, config=cfg).path == "arype"


def test_fit_no_vpe_wins_closes_the_window():
    timings = [_timing(512, 128, 128, vpe_wins=False),
               _timing(64, 3, 8, vpe_wins=False)]
    tau, vpe_max = fit_crossover(timings)
    assert 0.0 < tau < min(t.util for t in timings)
    assert vpe_max == DEFAULT_RUNTIME.vpe_max_elems  # analytic fallback
    cfg = RuntimeConfig(tau=tau, vpe_max_elems=vpe_max)
    assert all(router.route_matmul(t.m, t.k, t.n, config=cfg).path == "arype"
               for t in timings)


def test_fit_empty_returns_analytic_defaults():
    assert fit_crossover([]) == (DEFAULT_RUNTIME.tau, DEFAULT_RUNTIME.vpe_max_elems)


# ---------------------------------------------------------------------------
# Artifact round-trip + guards
# ---------------------------------------------------------------------------

def test_cache_roundtrip_identical_config(tmp_path):
    path = str(tmp_path / "calib.json")
    calib = _calib(tau=0.42, vpe_max_elems=1 << 16,
                   timings=(_timing(10, 3, 32, vpe_wins=True),))
    save_calibration(calib, path)
    loaded = load_calibration(path)
    assert loaded == calib
    assert loaded.apply(RuntimeConfig()) == calib.apply(RuntimeConfig())
    cfg = loaded.apply(RuntimeConfig())
    assert (cfg.tau, cfg.vpe_max_elems) == (0.42, 1 << 16)
    assert cfg.calibration == calib.fingerprint_id


def test_schema_version_mismatch_warns_and_falls_back(tmp_path):
    path = str(tmp_path / "calib.json")
    save_calibration(_calib(), path)
    raw = json.loads(open(path).read())
    raw["schema_version"] = autotune.SCHEMA_VERSION + 1
    with open(path, "w") as f:
        json.dump(raw, f)
    with pytest.warns(UserWarning, match="schema_version"):
        assert load_calibration(path) is None
    with pytest.warns(UserWarning, match="schema_version"):
        cfg = RuntimeConfig.calibrated(path)
    assert cfg.tau == DEFAULT_RUNTIME.tau
    assert cfg.calibration is None


def test_missing_artifact_warns_and_falls_back(tmp_path):
    path = str(tmp_path / "nope.json")
    with pytest.warns(UserWarning, match="no calibration artifact"):
        cfg = RuntimeConfig.calibrated(path)
    assert cfg == DEFAULT_RUNTIME


def test_foreign_backend_artifact_is_rejected(tmp_path):
    path = str(tmp_path / "calib.json")
    save_calibration(_calib(backend="tpu"), path)
    with pytest.warns(UserWarning, match="backend"):
        assert load_calibration(path) is None


def test_corrupt_artifact_warns_and_falls_back(tmp_path):
    path = str(tmp_path / "calib.json")
    with open(path, "w") as f:
        f.write("{not json")
    with pytest.warns(UserWarning, match="unreadable"):
        assert load_calibration(path) is None


def test_default_cache_path_is_backend_keyed(tmp_path, monkeypatch):
    monkeypatch.setenv("OCTOPUS_CACHE_DIR", str(tmp_path))
    assert autotune.cache_path() == str(tmp_path / "calib-cpu.json")
    path = save_calibration(_calib(tau=0.5))
    assert path == str(tmp_path / "calib-cpu.json")
    assert load_calibration().tau == 0.5


# ---------------------------------------------------------------------------
# Calibrated routing: analytic vs measured placement can diverge
# ---------------------------------------------------------------------------

def test_calibrated_config_changes_a_route(tmp_path):
    """(128,64)x(64,96): util 0.375 — arype under the analytic tau=0.35, vpe
    under a measured tau of 0.6.  The divergence must survive the artifact
    round-trip (save -> load -> calibrated())."""
    path = str(tmp_path / "calib.json")
    save_calibration(_calib(tau=0.6, vpe_max_elems=1 << 21), path)
    calibrated = RuntimeConfig.calibrated(path)
    analytic = router.route_matmul(128, 64, 96, config=DEFAULT_RUNTIME)
    measured = router.route_matmul(128, 64, 96, config=calibrated)
    assert (analytic.path, measured.path) == ("arype", "vpe")


def test_octopus_runtime_accepts_a_calibration(tmp_path):
    path = str(tmp_path / "calib.json")
    save_calibration(_calib(tau=0.6), path)
    with runtime_overrides(policy="collaborative", mxu_tile=64):
        with octopus_runtime(load_calibration(path)) as cfg:
            # applied onto the *ambient* config, not a fresh default
            assert cfg.mxu_tile == 64 and cfg.tau == 0.6
            assert current_runtime().calibration == cfg.calibration is not None
    assert current_runtime().calibration is None


def test_plan_and_cycle_report_record_calibration(tmp_path):
    from repro.core.collaborative import OctopusCycleModel, usecase2_layers

    path = str(tmp_path / "calib.json")
    save_calibration(_calib(tau=0.6), path)
    cfg = RuntimeConfig.calibrated(path)
    plan = RoutePlan.from_layers(usecase2_layers(1000), config=cfg)
    assert "[calibrated:" in plan.explain()
    rep = OctopusCycleModel().stack_report(plan, collaborative=True)
    assert rep["calibration"] == cfg.calibration
    analytic_rep = OctopusCycleModel().stack_report(
        RoutePlan.from_layers(usecase2_layers(1000)), collaborative=True)
    assert analytic_rep["calibration"] is None


# ---------------------------------------------------------------------------
# End-to-end measurement (tiny grid; CPU timings are noisy, so assert
# structure and constraints rather than which engine won)
# ---------------------------------------------------------------------------

def test_measure_and_calibrate_smoke(tmp_path):
    shapes = [(8, 3, 8), (256, 128, 128)]
    calib = autotune.calibrate(shapes, iters=1, warmup=0)
    assert len(calib.timings) == 2
    assert all(t.us_arype > 0 and t.us_vpe > 0 for t in calib.timings)
    assert 0.0 < calib.tau <= 1.0
    assert calib.vpe_max_elems > 0
    assert calib.backend == "cpu"
    path = save_calibration(calib, str(tmp_path / "calib.json"))
    assert load_calibration(path) == calib


def test_calibrate_cli_writes_artifact(tmp_path, capsys):
    from repro.launch import calibrate as cli

    out = str(tmp_path / "calib.json")
    assert cli.main(["--out", out, "--smoke", "--iters", "1"]) == 0
    raw = json.load(open(out))
    assert raw["schema_version"] == autotune.SCHEMA_VERSION
    assert raw["fingerprint"]["backend"] == "cpu"
    text = capsys.readouterr().out
    assert "placement divergence" in text
    loaded = load_calibration(out)
    assert isinstance(loaded.apply(RuntimeConfig()), RuntimeConfig)


def test_divergence_report_names_moved_layers():
    from repro.launch.calibrate import divergence_report

    # conv2 (10000,96,32): util 0.1875, working set 30.7M elems — moves to vpe
    # once the measured tau and cap both open up.
    report = divergence_report(RuntimeConfig(tau=0.6, vpe_max_elems=1 << 25),
                               flows=1000)
    assert "conv2" in report and "arype -> vpe" in report


def test_warnings_are_not_raised_on_happy_path(tmp_path):
    path = str(tmp_path / "calib.json")
    save_calibration(_calib(), path)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        load_calibration(path)
        RuntimeConfig.calibrated(path)
