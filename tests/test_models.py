"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
asserting output shapes + finite values — as required by the assignment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduced_config
from repro.models import LM

ARCHS = list_archs()


def make_batch(cfg, key, b=2, s=16, labels=True):
    batch = {}
    if cfg.frontend == "audio_frames":
        batch["frames"] = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    if cfg.frontend == "vision_patches":
        batch["vision"] = jax.random.normal(key, (b, cfg.num_image_tokens, cfg.d_model),
                                            jnp.float32)
    if labels:
        batch["labels"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return batch


def test_all_archs_registered():
    assert len(ARCHS) == 10
    assert set(ARCHS) == {
        "xlstm-1.3b", "llama-3.2-vision-90b", "gemma3-1b", "qwen3-0.6b", "qwen3-4b",
        "starcoder2-15b", "kimi-k2-1t-a32b", "granite-moe-1b-a400m", "zamba2-2.7b",
        "hubert-xlarge",
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_dims(arch):
    """The full configs carry the exact assigned dimensions."""
    cfg = get_config(arch)
    expected = {
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.d_ff,
           cfg.vocab_size)
    assert got == expected, (arch, got, expected)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced_config(get_config(arch))
    m = LM(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    batch = make_batch(cfg, key)
    logits, aux = jax.jit(m.forward)(params, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits[..., : cfg.vocab_size])))
    # one train (grad) step
    loss, grads = jax.jit(
        lambda p, b: jax.value_and_grad(lambda pp: m.loss(pp, b)[0])(p)
    )(params, batch)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_shape_cells_follow_assignment_rules(arch):
    cfg = get_config(arch)
    cells = cfg.shape_cells()
    assert "train_4k" in cells and "prefill_32k" in cells
    if arch == "hubert-xlarge":
        assert "decode_32k" not in cells and "long_500k" not in cells
    else:
        assert "decode_32k" in cells
    if arch in ("xlstm-1.3b", "zamba2-2.7b", "gemma3-1b"):
        assert "long_500k" in cells  # sub-quadratic archs run the 500k cell
    if arch in ("qwen3-0.6b", "qwen3-4b", "starcoder2-15b", "llama-3.2-vision-90b",
                "kimi-k2-1t-a32b", "granite-moe-1b-a400m"):
        assert "long_500k" not in cells  # pure full-attention: skipped


def test_total_cells_documented():
    from repro.launch.cells import all_cells

    cells = all_cells()
    # 10 archs x 4 shapes = 40 nominal; 7 long_500k skips + 1 decode skip = 32
    assert len(cells) == 32


@pytest.mark.parametrize("arch", ["gemma3-1b", "zamba2-2.7b", "xlstm-1.3b"])
def test_decode_state_is_constant_size(arch):
    """long_500k eligibility: decode cache must not scale with history for the
    recurrent parts (ring buffers for local attention)."""
    cfg = reduced_config(get_config(arch))
    m = LM(cfg)
    cache64 = jax.eval_shape(lambda: m.init_cache(1, 64))
    cache256 = jax.eval_shape(lambda: m.init_cache(1, 256))
    l64 = jax.tree.leaves(cache64)
    l256 = jax.tree.leaves(cache256)
    grew = sum(int(np.prod(b.shape)) > int(np.prod(a.shape))
               for a, b in zip(l64, l256))
    if arch == "xlstm-1.3b":
        assert grew == 0  # pure recurrent: nothing grows with history
    else:
        assert grew < len(l64)  # hybrid: only global-attn caches grow
