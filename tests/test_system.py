"""End-to-end behaviour tests for the paper's system: packets -> feature
extractor -> DL inference -> decisions (the full Octopus working procedure),
for all three use-cases, plus the cycle model's validation of the paper's own
Table 6 numbers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import decisions
from repro.core.collaborative import (
    OctopusCycleModel,
    collaborative_forward,
    usecase2_plan,
    usecase3_plan,
)
from repro.runtime import RuntimeConfig
from repro.core.feature_extractor import ExtractorConfig, FeatureExtractor
from repro.data.packets import PacketTraceConfig, synth_packet_trace
from repro.models import paper_models
from repro.serving.packet_path import FlowPath, PacketPath


@pytest.fixture(scope="module")
def trace():
    cfg = PacketTraceConfig(num_flows=64, pkts_per_flow=20, seed=7, table_size=1024)
    return synth_packet_trace(cfg)


def test_usecase1_packet_mlp_end_to_end(trace):
    packets, classes, hashes, labels = trace
    params = paper_models.init_paper_model("mlp", jax.random.PRNGKey(0))
    path = PacketPath(params)
    path.warmup(batch=packets.ts.shape[0])
    actions = path.process(packets)
    assert actions.shape == (packets.ts.shape[0],)
    assert set(np.unique(actions)) <= {0, 1}
    assert path.rules.lookup(int(packets.tuple_hash[0]))["generation"] == 1
    assert path.stats.latency_us > 0


def test_usecase2_flow_cnn_end_to_end(trace):
    packets, classes, hashes, labels = trace
    ex = FeatureExtractor(ExtractorConfig(table_size=1024, top_n=20))
    feats, series, sizes, payload, counts = ex.extract_segmented(packets)
    ready = np.asarray(counts) >= 20
    assert ready.sum() == 64  # all flows delivered top-20 packets
    x = jnp.log1p(series[ready].astype(jnp.float32))
    params = paper_models.init_paper_model("cnn", jax.random.PRNGKey(0))
    fp = FlowPath(params, model="cnn")
    cls = fp.process(x, np.flatnonzero(ready))
    assert cls.shape == (64,)
    assert (cls >= 0).all() and (cls < paper_models.CNN_CLASSES).all()


def test_usecase3_payload_transformer_end_to_end(trace):
    packets, classes, hashes, labels = trace
    ex = FeatureExtractor(ExtractorConfig(table_size=1024, top_n=20, top_k=15,
                                          pay_bytes=16))
    feats, series, sizes, payload, counts = ex.extract_segmented(packets)
    ready = np.asarray(counts) >= 15
    x = payload[ready].astype(jnp.float32) / 255.0
    params = paper_models.init_paper_model("transformer", jax.random.PRNGKey(0))
    fp = FlowPath(params, model="transformer")
    cls = fp.process(x, np.flatnonzero(ready))
    assert cls.shape[0] == int(ready.sum())


def test_cnn_matmul_mapping_matches_paper():
    """The img2col lowering reproduces the paper's §4.2 matmul shapes."""
    f = 3
    x = jnp.zeros((f, paper_models.CNN_SEQ))
    shapes = []

    # capture conv input widths by probing layer dims directly
    h = x[..., :, None]
    for i, (ci, co) in enumerate(zip(paper_models.CNN_CHANNELS[:-1],
                                     paper_models.CNN_CHANNELS[1:])):
        cols = paper_models._img2col_1d(h, paper_models.CNN_KERNEL)
        shapes.append((cols.shape[-2] * f if False else cols.shape[-2], cols.shape[-1], co))
        h = jnp.zeros((f, cols.shape[-2], co))
        h = paper_models._ceil_pool(h)
    # per-flow window counts 20 -> 10 -> 5 and K dims 3 -> 96 -> 96
    assert shapes[0] == (20, 3, 32)
    assert shapes[1] == (10, 96, 32)
    assert shapes[2] == (5, 96, 32)
    assert h.shape == (f, 3, 32)  # flatten -> 96 (paper's FC input)


def test_collaborative_fused_equals_unfused():
    ws = [jax.random.normal(jax.random.PRNGKey(i), s) for i, s in
          enumerate([(300, 64), (64, 96), (96, 8)])]
    x = jax.random.normal(jax.random.PRNGKey(9), (32, 300))
    a = collaborative_forward(x, ws, ["relu", "relu", None],
                              config=RuntimeConfig(fused_aggregation=True))
    b = collaborative_forward(x, ws, ["relu", "relu", None],
                              config=RuntimeConfig(fused_aggregation=False))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_cycle_model_reproduces_paper_table6_shape():
    """Paper Table 6: wo/ collaborating AryPE efficiency 48.2%; w/ 81.1%;
    1.69x throughput.  Our first-principles model lands within a few points
    on the ablation side and reproduces the direction and magnitude of the
    collaborative win."""
    m = OctopusCycleModel()
    plan = usecase2_plan(1000)
    off = m.stack_report(plan, collaborative=False)
    on = m.stack_report(plan, collaborative=True)
    assert abs(off["arype_eff"] - 0.482) < 0.06  # paper: 48.2%
    assert on["arype_eff"] > off["arype_eff"] + 0.25
    speedup = off["time_s"] / on["time_s"]
    assert 1.4 < speedup < 2.6  # paper: 1.69x


def test_cycle_model_usecase3_efficiency():
    m = OctopusCycleModel()
    rep = m.stack_report(usecase3_plan(1000), collaborative=True)
    # paper: 96.3% AryPE efficiency for the transformer use-case
    assert rep["arype_eff"] > 0.70


def test_decision_module():
    logits = jnp.asarray([[0.1, 5.0], [5.0, 0.1]])
    acts = decisions.decide_binary(logits)
    assert list(np.asarray(acts)) == [1, 0]
    table = decisions.RuleTable()
    table.update(np.asarray([11, 22]), np.asarray(acts))
    assert table.lookup(11)["action"] == "deny"
    assert table.lookup(22)["action"] == "allow"
    assert table.lookup(99)["action"] == "allow"  # default
