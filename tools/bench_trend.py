"""Persistent bench trajectory: append smoke-bench runs to a directory of
slim per-run points and gate regressions against the last point.

The trajectory directory (CI: restored/saved via ``actions/cache``) holds one
``BENCH_<index>.json`` per past bench-smoke run.  Each point carries just the
tracked rows' throughput — not the full artifact — so the directory stays
small enough to cache across hundreds of PRs.

    python tools/bench_trend.py append  --trajectory DIR --run bench.json
    python tools/bench_trend.py check   --trajectory DIR --run bench.json
    python tools/bench_trend.py summary --trajectory DIR [--markdown]

``check`` exits nonzero when any tracked row's pkt/s drops more than
``--threshold`` (default 25%) against the previous point; ``--skip`` (CI: a
``[bench-skip]`` commit-message tag) records the comparison but always exits
zero.  Int8 twin rows are deliberately untracked: their trajectory is
informational until a backend with a native int8 MXU path runs the job.

An empty trajectory is bootstrapped from the committed seed point in
``benchmarks/trajectory/`` (CI copies it in when the cache restore comes
back empty).  A baseline labeled ``seed`` is report-only — it was measured
on whatever machine generated it, so the absolute pkt/s is not comparable
to the CI runner's; the gate arms at the first CI-appended point.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys
import time

SCHEMA_VERSION = 1

# Gated rows: the single-lane/sharded segmented pipeline curve, the
# 4-client service row, the overlapped-dispatch row, and the hierarchical
# (hot+cold, ~1.3e5-flow capacity) flow-table row — the repo's headline
# pkt/s numbers.
TRACKED = (
    "pipeline_cnn_lane128_segmented_s1",
    "pipeline_cnn_lane128_segmented_s2",
    "pipeline_cnn_lane128_segmented_s4",
    "pipeline_cnn_b128_segmented_x8_ovl1",
    "service_cnn_c4_b16",
    "pipeline_cnn_b128_cold131072",
    "scenario_topk_b128_cold4096",
)

_POINT_RE = re.compile(r"^BENCH_(\d+)\.json$")


def _derived_metric(derived: str, key: str) -> float | None:
    for part in derived.split(";"):
        if part.startswith(key + "="):
            try:
                return float(part.split("=", 1)[1])
            except ValueError:
                return None
    return None


def extract_point(run_artifact: dict, label: str | None = None) -> dict:
    """Slim trajectory point from a ``benchmarks/run.py --json`` artifact."""
    rows = {}
    for suite in run_artifact.get("suites", []):
        for r in suite.get("rows", []):
            if r.get("name") in TRACKED:
                rows[r["name"]] = {
                    "us_per_call": r.get("us_per_call"),
                    "pkt_per_s": _derived_metric(r.get("derived", ""), "pkt_per_s"),
                }
    return {
        "schema_version": SCHEMA_VERSION,
        "label": label or "",
        "created_unix": time.time(),
        "backend": (run_artifact.get("platform") or {}).get("backend"),
        "rows": rows,
    }


def load_trajectory(traj_dir: str) -> list[tuple[int, dict]]:
    """(index, point) pairs sorted by index; unreadable points are skipped."""
    points = []
    if not os.path.isdir(traj_dir):
        return points
    for name in os.listdir(traj_dir):
        m = _POINT_RE.match(name)
        if not m:
            continue
        try:
            with open(os.path.join(traj_dir, name)) as f:
                d = json.load(f)
        except (OSError, json.JSONDecodeError):
            continue
        if not isinstance(d, dict) or d.get("schema_version") != SCHEMA_VERSION:
            continue
        points.append((int(m.group(1)), d))
    points.sort(key=lambda kv: kv[0])
    return points


def cmd_append(args) -> int:
    with open(args.run) as f:
        artifact = json.load(f)
    point = extract_point(artifact, label=args.label)
    if not point["rows"]:
        print("[trend] run artifact has no tracked rows; nothing appended")
        return 1
    os.makedirs(args.trajectory, exist_ok=True)
    points = load_trajectory(args.trajectory)
    index = points[-1][0] + 1 if points else 1
    path = os.path.join(args.trajectory, f"BENCH_{index:04d}.json")
    with open(path, "w") as f:
        json.dump(point, f, indent=1)
    print(f"[trend] appended point {index} ({len(point['rows'])} tracked rows) "
          f"-> {path}")
    return 0


def cmd_check(args) -> int:
    with open(args.run) as f:
        artifact = json.load(f)
    current = extract_point(artifact)["rows"]
    points = load_trajectory(args.trajectory)
    if not points:
        print("[trend] no prior trajectory point; nothing to gate against")
        return 0
    prev_idx, prev = points[-1]
    # The committed seed point (label "seed") was measured on whatever
    # machine bootstrapped the trajectory — cross-machine CPU deltas can
    # exceed any sane threshold, so a seed baseline reports but never
    # fails.  The gate arms once CI appends its own first point.
    seed_baseline = prev.get("label") == "seed"
    regressions = []
    for name in TRACKED:
        now = (current.get(name) or {}).get("pkt_per_s")
        was = (prev["rows"].get(name) or {}).get("pkt_per_s")
        if now is None or was is None or was <= 0:
            continue
        delta = (now - was) / was
        marker = " <-- REGRESSION" if delta < -args.threshold else ""
        print(f"[trend] {name}: {was:.0f} -> {now:.0f} pkt/s "
              f"({100 * delta:+.1f}% vs point {prev_idx}){marker}")
        if delta < -args.threshold:
            regressions.append((name, was, now, delta))
    if regressions:
        if args.skip:
            print(f"[trend] {len(regressions)} regression(s) over the "
                  f"{100 * args.threshold:.0f}% threshold — [bench-skip] "
                  f"active, not failing")
            return 0
        if seed_baseline:
            print(f"[trend] {len(regressions)} regression(s) vs the committed "
                  f"seed point — different machine, report-only; the gate "
                  f"arms at the next CI-appended point")
            return 0
        print(f"[trend] FAIL: {len(regressions)} tracked row(s) dropped more "
              f"than {100 * args.threshold:.0f}% (commit with [bench-skip] "
              f"to override)")
        return 1
    print("[trend] all tracked rows within threshold")
    return 0


def cmd_summary(args) -> int:
    points = load_trajectory(args.trajectory)
    if not points:
        print("no bench trajectory yet")
        return 0
    if args.markdown:
        print(f"### Bench trajectory ({len(points)} runs)")
        print()
        header = ["run", "label"] + [n.replace("pipeline_cnn_", "").replace(
            "service_cnn_", "svc_") for n in TRACKED]
        print("| " + " | ".join(header) + " |")
        print("|" + "---|" * len(header))
        for idx, p in points:
            cells = [str(idx), p.get("label") or "-"]
            for name in TRACKED:
                v = (p["rows"].get(name) or {}).get("pkt_per_s")
                cells.append(f"{v:.0f}" if v is not None else "-")
            print("| " + " | ".join(cells) + " |")
        print()
        print("_pkt/s per tracked row; gate fails on a >25% drop vs the "
              "previous run ([bench-skip] overrides)._")
    else:
        for idx, p in points:
            vals = "  ".join(
                f"{name}={((p['rows'].get(name) or {}).get('pkt_per_s') or float('nan')):.0f}"
                for name in TRACKED)
            print(f"run {idx:4d} [{p.get('label') or '-'}]  {vals}")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("append", help="append a run artifact to the trajectory")
    p.add_argument("--trajectory", required=True)
    p.add_argument("--run", required=True, help="benchmarks/run.py --json artifact")
    p.add_argument("--label", default=None, help="point label (CI: commit sha)")
    p.set_defaults(fn=cmd_append)

    p = sub.add_parser("check", help="gate a run against the last trajectory point")
    p.add_argument("--trajectory", required=True)
    p.add_argument("--run", required=True)
    p.add_argument("--threshold", type=float, default=0.25,
                   help="max tolerated fractional pkt/s drop (default 0.25)")
    p.add_argument("--skip", action="store_true",
                   help="report but never fail ([bench-skip] escape hatch)")
    p.set_defaults(fn=cmd_check)

    p = sub.add_parser("summary", help="print the pkt/s curve across runs")
    p.add_argument("--trajectory", required=True)
    p.add_argument("--markdown", action="store_true",
                   help="GitHub step-summary table format")
    p.set_defaults(fn=cmd_summary)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
