"""Docs checker: every fenced ``python`` block in the given markdown files
must execute, and every ``repro.*`` dotted path named anywhere in them must
resolve (module import, optionally + attribute chain).

    PYTHONPATH=src python tools/check_docs.py README.md docs/ARCHITECTURE.md

Execution model: blocks of one file run *in order in one shared namespace*
(like a reader typing them into one REPL), so later blocks may use names an
earlier block defined.  Blocks fenced as ```python are executed; any other
info string (```bash, ```text, ...) is skipped.  Keep doc snippets small —
this runs on CPU in CI on every PR.

The dead-reference lint catches docs drifting from the tree: renaming a
module without updating README/ARCHITECTURE fails CI instead of shipping a
stale paper→module map.
"""
from __future__ import annotations

import argparse
import importlib
import re
import sys
import traceback

FENCE = re.compile(r"^```(\w*)\s*$")
# dotted repro paths in prose or code: repro.core.flow_tracker,
# repro.serving.OctopusPipeline, ... (at least one dotted component)
REF = re.compile(r"\brepro(?:\.[A-Za-z_][A-Za-z0-9_]*)+")


def python_blocks(text: str) -> list[tuple[int, str]]:
    """(first-line-number, source) for every ```python fenced block."""
    blocks = []
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = FENCE.match(lines[i])
        if m and m.group(1) == "python":
            start = i + 1
            j = start
            while j < len(lines) and not lines[j].startswith("```"):
                j += 1
            blocks.append((start + 1, "\n".join(lines[start:j])))
            i = j + 1
        else:
            i += 1
    return blocks


def resolve_ref(path: str) -> str | None:
    """Import the longest module prefix of ``path``, then getattr the rest.
    Returns an error string, or None when the reference resolves."""
    parts = path.split(".")
    for cut in range(len(parts), 0, -1):
        mod_name = ".".join(parts[:cut])
        try:
            obj = importlib.import_module(mod_name)
        except ImportError:
            continue
        try:
            for attr in parts[cut:]:
                obj = getattr(obj, attr)
        except AttributeError as e:
            return f"{path}: imported {mod_name} but {e}"
        return None
    return f"{path}: no importable module prefix"


def check_file(path: str) -> list[str]:
    with open(path, encoding="utf-8") as f:
        text = f.read()
    errors = []

    ns: dict = {"__name__": f"doccheck_{path}"}
    for lineno, src in python_blocks(text):
        try:
            exec(compile(src, f"{path}:{lineno}", "exec"), ns)  # noqa: S102
        except Exception:
            errors.append(f"{path}:{lineno}: python block failed:\n"
                          f"{traceback.format_exc(limit=3)}")

    for ref in sorted({m.group(0).rstrip(".") for m in REF.finditer(text)}):
        err = resolve_ref(ref)
        if err:
            errors.append(f"{path}: dead reference {err}")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="execute doc snippets + lint repro.* references")
    ap.add_argument("files", nargs="+", help="markdown files to check")
    args = ap.parse_args(argv)
    failures = []
    for path in args.files:
        errs = check_file(path)
        status = "FAIL" if errs else "ok"
        print(f"[docs-check] {path}: {status}")
        failures.extend(errs)
    for e in failures:
        print(e, file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
